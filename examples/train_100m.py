"""Train a LM end-to-end on the synthetic pipeline with checkpoints.

Default is a CPU-friendly ~3M-param config for a quick run; pass
--full for a ~100M-parameter model (a few hundred steps; intended for a
real accelerator) — same code path either way.

    PYTHONPATH=src python examples/train_100m.py [--full] [--steps 300]
"""
import argparse
import dataclasses

import jax

from repro.configs.base import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.lm import LM
from repro.training import checkpoint as CKPT
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true")
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--ckpt-dir", default="/tmp/comet_train_example")
args = ap.parse_args()

cfg = get_smoke_config("llama3_8b")
if args.full:   # ~100M params
    cfg = dataclasses.replace(
        cfg, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32000)
print(f"{cfg.name}: ~{cfg.param_count()/1e6:.0f}M params")

lm = LM(cfg)
params, axes = lm.init(jax.random.PRNGKey(0))
opt_state = OPT.adamw_init(params)
opt_cfg = OPT.AdamWConfig(
    lr=3e-4, schedule=OPT.cosine_schedule(20, args.steps))
step_fn = jax.jit(make_train_step(lm, opt_cfg), donate_argnums=(0, 1))
data = SyntheticLMData(DataConfig(
    vocab_size=cfg.vocab_size, seq_len=128, global_batch=8))

start = 0
if CKPT.latest_step(args.ckpt_dir) is not None:
    (params, opt_state), _, start = CKPT.restore(
        args.ckpt_dir, (params, opt_state))
    print(f"resumed from step {start}")

for step in range(start, args.steps):
    params, opt_state, m = step_fn(params, opt_state,
                                   data.batch_for_step(step))
    if step % 10 == 0 or step == args.steps - 1:
        print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
              f"lr {float(m['lr']):.2e}")
    if (step + 1) % 50 == 0:
        CKPT.save_async(args.ckpt_dir, step + 1, (params, opt_state))
CKPT.wait_async()
CKPT.save(args.ckpt_dir, args.steps, (params, opt_state))
print("done; checkpoint at", args.ckpt_dir)
