"""Replica-group failover walkthrough: kill a replica mid-decode and
watch the group keep every client stream intact.

Runs the same workload twice on a two-replica group — once untouched
(the reference), once with a deterministic ``crash`` fault killing
replica 0 at its step 6 (mid-decode) — and proves the failure is
invisible at the layer clients read:

* every delivered token stream is greedy-identical to the reference,
* every request gets exactly ONE terminal event (duplicates from the
  recovery replay are verified bitwise and suppressed),
* the survivors' page pools drain back to baseline,
* the whole episode is counters, not exceptions
  (``internal_errors == 0``).

Both failover policies run: ``migrate`` folds the dead replica's
in-flight requests (prompt + delivered tokens) onto the survivor under
their original request ids; ``standby`` resumes the dead replica whole
from its shipped RecoveryLog artifacts and promotes it in place.

    PYTHONPATH=src python examples/failover_walkthrough.py

The serve CLI drives the same seam:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b \
        --smoke --requests 6 --max-new 8 --replicas 2 \
        --failover migrate --kill-replica-at 6 --stream
"""
import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import EngineConfig, SamplingParams
from repro.serving.faults import Fault, FaultInjector
from repro.serving.replication import ReplicaGroup

cfg = get_smoke_config("llama3_8b")
quant = QuantConfig(weight_only=True, kv4=True, impl="ref")
params, axes = LM(cfg).init(jax.random.PRNGKey(0))
qparams, _ = LM(cfg, quant=quant).quantize(params, axes)

ECFG = EngineConfig(max_batch=4, num_pages=64, page_size=8,
                    max_pages_per_seq=16, prefill_chunk_tokens=8,
                    kv_range=4.0)
rng = np.random.default_rng(7)
PROMPTS = [rng.integers(1, 100, int(n)).tolist()
           for n in rng.integers(12, 18, 3)]


def run_group(failover, kill_step=None):
    faults = None
    if kill_step is not None:
        faults = [FaultInjector([Fault("crash", step=kill_step)]),
                  FaultInjector()]
    group = ReplicaGroup(cfg, qparams, quant, ECFG, replicas=2,
                         failover=failover, snapshot_every=4,
                         faults=faults)
    rids = [group.submit(p, SamplingParams(max_new_tokens=6))
            for p in PROMPTS]
    group.run()
    return group, rids


# the no-failure run every failover case is compared against
ref, rids = run_group("migrate")
print("reference (no failure):")
for rid in rids:
    print(f"  req {rid}: {ref.tokens_for(rid)} "
          f"[{ref.terminal_for(rid).state.value}]")
assert ref.failovers == 0

for failover in ("migrate", "standby"):
    group, rids = run_group(failover, kill_step=6)
    idx, why, at = group.deaths[0]
    c = group.counters()
    print(f"\n--- {failover}: replica {idx} killed ({why}) at engine "
          f"step {at} ---")
    print(f"  failovers={c['failovers']} "
          f"migrated={c['migrated_requests']} "
          f"dup_suppressed={c['duplicates_suppressed']} "
          f"internal_errors={c['internal_errors']} "
          f"health={c['health']}")
    for rid in rids:
        toks = group.tokens_for(rid)
        same = "identical" if toks == ref.tokens_for(rid) else "DIFFERS"
        print(f"  req {rid} (owner → replica {group.owner[rid]}): "
              f"{toks} [{group.terminal_for(rid).state.value}] {same}")
        assert toks == ref.tokens_for(rid)
        assert group.terminal_for(rid) is not None
    assert len(group.terminals) == len(rids)    # exactly one terminal each
    assert group.internal_errors == 0
    for rep in group.replicas:
        if rep.alive:                           # pools drain to baseline
            assert rep.engine.cache.pages_free == ECFG.num_pages

print("\nevery stream identical across both failover policies — the "
      "kill cost throughput, never correctness")
