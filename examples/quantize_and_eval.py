"""FMPQ calibration walkthrough: collect activation statistics, build
per-layer plans (outlier channel permutation), inspect the INT4 block
fraction, and compare quantized-vs-fp logits.

    PYTHONPATH=src python examples/quantize_and_eval.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fmpq
from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig

cfg = get_smoke_config("llama3_8b")
lm = LM(cfg)
params, axes = lm.init(jax.random.PRNGKey(0))

# --- calibration on synthetic outlier-heavy activations (Fig. 3 regime)
rng = np.random.default_rng(0)
acts = rng.normal(size=(2048, 1024)).astype(np.float32)
acts[:, rng.choice(1024, 24, replace=False)] *= 50.0

stats = fmpq.collect_channel_stats(jnp.asarray(acts))
plan = fmpq.plan_fmpq(np.asarray(stats))
print(f"FMPQ plan: {plan.num_blocks} blocks, "
      f"{plan.num_int4_blocks} INT4 ({100*plan.int4_fraction:.1f}% W4A4), "
      f"{plan.num_blocks - plan.num_int4_blocks} INT8 tail blocks")

# without permutation the same outliers would poison many blocks:
mask = fmpq.identify_outlier_channels(np.asarray(stats))
unpermuted_int8 = int(mask.reshape(-1, 128).any(1).sum())
print(f"without channel permutation: {unpermuted_int8} INT8 blocks "
      f"(vs {plan.num_blocks - plan.num_int4_blocks} with)")

# --- end-to-end: quantize the model and compare logits
quant = QuantConfig(int4_fraction=plan.int4_fraction, impl="ref")
lmq = LM(cfg, quant=quant)
qparams, _ = lmq.quantize(params, axes)
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                            cfg.vocab_size)
lg_fp, _ = jax.jit(lm.train_logits)(params, tokens)
lg_q, _ = jax.jit(lmq.train_logits)(qparams, tokens)
corr = np.corrcoef(np.asarray(lg_fp).ravel(), np.asarray(lg_q).ravel())[0, 1]
print(f"fp vs W4AxKV4 logit correlation: {corr:.4f}")
