"""Quickstart: build a model, PTQ-quantize it (FMPQ W4AxKV4), generate.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig

# 1. a small llama-family model (any of the 12 archs works: --arch ids)
cfg = get_smoke_config("llama3_8b")
lm_fp = LM(cfg)
params, axes = lm_fp.init(jax.random.PRNGKey(0))
print(f"model: {cfg.name}  params ≈ {cfg.param_count()/1e6:.1f}M")

# 2. offline PTQ: pack weights to int4, 87.5 % of activation blocks INT4
quant = QuantConfig(int4_fraction=0.875, impl="auto", kv4=True)
lm = LM(cfg, quant=quant)
qparams, _ = lm.quantize(params, axes)

# 3. serve: prefill a prompt, then decode greedily over the int4 KV cache
prompt = jnp.asarray([[1, 42, 7, 99, 5]], jnp.int32)
cache = lm.init_cache(batch=1, max_len=64)
logits, cache = jax.jit(lm.prefill)(qparams, prompt, cache)
tokens = [int(jnp.argmax(logits[0, -1]))]
decode = jax.jit(lm.decode)
for _ in range(10):
    logits, cache = decode(
        qparams, jnp.asarray([[tokens[-1]]], jnp.int32), cache)
    tokens.append(int(jnp.argmax(logits[0, -1])))
print("generated:", tokens)
