"""End-to-end driver for the request-lifecycle serving API: submit
requests with per-request SamplingParams through the full COMET stack
(FMPQ quantization, refcounted paged int4 KV cache with prefix reuse,
continuous batching), stream tokens as they are sampled, abort one
request mid-flight, survive an injected mid-step fault, expire a
deadline, bounce a request off the bounded waiting queue, and
crash/restore — both the legacy scheduler snapshot and the journaled
full-state recovery with bit-identical continuation.

    PYTHONPATH=src python examples/serve_batched.py

The same engine runs tensor-parallel by passing a mesh: build one with
``repro.launch.mesh.make_local_mesh(1, m)`` and construct
``Engine(..., mesh=mesh, param_axes=qaxes)`` (the axes tree
``LM.quantize`` returns alongside qparams) — greedy output is
unchanged. The serve CLI exposes this as ``--mesh 1xm``; try it on CPU
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8
PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --smoke
--mesh 1x2 --head-dim 64 --int4-fraction 1.0``.
"""
import time

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig, SamplingParams
from repro.serving.faults import Fault, FaultInjector
from repro.serving.recovery import RecoveryLog

cfg = get_smoke_config("llama3_8b")
quant = QuantConfig(int4_fraction=0.875, impl="ref")
params, axes = LM(cfg).init(jax.random.PRNGKey(0))
qparams, _ = LM(cfg, quant=quant).quantize(params, axes)

engine = Engine(cfg, qparams, quant, EngineConfig(
    max_batch=8, num_pages=128, page_size=16))

# a shared system prompt: after the first request publishes its pages,
# later arrivals reuse them (watch prefix_hit_tokens in the summary)
rng = np.random.default_rng(0)
system_prompt = rng.integers(0, cfg.vocab_size, 32).tolist()

# stream the first request token-by-token (stream() drives step())
h0 = engine.submit(
    system_prompt + rng.integers(0, cfg.vocab_size, 5).tolist(),
    SamplingParams(max_new_tokens=12))
print("streaming request 0:", end="", flush=True)
for ev in engine.stream(h0):
    if ev.token is not None:
        print(f" {ev.token}", end="", flush=True)
print(f"  [{engine.result(h0).state.value}]")

# a batch of followers sharing the (now published) system prompt, one
# of them sampled at temperature, one aborted mid-decode
t0 = time.time()
handles = [engine.submit(
    system_prompt + rng.integers(0, cfg.vocab_size, int(n)).tolist(),
    SamplingParams(max_new_tokens=12,
                   temperature=0.8 if i == 2 else 0.0, top_k=8))
    for i, n in enumerate(rng.integers(4, 16, 5))]
victim = handles[3]
while engine.sched.has_work:
    engine.step()
    for ev in engine.events():
        if ev.request_id == victim.request_id and ev.num_generated >= 3:
            engine.abort(victim)
dt = time.time() - t0

finished = engine.sched.finished
tokens = sum(len(r.generated) for r in finished)
hit = engine.prefix_hit_tokens
total = hit + engine.prefill_tokens
print(f"{len(finished)} requests, {tokens} tokens in {dt:.1f}s "
      f"→ {tokens/dt:.1f} tok/s "
      f"(steps={engine.steps}, forwards={engine.forward_calls}, "
      f"prefix hit rate {hit}/{total} prompt tokens, "
      f"aborted={engine.aborted_count})")
for r in sorted(finished, key=lambda r: r.request_id):
    print(f"  req {r.request_id:2d} [{r.state.value:9s}]: {r.generated}")
assert engine.result(victim).state.value == "aborted"
assert engine.cache.pages_free == 128      # abort/finish freed every page

# fault tolerance: snapshot → "crash" → restore → keep serving
engine.submit([1, 2, 3], SamplingParams(max_new_tokens=4), request_id=100)
blob = engine.snapshot()
engine2 = Engine.restore(blob, cfg, qparams, quant, EngineConfig(
    max_batch=8, num_pages=128, page_size=16))
done = engine2.run()
print(f"after restore: completed request {done[-1].request_id} "
      f"→ {done[-1].generated}")

# --- the fault-tolerant serving core ---------------------------------

# step-level failure isolation: NaN the logits at step 2 — the affected
# request fails terminally (pages freed exactly), step() never raises,
# and other requests keep decoding
ecfg = EngineConfig(max_batch=8, num_pages=128, page_size=16,
                    max_waiting=2)
eng3 = Engine(cfg, qparams, quant, ecfg,
              faults=FaultInjector([Fault("forward", step=2,
                                          action="nan")]))
hs = [eng3.submit(rng.integers(0, cfg.vocab_size, 8).tolist(),
                  SamplingParams(max_new_tokens=6)) for _ in range(3)]
eng3.run()
states = sorted(eng3.result(h).state.value for h in hs)
print(f"after injected NaN: states={states} "
      f"(failed={eng3.failed_count}), pages_free="
      f"{eng3.cache.pages_free}/128, step() raised: never "
      f"(internal_errors={eng3.internal_errors})")
assert eng3.cache.pages_free == 128

# deadlines + backpressure: a request with a 1ms deadline expires to
# TIMED_OUT; submits past max_waiting=2 are rejected (queue_full)
hd = eng3.submit(rng.integers(0, cfg.vocab_size, 8).tolist(),
                 SamplingParams(max_new_tokens=6, deadline_ms=0.001))
time.sleep(0.01)
overflow = [eng3.submit([5, 6, 7], SamplingParams(max_new_tokens=2))
            for _ in range(4)]
eng3.run()
print(f"deadline: req {hd.request_id} → "
      f"{eng3.result(hd).state.value} ({eng3.result(hd).stop_reason}); "
      f"rejected={eng3.rejected_count} of {len(overflow)} overflow "
      f"submits (timed_out={eng3.timeout_count})")
assert eng3.result(hd).state.value == "timed_out"
assert eng3.rejected_count >= 1

# journaled crash recovery: run under a RecoveryLog, "kill" the engine
# mid-decode, resume from the last full snapshot + journal — the
# continuation is bitwise greedy-identical and nothing is redelivered
eng4 = Engine(cfg, qparams, quant, ecfg)
log = RecoveryLog(eng4, snapshot_every=4)
h4 = eng4.submit(rng.integers(0, cfg.vocab_size, 12).tolist(),
                 SamplingParams(max_new_tokens=10))
for _ in range(6):          # partial run, then the "crash"
    log.step()
log2 = RecoveryLog.resume(log.snapshot_blob, log.journal,
                          cfg, qparams, quant, ecfg, snapshot_every=4)
log2.run()
done4 = [r for r in log2.engine.sched.finished
         if r.request_id == h4.request_id][0]
print(f"recovery: {log2.replayed} replayed events verified bitwise, "
      f"tokens={done4.generated} [{done4.state.value}] "
      f"(journal compacted {log2.compacted_total} dead entries at "
      f"checkpoints, {len(log2.journal)} live)")

# availability above one engine — N replicas, health-checked failover,
# exactly-once migration: examples/failover_walkthrough.py
