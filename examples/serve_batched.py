"""End-to-end driver: serve a small model with batched requests through
the full COMET stack — FMPQ quantization, paged int4 KV cache,
continuous batching with admission control and preemption.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig

cfg = get_smoke_config("llama3_8b")
quant = QuantConfig(int4_fraction=0.875, impl="ref")
params, axes = LM(cfg).init(jax.random.PRNGKey(0))
qparams, _ = LM(cfg, quant=quant).quantize(params, axes)

engine = Engine(cfg, qparams, quant, EngineConfig(
    max_batch=8, num_pages=128, page_size=16))

rng = np.random.default_rng(0)
n_requests, max_new = 12, 12
for i in range(n_requests):
    plen = int(rng.integers(4, 24))
    engine.add_request(i, rng.integers(0, cfg.vocab_size, plen).tolist(),
                       max_new)

t0 = time.time()
finished = engine.run()
dt = time.time() - t0
tokens = sum(len(r.generated) for r in finished)
print(f"{len(finished)} requests, {tokens} tokens in {dt:.1f}s "
      f"→ {tokens/dt:.1f} tok/s "
      f"(engine steps={engine.steps}, forwards={engine.forward_calls}, "
      f"traces={engine.trace_count}, preemptions={engine.sched.preemptions})")
for r in sorted(finished, key=lambda r: r.request_id)[:5]:
    print(f"  req {r.request_id:2d}: {r.generated}")

# fault tolerance: snapshot → "crash" → restore → keep serving
engine.add_request(100, [1, 2, 3], 4)
blob = engine.snapshot()
engine2 = Engine.restore(blob, cfg, qparams, quant, EngineConfig(
    max_batch=8, num_pages=128, page_size=16))
done = engine2.run()
print(f"after restore: completed request {done[-1].request_id} "
      f"→ {done[-1].generated}")
