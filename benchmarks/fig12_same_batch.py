"""Fig. 12: same-batch decode speedup, COMET vs best TRT-LLM config
(LLaMA-3-8B, in/out 1024/512), derived from the v5e decode roofline.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.fig11_e2e_throughput import CONFIGS, decode_step_time
from repro.configs.base import get_config


def run(verbose=True):
    cfg = get_config("llama3_8b")
    ctx = 1024 + 512
    rows = []
    for batch in (4, 16, 64, 128, 256):
        times = {name: decode_step_time(cfg, batch, ctx, *bits)
                 for name, bits in CONFIGS.items()}
        best_base = min(times["W16A16"], times["W8A8"], times["W4A16"])
        speed = best_base / times["W4AxKV4"]
        rows.append((batch, speed))
        if verbose:
            print(f"batch {batch:4d}: COMET {speed:5.2f}× vs best baseline "
                  f"({min(CONFIGS, key=lambda k: times[k])} fastest baseline)")
    return rows


def main():
    t0 = time.time()
    print("\n== Fig. 12 proxy: same-batch speedup, LLaMA-3-8B ==")
    rows = run()
    dt = time.time() - t0
    mean = float(np.mean([s for _, s in rows]))
    print(f"(paper: 1.37× mean over best TRT-LLM config)")
    print(f"fig12_same_batch,{dt*1e6:.0f},mean_speedup={mean:.2f}x;"
          f"ge_1={all(s >= 1.0 for _, s in rows)}")


if __name__ == "__main__":
    main()
