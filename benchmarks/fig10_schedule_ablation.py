"""Fig. 10: kernel-schedule ablation — naive mixed → tile remapping →
tile decomposition (COMET §4.4), adapted to the TPU static schedule.

On GPU the paper measures SM idle time; on TPU the analogue is the
static grid schedule's core-time. We model a 2-core (megacore) chip and
compute total kernel time under each schedule given per-tile costs from
the v5e roofline (INT8-MXU tile = 1 unit, INT4-path tile = 0.5 units of
*memory* time since int4 halves the bytes; MXU time equal):

  naive      per-K-step barrier: every step costs max(t4, t8) when the
             two cores hold different-precision tiles (Fig. 8b);
  remapped   like-precision tiles grouped per wave (Fig. 8d): cores run
             uniform waves, but the tail wave may underfill cores;
  decomposed split-GEMM / Stream-K one-to-many binding (Fig. 8e): work
             is a divisible pool — perfect balance up to the last tile.

We also measure the *compiled* analogue: HLO op counts of the mixed
single-kernel (branchy) vs split-schedule lowering of the same W4Ax
GEMM, plus interpret-mode correctness of both.

**Measured ragged-imbalance ablation** (the part that is no longer just
a model): the real serving engine on a ragged workload with one
dominant long-context row, run under ``attention_schedule="dense"``
(the padded ``(B·Hkv, max_npages)`` paged-attention grid) vs
``"work_queue"`` (flat Stream-K descriptors over real pages + split-KV
combine — Fig. 8e's tile decomposition applied to paged attention).
Asserted via engine COUNTERS, not wall-clock (the CPU-smoke lesson:
per-shape retrace noise swamps timing in CI): both schedules do the
same real work (``attn_work_items``), the work-queue grid launches
strictly fewer items than the dense rectangle, its padding waste
(grid − work, just pow-2 bucketing) is strictly below dense's
rectangle waste, and greedy output is token-identical. ``--smoke``
runs only this part for CI; wall-clock tok/s is reported off-CI.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantizer as Q
from repro.kernels import ops
from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig


def modeled_schedule_times(n_tiles4: int, n_tiles8: int, n_cores: int = 2):
    """Abstract tile-time model: t8 = 1.0, t4 = 0.5 (bytes-bound)."""
    t4, t8 = 0.5, 1.0
    # naive: tiles interleaved (4,8,4,8,…) with a barrier each wave
    tiles = []
    a, b = n_tiles4, n_tiles8
    while a or b:
        if b:
            tiles.append(t8)
            b -= 1
        if a:
            tiles.append(t4)
            a -= 1
    naive = 0.0
    for i in range(0, len(tiles), n_cores):
        naive += max(tiles[i:i + n_cores])
    # remapped: LPT (longest-processing-time) static balance of whole
    # tiles across cores, single final barrier (Fig. 8d)
    loads = [0.0] * n_cores
    for tt in sorted([t8] * n_tiles8 + [t4] * n_tiles4, reverse=True):
        loads[loads.index(min(loads))] += tt
    remap = max(loads)
    # decomposed: perfectly divisible pool
    decomp = (n_tiles4 * t4 + n_tiles8 * t8) / n_cores
    return naive, remap, decomp


def compiled_op_counts(m=128, k4=256, k8=128, n=128):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(m, k4 + k8)).astype(np.float32)
    w = (rng.normal(size=(k4 + k8, n)) * 0.05).astype(np.float32)
    q4, s4 = Q.quantize_act_groupwise(jnp.asarray(x[:, :k4]), 128, bits=4)
    a4 = Q.pack_int4_interleaved(q4, axis=1, block_size=128)
    a8, s8 = Q.quantize_act_groupwise(jnp.asarray(x[:, k4:]), 128, bits=8)
    wq = Q.quantize_weight_int4(jnp.asarray(w), group_size=128)

    outs = {}
    hlos = {}
    for sched in ("mixed", "split"):
        fn = lambda *args: ops.w4ax_matmul(*args, schedule=sched,
                                           impl="pallas")
        lowered = jax.jit(fn).lower(a4, s4, a8, s8, wq.data, wq.scale)
        hlos[sched] = lowered.as_text()
        outs[sched] = np.asarray(fn(a4, s4, a8, s8, wq.data, wq.scale))
    np.testing.assert_allclose(outs["mixed"], outs["split"],
                               rtol=1e-5, atol=1e-4)
    counts = {s: {"conditionals": h.count("cond("),
                  "while_ops": h.count("while("),
                  "hlo_lines": len(h.splitlines())}
              for s, h in hlos.items()}
    return counts


def measured_ragged_imbalance(verbose=True):
    """Dense vs work-queue paged attention on the real engine: a ragged
    mix where one long-context row dominates (the Fig. 8 imbalance).
    Weight-only + calibrated kv_range keeps greedy output identical
    across schedules (the parity regime), so the schedule win is pure
    grid accounting: work items vs launched grid items."""
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    rng = np.random.default_rng(1)      # pinned: healthy argmax margins
    lens = (96, 6, 9, 5, 12, 7)         # one dominant row + short tail
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist() for n in lens]
    results = {}
    for sched in ("dense", "work_queue"):
        eng = Engine(cfg, qparams, qc, EngineConfig(
            max_batch=6, num_pages=128, page_size=8, max_pages_per_seq=32,
            prefill_chunk_tokens=24, kv_range=4.0,
            attention_schedule=sched))
        for i, p in enumerate(prompts):
            eng.add_request(i, p, 16)
        t0 = time.time()
        eng.run(max_steps=400)
        dt = time.time() - t0
        results[sched] = {
            "tokens": {r.request_id: list(r.generated)
                       for r in eng.sched.finished},
            "work": eng.attn_work_items,
            "grid": eng.attn_grid_items,
            "dense_grid": eng.attn_dense_grid_items,
            "forwards": eng.attn_forwards,
            "waste": eng.attn_grid_items - eng.attn_work_items,
            "tok_s": eng.tokens_generated / dt,
            "steps": eng.steps,
            "traces": eng.trace_count,
        }
        if verbose:
            r = results[sched]
            print(f"schedule {sched:10s}: work={r['work']:5d} "
                  f"grid={r['grid']:5d} waste={r['waste']:5d} "
                  f"({r['forwards']} attn forwards, "
                  f"{r['tok_s']:6.1f} tok/s off-CI, "
                  f"traces={r['traces']})")
    if verbose:
        dn, wq = results["dense"], results["work_queue"]
        print(f"work-queue grid is {dn['grid']/wq['grid']:.2f}× smaller; "
              f"padding waste {dn['waste']} → {wq['waste']} "
              f"({dn['waste']/max(wq['waste'],1):.1f}×); "
              f"greedy-identical={dn['tokens'] == wq['tokens']}")
    return results


def run():
    print("\n== Fig. 10 proxy: schedule ablation (modeled 2-core time) ==")
    print(f"{'tiles(4,8)':>12s} {'naive':>8s} {'remap':>8s} {'decomp':>8s} "
          f"{'remap×':>7s} {'decomp×':>8s}")
    speed_remap, speed_dec = [], []
    for n4, n8 in [(14, 2), (28, 4), (7, 1), (12, 6), (56, 8)]:
        naive, remap, dec = modeled_schedule_times(n4, n8)
        print(f"  ({n4:3d},{n8:3d})  {naive:8.2f} {remap:8.2f} {dec:8.2f}"
              f" {naive/remap:6.2f}× {naive/dec:7.2f}×")
        speed_remap.append(naive / remap)
        speed_dec.append(naive / dec)
    counts = compiled_op_counts()
    print(f"compiled mixed-kernel HLO: {counts['mixed']}")
    print(f"compiled split-schedule HLO: {counts['split']}")
    return float(np.mean(speed_remap)), float(np.mean(speed_dec)), counts


def main(smoke: bool = False):
    t0 = time.time()
    if smoke:
        print("== fig10 --smoke: measured ragged-imbalance ablation "
              "(dense vs work-queue paged attention, tiny model, CPU) ==")
        res = measured_ragged_imbalance()
        dn, wq = res["dense"], res["work_queue"]
        dt = time.time() - t0
        # counters, not wall-clock: identical output and real work,
        # strictly smaller launched grid, strictly less padding waste,
        # and the work-queue grid within its pow-2 bucketing bound
        assert wq["tokens"] == dn["tokens"], (
            "work-queue schedule changed greedy output")
        assert wq["work"] == dn["work"], (
            "schedules must do the same real attention work")
        assert wq["grid"] < dn["grid"], (
            "work-queue grid must launch strictly fewer items than the "
            "dense (B·Hkv)·(max_npages+1) rectangle")
        assert wq["waste"] < dn["waste"], (
            "work-queue padding waste must undercut dense padding waste")
        assert dn["grid"] == dn["dense_grid"], (
            "dense launches exactly its rectangle")
        # grid = Σ per-forward pow-2 buckets: < 2×work + the min-8 floor
        assert wq["work"] <= wq["grid"] < 2 * wq["work"] + 8 * wq["forwards"], (
            "work-queue grid must be the bucketed work count")
        print(f"fig10_schedule_ablation,{dt*1e6:.0f},"
              f"work_items={wq['work']};"
              f"grid_wq={wq['grid']}vs_dense={dn['grid']};"
              f"waste_wq={wq['waste']}vs_dense={dn['waste']};"
              f"greedy_identical={wq['tokens'] == dn['tokens']}")
        return
    remap_x, dec_x, counts = run()
    print("\n== measured ragged-imbalance ablation (tiny model, CPU) ==")
    measured_ragged_imbalance()
    dt = time.time() - t0
    mono = 1.0 <= remap_x <= dec_x
    print(f"(paper Fig. 10: naive→remap ≈1.2×, naive→full ≈1.3×, "
          f"W4A8→full 1.71×/1.67×)")
    print(f"fig10_schedule_ablation,{dt*1e6:.0f},remap={remap_x:.2f}x;"
          f"decomp={dec_x:.2f}x;monotone={mono};"
          f"split_branchfree={counts['split']['conditionals'] <= counts['mixed']['conditionals']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI: only the measured ragged-imbalance part — "
                         "dense vs work-queue schedule counters (no "
                         "wall-clock asserts)")
    main(smoke=ap.parse_args().smoke)
