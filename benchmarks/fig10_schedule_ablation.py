"""Fig. 10: kernel-schedule ablation — naive mixed → tile remapping →
tile decomposition (COMET §4.4), adapted to the TPU static schedule.

On GPU the paper measures SM idle time; on TPU the analogue is the
static grid schedule's core-time. We model a 2-core (megacore) chip and
compute total kernel time under each schedule given per-tile costs from
the v5e roofline (INT8-MXU tile = 1 unit, INT4-path tile = 0.5 units of
*memory* time since int4 halves the bytes; MXU time equal):

  naive      per-K-step barrier: every step costs max(t4, t8) when the
             two cores hold different-precision tiles (Fig. 8b);
  remapped   like-precision tiles grouped per wave (Fig. 8d): cores run
             uniform waves, but the tail wave may underfill cores;
  decomposed split-GEMM / Stream-K one-to-many binding (Fig. 8e): work
             is a divisible pool — perfect balance up to the last tile.

We also measure the *compiled* analogue: HLO op counts of the mixed
single-kernel (branchy) vs split-schedule lowering of the same W4Ax
GEMM, plus interpret-mode correctness of both.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantizer as Q
from repro.kernels import ops


def modeled_schedule_times(n_tiles4: int, n_tiles8: int, n_cores: int = 2):
    """Abstract tile-time model: t8 = 1.0, t4 = 0.5 (bytes-bound)."""
    t4, t8 = 0.5, 1.0
    # naive: tiles interleaved (4,8,4,8,…) with a barrier each wave
    tiles = []
    a, b = n_tiles4, n_tiles8
    while a or b:
        if b:
            tiles.append(t8)
            b -= 1
        if a:
            tiles.append(t4)
            a -= 1
    naive = 0.0
    for i in range(0, len(tiles), n_cores):
        naive += max(tiles[i:i + n_cores])
    # remapped: LPT (longest-processing-time) static balance of whole
    # tiles across cores, single final barrier (Fig. 8d)
    loads = [0.0] * n_cores
    for tt in sorted([t8] * n_tiles8 + [t4] * n_tiles4, reverse=True):
        loads[loads.index(min(loads))] += tt
    remap = max(loads)
    # decomposed: perfectly divisible pool
    decomp = (n_tiles4 * t4 + n_tiles8 * t8) / n_cores
    return naive, remap, decomp


def compiled_op_counts(m=128, k4=256, k8=128, n=128):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(m, k4 + k8)).astype(np.float32)
    w = (rng.normal(size=(k4 + k8, n)) * 0.05).astype(np.float32)
    q4, s4 = Q.quantize_act_groupwise(jnp.asarray(x[:, :k4]), 128, bits=4)
    a4 = Q.pack_int4_interleaved(q4, axis=1, block_size=128)
    a8, s8 = Q.quantize_act_groupwise(jnp.asarray(x[:, k4:]), 128, bits=8)
    wq = Q.quantize_weight_int4(jnp.asarray(w), group_size=128)

    outs = {}
    hlos = {}
    for sched in ("mixed", "split"):
        fn = lambda *args: ops.w4ax_matmul(*args, schedule=sched,
                                           impl="pallas")
        lowered = jax.jit(fn).lower(a4, s4, a8, s8, wq.data, wq.scale)
        hlos[sched] = lowered.as_text()
        outs[sched] = np.asarray(fn(a4, s4, a8, s8, wq.data, wq.scale))
    np.testing.assert_allclose(outs["mixed"], outs["split"],
                               rtol=1e-5, atol=1e-4)
    counts = {s: {"conditionals": h.count("cond("),
                  "while_ops": h.count("while("),
                  "hlo_lines": len(h.splitlines())}
              for s, h in hlos.items()}
    return counts


def run():
    print("\n== Fig. 10 proxy: schedule ablation (modeled 2-core time) ==")
    print(f"{'tiles(4,8)':>12s} {'naive':>8s} {'remap':>8s} {'decomp':>8s} "
          f"{'remap×':>7s} {'decomp×':>8s}")
    speed_remap, speed_dec = [], []
    for n4, n8 in [(14, 2), (28, 4), (7, 1), (12, 6), (56, 8)]:
        naive, remap, dec = modeled_schedule_times(n4, n8)
        print(f"  ({n4:3d},{n8:3d})  {naive:8.2f} {remap:8.2f} {dec:8.2f}"
              f" {naive/remap:6.2f}× {naive/dec:7.2f}×")
        speed_remap.append(naive / remap)
        speed_dec.append(naive / dec)
    counts = compiled_op_counts()
    print(f"compiled mixed-kernel HLO: {counts['mixed']}")
    print(f"compiled split-schedule HLO: {counts['split']}")
    return float(np.mean(speed_remap)), float(np.mean(speed_dec)), counts


def main():
    t0 = time.time()
    remap_x, dec_x, counts = run()
    dt = time.time() - t0
    mono = 1.0 <= remap_x <= dec_x
    print(f"(paper Fig. 10: naive→remap ≈1.2×, naive→full ≈1.3×, "
          f"W4A8→full 1.71×/1.67×)")
    print(f"fig10_schedule_ablation,{dt*1e6:.0f},remap={remap_x:.2f}x;"
          f"decomp={dec_x:.2f}x;monotone={mono};"
          f"split_branchfree={counts['split']['conditionals'] <= counts['mixed']['conditionals']}")


if __name__ == "__main__":
    main()
