"""Benchmark harness entry point — one benchmark per paper table/figure.

Each module prints its human-readable table followed by a machine line
``name,us_per_call,derived``. This runner executes them all and also
emits the roofline summary if dry-run records exist.

  PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks import (conversion_ablation, fig9_kernel_bench,
                            fig10_schedule_ablation, fig11_e2e_throughput,
                            fig12_same_batch, fmpq_ratio,
                            table1_quant_error)

    benches = [
        ("table1_quant_error", table1_quant_error.main),
        ("fmpq_ratio", fmpq_ratio.main),
        ("fig9_kernel_bench", fig9_kernel_bench.main),
        ("fig10_schedule_ablation", fig10_schedule_ablation.main),
        ("fig11_e2e_throughput", fig11_e2e_throughput.main),
        ("fig12_same_batch", fig12_same_batch.main),
        ("conversion_ablation", conversion_ablation.main),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,FAILED")
            traceback.print_exc()

    # roofline summary from dry-run records, if present
    dr = os.path.join(os.path.dirname(__file__), "..",
                      "experiments", "dryrun")
    if os.path.isdir(dr) and any(f.endswith(".json") for f in os.listdir(dr)):
        from benchmarks import roofline
        print("\n== §Roofline summary (single-pod 16x16, split schedule) ==")
        rows = [roofline.analyze_record(r)
                for r in roofline.load_records(dr, "16x16")]
        rows.sort(key=lambda r: (r["arch"], r["shape"]))
        roofline.print_table(rows)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
