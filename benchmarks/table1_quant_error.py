"""Table 1 proxy: quantization quality across precision configs.

The paper reports WikiText-2 perplexity of quantized LLaMA models. Two
offline reproductions of that table's *structure*:

(a) end-to-end: a trained tiny LM (d_model 256) evaluated teacher-forced
    through the real prefill+decode serving path (so KV4 is actually
    exercised) under FP16 / W4A16 / W4A8 / FMPQ-W4Ax / naive-W4A4, with
    and without the int4 KV cache.

(b) layer-level, outlier regime: LLM activations have outlier channels
    (paper Fig. 3) that a tiny synthetic-data LM cannot develop, so the
    FMPQ-vs-naive separation is measured directly on outlier-heavy
    activations: per-GEMM relative error for naive W4A4 vs FMPQ (plan
    with channel permutation) vs W4A8 — the paper's central accuracy
    mechanism.

Expected: (a) FMPQ ≈ W4A16/W4A8, KV4 delta ≈ 0; (b) FMPQ ≪ naive W4A4.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core import fmpq
from repro.core import quantizer as Q
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.lm import LM, QuantConfig
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step


def wide_cfg():
    base = get_smoke_config("llama3_8b")
    return dataclasses.replace(
        base, d_model=256, num_heads=8, num_kv_heads=4, head_dim=32,
        d_ff=512)


def train_tiny(cfg, steps=60, seed=0):
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(seed))
    opt = OPT.adamw_init(params)
    step = jax.jit(make_train_step(lm, OPT.AdamWConfig(lr=2e-3)))
    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=seed))
    loss = None
    for i in range(steps):
        params, opt, m = step(params, opt, data.batch_for_step(i))
    return lm, params, axes, data, float(m["loss"])


def decode_ce(lm, params, data, prompt_len=16, gen_len=32, batches=2):
    """Teacher-forced CE through the real prefill+decode serving path."""
    tot, cnt = 0.0, 0
    prefill = jax.jit(lm.prefill)
    decode = jax.jit(lm.decode)
    for bi in range(batches):
        b = data.batch_for_step(2000 + bi)
        toks = b["tokens"][:4, : prompt_len + gen_len]
        cache = lm.init_cache(4, prompt_len + gen_len + 4)
        lg, cache = prefill(params, toks[:, :prompt_len], cache)
        logp = jax.nn.log_softmax(lg[:, 0])
        tot -= float(jnp.take_along_axis(
            logp, toks[:, prompt_len][:, None], 1).sum())
        cnt += toks.shape[0]
        for t in range(prompt_len, prompt_len + gen_len - 1):
            lg, cache = decode(params, toks[:, t:t + 1], cache)
            logp = jax.nn.log_softmax(lg[:, 0])
            tot -= float(jnp.take_along_axis(
                logp, toks[:, t + 1][:, None], 1).sum())
            cnt += toks.shape[0]
    return tot / cnt


def part_a():
    cfg = wide_cfg()
    lm_fp, params, axes, data, train_loss = train_tiny(cfg)
    rows = [("FP16", decode_ce(lm_fp, params, data))]
    configs = [
        ("W4A16", QuantConfig(weight_only=True, impl="ref", kv4=False)),
        ("W4A8-all", QuantConfig(int4_fraction=0.0, impl="ref", kv4=False)),
        ("FMPQ-W4Ax", QuantConfig(int4_fraction=0.5, impl="ref",
                                  kv4=False)),
        ("FMPQ-W4AxKV4", QuantConfig(int4_fraction=0.5, impl="ref",
                                     kv4=True)),
        ("naive-W4A4", QuantConfig(int4_fraction=1.0, impl="ref",
                                   kv4=False)),
        ("naive-W4A4KV4", QuantConfig(int4_fraction=1.0, impl="ref",
                                      kv4=True)),
    ]
    for name, qc in configs:
        lmq = LM(cfg, quant=qc)
        qparams, _ = lmq.quantize(params, axes)
        rows.append((name, decode_ce(lmq, qparams, data)))
    return rows, train_loss


def part_b(trials=6):
    """Layer-level relative GEMM error in the outlier regime (Fig. 3)."""
    rng = np.random.default_rng(0)
    errs = {"naive-W4A4": [], "FMPQ-W4Ax": [], "W4A8-all": []}
    for _ in range(trials):
        m, k, n = 256, 1024, 256
        x = rng.normal(size=(m, k)).astype(np.float32)
        n_out = int(rng.integers(8, 48))
        x[:, rng.choice(k, n_out, replace=False)] *= rng.uniform(20, 80)
        w = (rng.normal(size=(k, n)) * 0.05).astype(np.float32)
        exact = x @ w
        wq = Q.quantize_weight_int4(jnp.asarray(w), group_size=128)
        wd = np.asarray(Q.dequantize_weight_int4(wq, 128))

        def gemm_err(xd):
            out = xd @ wd
            return float(np.median(
                np.abs(out - exact) / (np.abs(exact) + 1e-2)))

        # naive all-int4
        q4, s4 = Q.quantize_act_groupwise(jnp.asarray(x), 128, bits=4)
        xd = np.asarray(q4, np.float32).reshape(m, -1, 128) * \
            np.asarray(s4)[:, :, None]
        errs["naive-W4A4"].append(gemm_err(xd.reshape(m, k)))
        # all-int8
        q8, s8 = Q.quantize_act_groupwise(jnp.asarray(x), 128, bits=8)
        xd8 = np.asarray(q8, np.float32).reshape(m, -1, 128) * \
            np.asarray(s8)[:, :, None]
        errs["W4A8-all"].append(gemm_err(xd8.reshape(m, k)))
        # FMPQ: calibrated plan, permuted weight
        plan = fmpq.plan_fmpq(np.abs(x).max(0))
        cfgq = fmpq.FMPQConfig()
        wqp = fmpq.apply_fmpq_to_weight(jnp.asarray(w), plan, cfgq)
        wdp = np.asarray(Q.dequantize_weight_int4(wqp, 128))
        aq, asc = fmpq.quantize_activation_mixed(jnp.asarray(x), plan, cfgq)
        ad = np.asarray(aq, np.float32).reshape(m, -1, 128) * \
            np.asarray(asc)[:, :, None]
        out = ad.reshape(m, k) @ wdp
        errs["FMPQ-W4Ax"].append(float(np.median(
            np.abs(out - exact) / (np.abs(exact) + 1e-2))))
    return {k: float(np.mean(v)) for k, v in errs.items()}


def main():
    t0 = time.time()
    rows, train_loss = part_a()
    d = dict(rows)
    ce_fp = d["FP16"]
    print(f"\n== Table 1 proxy (a): serving-path teacher-forced CE "
          f"(train loss {train_loss:.3f}) ==")
    print(f"{'config':16s} {'eval CE':>8s} {'ppl':>9s} {'ΔCE':>8s}")
    for name, ce in rows:
        print(f"{name:16s} {ce:8.4f} {np.exp(ce):9.2f} {ce - ce_fp:+8.4f}")

    errs = part_b()
    print("\n== Table 1 proxy (b): layer-level GEMM rel. error, "
          "outlier regime ==")
    for name, e in errs.items():
        print(f"{name:16s} median rel err {e:.4f}")

    dt = time.time() - t0
    kv4_delta = d["FMPQ-W4AxKV4"] - d["FMPQ-W4Ax"]
    fmpq_gap = d["FMPQ-W4Ax"] - ce_fp
    layer_ok = errs["FMPQ-W4Ax"] < 0.75 * errs["naive-W4A4"]
    ce_ok = fmpq_gap < 0.3 and abs(kv4_delta) < 0.1
    print(f"(paper: FMPQ ΔPPL ≈ +0.1–0.3 vs FP16; KV4 adds ≤0.05; "
          f"naive W4A4 ΔPPL > 5)")
    print(f"table1_quant_error,{dt*1e6:.0f},fmpq_dce={fmpq_gap:.4f};"
          f"kv4_delta={kv4_delta:.4f};"
          f"layer_fmpq={errs['FMPQ-W4Ax']:.3f};"
          f"layer_naive={errs['naive-W4A4']:.3f};"
          f"ok={ce_ok and layer_ok}")


if __name__ == "__main__":
    main()
