"""§3.2 claim: ">84 % of GEMM computations use W4A4" after channel
permutation, "<20 % of blocks need 8-bit".

We measure the INT4 block fraction of FMPQ plans built from real
calibration statistics of a trained tiny LM (captured by instrumenting
the linear layer), plus a synthetic LLM-like activation model
(heavy-tailed outlier channels, the Fig. 3 distribution).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fmpq
from repro.configs.base import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.layers import common as C
from repro.models.lm import LM
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step


def collect_linear_stats(lm, params, batch):
    """Eager per-layer forward recording per-linear input channel absmax
    (the lax.scan path traces its body, so calibration uses an unrolled
    layer loop — exactly what the offline calibration pass would do)."""
    from repro.layers import attention as ATT
    from repro.layers import mlp as MLP
    cfg = lm.cfg
    stats = {}

    def record(name, x):
        am = np.asarray(
            jnp.max(jnp.abs(x.reshape(-1, x.shape[-1])), axis=0), np.float64)
        stats[name] = np.maximum(stats.get(name, 0.0), am)

    x = lm._embed(params, batch["tokens"])
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    for li in range(cfg.num_layers):
        bp = jax.tree.map(lambda a: a[li], params["blocks"])
        h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
        record(f"L{li}.qkv_in", h)
        x = x + ATT.attention_train(bp["attn"], cfg, h, positions)
        h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
        record(f"L{li}.ffn_in", h)
        x = x + MLP.mlp_apply(bp["mlp"], h, cfg.mlp_act)
    return stats


def synthetic_llm_activations(rng, n_ch=4096, n_outlier=30, mag=80.0):
    absmax = rng.lognormal(0.0, 0.4, size=n_ch)
    idx = rng.choice(n_ch, n_outlier, replace=False)
    absmax[idx] *= mag
    return absmax


def run():
    t0 = time.time()
    rows = []

    # (a) trained tiny LM calibration
    cfg = get_smoke_config("llama3_8b")
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    opt = OPT.adamw_init(params)
    step = jax.jit(make_train_step(lm, OPT.AdamWConfig(lr=2e-3)))
    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=8))
    for i in range(30):
        params, opt, _ = step(params, opt, data.batch_for_step(i))
    stats = collect_linear_stats(lm, params, data.batch_for_step(500))
    for name, absmax in stats.items():
        if absmax.shape[0] % 128:
            continue
        plan = fmpq.plan_fmpq(absmax)
        rows.append((f"tinyLM.{name}", plan.int4_fraction))

    # (b) synthetic LLaMA-like activations (Fig. 3 regime), many trials
    rng = np.random.default_rng(0)
    for trial in range(8):
        absmax = synthetic_llm_activations(
            rng, n_outlier=int(rng.integers(8, 64)))
        plan = fmpq.plan_fmpq(absmax)
        unperm = fmpq.identify_outlier_channels(absmax).reshape(
            -1, 128).any(1).mean()
        rows.append((f"llm-like-{trial}", plan.int4_fraction))
        rows.append((f"llm-like-{trial}-unpermuted", 1.0 - float(unperm)))

    dt = time.time() - t0
    return rows, dt


def main():
    rows, dt = run()
    print("\n== FMPQ INT4-block fraction (paper: ≥84 % W4A4) ==")
    for name, frac in rows:
        print(f"{name:32s} int4_fraction={frac:.3f}")
    llm_like = [f for n, f in rows
                if n.startswith("llm-like") and "unperm" not in n]
    mean_frac = float(np.mean(llm_like))
    print(f"fmpq_ratio,{dt*1e6:.0f},mean_llm_like_int4={mean_frac:.3f};"
          f"paper_claim=0.84;ok={mean_frac >= 0.84}")


if __name__ == "__main__":
    main()
