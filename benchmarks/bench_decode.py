"""Decode-throughput bench: speculative multi-token decode off vs on.

Measures the unified engine's decode tokens/s on a repetitive,
decode-heavy workload (the prompt-lookup draft source's favorable
regime) with ``SamplingParams.speculation`` 0 vs K, and reports the
structural counters behind the wall-clock number: forwards per step,
draft/accept/rollback counts, acceptance rate, and compiled-trace
counts.

Methodology — warmed pass. On the CPU smoke model, jit compilation
dominates any first run, so each arm replays the workload on the SAME
engine until a pass compiles nothing new (the scheduler's round-robin
prefill cursor rotates the chunk split between passes, so the shape
buckets take a few passes to all land in the jit cache;
``prefix_cache=False`` keeps repeat waves from short-circuiting
prefill). The first zero-compile pass is the measurement, so the
ratio is dataflow, not compile noise. Wall-clock on shared CI runners
is still noisy, so ``--smoke`` gates on the STRUCTURAL ratio
(spec-off forwards / spec-on forwards ≥ 1.5 — the machine-independent
speedup bound) plus greedy parity and counter sanity; the measured
tok/s lands in the JSON for the record.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_decode --smoke --json
  # writes BENCH_decode.json next to the repo root
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig, SamplingParams

# highly repetitive prompts: greedy decode on the random smoke model
# falls into short absorbing cycles, so trailing n-grams recur and
# prompt-lookup drafts verify at high acceptance
PROMPTS = [[188] * 8, [139, 133, 188, 188] * 2, [188] * 12, [188] * 10]
OUT_LEN = 24


def _run_pass(eng, base_id: int, k: int) -> dict:
    """Submit the workload and drain it; return the pass's deltas."""
    before = dict(tokens=eng.tokens_generated, steps=eng.steps,
                  forwards=eng.forward_calls, traces=eng.trace_count,
                  drafted=eng.spec_draft_tokens,
                  accepted=eng.spec_accepted_tokens,
                  rollback=eng.spec_rollback_tokens)
    t0 = time.time()
    for i, p in enumerate(PROMPTS):
        eng.submit(p, SamplingParams(max_new_tokens=OUT_LEN,
                                     temperature=0.0, speculation=k),
                   request_id=base_id + i)
    done = eng.run(max_steps=800)
    dt = time.time() - t0
    toks = {r.request_id - base_id: list(r.generated)
            for r in done if r.request_id >= base_id}
    out = {key: getattr(eng, attr) - before[key]
           for key, attr in (("tokens", "tokens_generated"),
                             ("steps", "steps"),
                             ("forwards", "forward_calls"),
                             ("traces", "trace_count"),
                             ("drafted", "spec_draft_tokens"),
                             ("accepted", "spec_accepted_tokens"),
                             ("rollback", "spec_rollback_tokens"))}
    out.update(wall_s=dt, tok_s=out["tokens"] / max(dt, 1e-9),
               tokens_by_req=toks)
    return out


def bench(k: int = 4, verbose: bool = True) -> dict:
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    results = {}
    for spec in (0, k):
        eng = Engine(cfg, qparams, qc, EngineConfig(
            max_batch=8, num_pages=128, page_size=8, max_pages_per_seq=32,
            prefill_chunk_tokens=24, kv_range=4.0, unified_step=True,
            prefix_cache=False, sanitize=True))
        cold = _run_pass(eng, 0, spec)
        # replay until a pass hits the jit cache end to end (the
        # round-robin prefill cursor rotates chunk splits, so a few
        # passes may surface fresh shape buckets) — that pass is warm
        warm, warmups = cold, 0
        while warm["traces"] > 0 and warmups < 8:
            warmups += 1
            warm = _run_pass(eng, 100 * warmups, spec)
        name = f"spec{spec}"
        results[name] = {
            "cold": cold, "warm": warm, "warmup_passes": warmups,
            "trace_count": eng.trace_count,
            "internal_errors": eng.internal_errors,
            "acceptance_rate": (warm["accepted"] / warm["drafted"]
                                if warm["drafted"] else 0.0),
            "forwards_per_step": warm["forwards"] / max(1, warm["steps"]),
        }
        if verbose:
            r = results[name]
            print(f"speculation k={spec}: warm {warm['tok_s']:7.1f} tok/s "
                  f"({warm['tokens']} tok / {warm['wall_s']:.2f}s)  "
                  f"forwards={warm['forwards']:3d}  "
                  f"warmups={warmups} (+{warm['traces']} traces)  "
                  f"acceptance={r['acceptance_rate']:.0%}")
    off, on = results["spec0"], results[f"spec{k}"]
    summary = {
        "k": k,
        "decode_tok_s_off": off["warm"]["tok_s"],
        "decode_tok_s_on": on["warm"]["tok_s"],
        "speedup_tok_s": on["warm"]["tok_s"] / max(off["warm"]["tok_s"],
                                                   1e-9),
        "speedup_forwards": off["warm"]["forwards"]
        / max(1, on["warm"]["forwards"]),
        "acceptance_rate": on["acceptance_rate"],
        "accepted_per_step": on["warm"]["accepted"]
        / max(1, on["warm"]["steps"]),
        "forwards_per_step_on": on["forwards_per_step"],
        "trace_count_off": off["trace_count"],
        "trace_count_on": on["trace_count"],
        "greedy_identical": (
            off["warm"]["tokens_by_req"] == on["warm"]["tokens_by_req"]
            and off["cold"]["tokens_by_req"] == on["warm"]["tokens_by_req"]),
    }
    if verbose:
        print(f"decode speedup: ×{summary['speedup_tok_s']:.2f} wall "
              f"(×{summary['speedup_forwards']:.2f} forwards), "
              f"acceptance {summary['acceptance_rate']:.0%}, "
              f"greedy-identical={summary['greedy_identical']}")
    return {"summary": summary, "arms": results}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=4,
                    help="draft tokens per decode row for the spec-on arm")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_decode.json with the full results")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: assert greedy parity, acceptance > 0, "
                         "structural forwards ratio >= 1.5, warm passes "
                         "compile nothing, zero internal errors")
    args = ap.parse_args()
    t0 = time.time()
    res = bench(k=args.k)
    s = res["summary"]
    if args.json:
        with open("BENCH_decode.json", "w") as f:
            json.dump(res, f, indent=2, sort_keys=True)
        print("wrote BENCH_decode.json")
    if args.smoke:
        off, on = res["arms"]["spec0"], res["arms"][f"spec{args.k}"]
        assert s["greedy_identical"], (
            "speculative decode changed greedy output")
        assert off["internal_errors"] == 0 and on["internal_errors"] == 0, (
            "bench tripped the engine backstop")
        assert off["warm"]["traces"] == 0 and on["warm"]["traces"] == 0, (
            "warm pass compiled new traces — the measurement is compile "
            "noise, not dataflow")
        assert on["warm"]["drafted"] > 0 and s["acceptance_rate"] > 0, (
            "spec-on arm accepted no drafts")
        assert s["accepted_per_step"] > 1.0, (
            "mean accepted draft tokens per step must exceed 1")
        assert s["speedup_forwards"] >= 1.5, (
            f"structural decode speedup {s['speedup_forwards']:.2f}x "
            f"< 1.5x on the repetitive workload")
        print("bench_decode --smoke: all assertions passed")
    dt = time.time() - t0
    print(f"bench_decode,{dt*1e6:.0f},"
          f"tok_s_on={s['decode_tok_s_on']:.1f};"
          f"tok_s_off={s['decode_tok_s_off']:.1f};"
          f"speedup={s['speedup_tok_s']:.2f}x;"
          f"forwards_speedup={s['speedup_forwards']:.2f}x;"
          f"acceptance={s['acceptance_rate']:.2f};"
          f"accepted_per_step={s['accepted_per_step']:.2f}")


if __name__ == "__main__":
    main()
