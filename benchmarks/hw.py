"""TPU v5e hardware constants (the dry-run target) + roofline helpers."""

PEAK_BF16 = 197e12          # FLOP/s per chip
PEAK_INT8 = 394e12          # int8 OPS per chip (2× bf16 on the MXU)
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link
CHIP_HBM = 16e9             # bytes per chip (v5e 16 GB)

# A100-80G constants — used only to sanity-map the paper's Fig. 9 claims.
A100_FP16 = 312e12
A100_INT8 = 624e12
A100_INT4 = 1248e12
A100_HBM = 2.0e12


def compute_time(flops: float, chips: int = 1, int8: bool = False) -> float:
    peak = PEAK_INT8 if int8 else PEAK_BF16
    return flops / (chips * peak)


def memory_time(bytes_: float, chips: int = 1) -> float:
    return bytes_ / (chips * HBM_BW)


def collective_time(bytes_: float, chips: int = 1) -> float:
    return bytes_ / (chips * ICI_BW)


def gemm_roofline_latency(m: int, k: int, n: int, *,
                          w_bits: int, a_bits: int,
                          out_bytes: int = 4, scale_overhead: float = 0.0,
                          int_mxu: bool = True) -> dict:
    """Single-chip GEMM latency model: max(compute, memory) + terms.

    ``scale_overhead`` adds per-group dequant metadata bytes (f32 scales
    per 128-group). int_mxu: int8-rate MXU when both operands ≤ 8 bit.
    """
    flops = 2.0 * m * k * n
    use_int8 = int_mxu and w_bits <= 8 and a_bits <= 8
    t_c = compute_time(flops, int8=use_int8)
    w_bytes = k * n * w_bits / 8 * (1 + scale_overhead)
    a_bytes = m * k * a_bits / 8 * (1 + scale_overhead)
    o_bytes = m * n * out_bytes
    t_m = memory_time(w_bytes + a_bytes + o_bytes)
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "latency_s": max(t_c, t_m),
        "bound": "compute" if t_c > t_m else "memory",
        "bytes": w_bytes + a_bytes + o_bytes,
        "flops": flops,
    }
