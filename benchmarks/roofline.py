"""§Roofline: turn dry-run records into the three-term roofline table.

  compute term    = HLO_FLOPs / (chips × peak)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

Caveat (documented in EXPERIMENTS.md): XLA:CPU cost_analysis does not
count integer-MXU dot ops as "flops", so for the quantized serving cells
the compute term is also derived analytically from MODEL_FLOPS
(6·N·D train / 2·N_active·tokens serve) — we report both and take the
max as the effective compute term.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from benchmarks import hw

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SHAPES, get_config  # noqa: E402

MESH_CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic MODEL_FLOPS for the whole step (global, all chips)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/seq


def ideal_bytes_per_device(arch: str, shape_name: str, mesh: str) -> float:
    """Analytic minimum HBM traffic per device for one step (the fused
    Pallas-kernel dataflow: packed weights + packed KV + small acts).

    Used for the *attainment* column: the as-compiled dry-run lowers the
    portable jnp reference path, which materializes dequantized int4
    operands (u8→f32 converts) that the TPU Pallas kernels keep in VMEM —
    so cost_analysis bytes overstate the target kernel's traffic and the
    ideal-bytes ratio bounds what kernel-level fusion recovers.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = MESH_CHIPS[mesh]
    model_par = 16
    data_par = chips // model_par
    b_dev = max(1, shape.global_batch // data_par)

    if shape.kind == "train":
        # params f32 + grads + adam m/v (read+write) sharded over all chips
        n = cfg.param_count()
        param_traffic = n * (4 + 4 + 4 * 4) / chips
        tokens_dev = shape.global_batch * shape.seq_len / data_par
        act_traffic = (cfg.num_layers * tokens_dev * cfg.d_model * 2 * 8
                       / model_par)
        return param_traffic + act_traffic

    n_active = cfg.active_param_count()
    w = n_active * 0.515 / model_par           # int4 + group scales
    head = cfg.vocab_size * cfg.d_model * 4 / model_par  # fp head+embed
    toks = b_dev * (shape.seq_len if shape.kind == "prefill" else 1)
    kv = 0.0
    if cfg.family in ("dense", "moe", "vlm"):
        t_eff = shape.seq_len
        kv = (b_dev * t_eff * cfg.kv_dim * 2 * 0.5
              * cfg.num_layers)
        if shape.global_batch == 1:
            kv /= data_par                      # seq-parallel cache
    elif cfg.family == "hybrid":
        t_eff = shape.seq_len
        groups = cfg.num_layers // cfg.attn_period
        kv = b_dev * t_eff * cfg.kv_dim * 2 * 0.5 * groups
        if shape.global_batch == 1:
            kv /= data_par
        d_in = cfg.ssm_expand * cfg.d_model
        kv += (cfg.num_layers * b_dev * (d_in // cfg.ssm_head_dim)
               * cfg.ssm_state * cfg.ssm_head_dim * 4 * 2)
    elif cfg.family == "ssm":
        d = cfg.d_model
        kv = cfg.num_layers * b_dev * (d // cfg.rwkv_head_dim) \
            * cfg.rwkv_head_dim ** 2 * 4 * 2
    act = toks * cfg.d_model * cfg.num_layers * 4 * 2 / model_par
    if shape.kind == "prefill":
        return w + head + kv + act
    return w + head + kv + act


def analyze_record(rec: dict) -> dict:
    chips = MESH_CHIPS[rec["mesh"]]
    # cost_analysis on the SPMD module is per-device
    flops_dev = rec["flops"]
    bytes_dev = rec["bytes_accessed"]
    coll_bytes_dev = rec["collectives"]["total_bytes"]

    mf = model_flops(rec["arch"], rec["shape"])
    mf_per_chip = mf / chips
    int8_frac = 0.0 if rec["kind"] == "train" else 0.9
    peak = hw.PEAK_BF16 * (1 - int8_frac) + hw.PEAK_INT8 * int8_frac

    t_compute_hlo = flops_dev / hw.PEAK_BF16
    t_compute_model = mf_per_chip / peak
    t_compute = max(t_compute_hlo, t_compute_model)
    t_memory = bytes_dev / hw.HBM_BW
    t_coll = coll_bytes_dev / hw.ICI_BW

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_total = max(terms.values())
    useful = mf_per_chip / max(flops_dev, mf_per_chip, 1.0)
    ideal_by = ideal_bytes_per_device(rec["arch"], rec["shape"], rec["mesh"])
    t_ideal = max(t_compute_model, ideal_by / hw.HBM_BW)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": t_compute, "compute_hlo_s": t_compute_hlo,
        "compute_model_s": t_compute_model,
        "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "step_s": t_total,
        "model_flops_global": mf,
        "useful_flops_ratio": useful,
        "ideal_bytes_gb": ideal_by / 1e9,
        "ideal_step_s": t_ideal,
        "attainment": t_ideal / t_total if t_total > 0 else 0.0,
        "roofline_fraction": (
            t_compute_model / t_total if t_total > 0 else 0.0),
        "hbm_gb_per_device": rec["memory"]["argument_bytes"] / 1e9,
    }


def load_records(dir_: str, mesh: str | None = None,
                 schedule: str = "split"):
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        if rec.get("schedule", "split") != schedule:
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        recs.append(rec)
    return recs


def fmt_time(s: float) -> str:
    if s >= 1:
        return f"{s:7.2f}s "
    if s >= 1e-3:
        return f"{s*1e3:7.2f}ms"
    return f"{s*1e6:7.1f}us"


def print_table(rows, file=sys.stdout):
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'compute':9s} "
           f"{'memory':9s} {'collective':10s} {'dominant':10s} "
           f"{'attain%':8s} {'useful%':8s} {'HBM GB':7s}")
    print(hdr, file=file)
    print("-" * len(hdr), file=file)
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
              f"{fmt_time(r['compute_s'])} {fmt_time(r['memory_s'])} "
              f"{fmt_time(r['collective_s'])}  {r['dominant']:10s} "
              f"{100*r['attainment']:7.1f}% "
              f"{100*r['useful_flops_ratio']:7.1f}% "
              f"{r['hbm_gb_per_device']:6.2f}", file=file)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--schedule", default="split")
    ap.add_argument("--json-out", default="")
    args = ap.parse_args()
    rows = [analyze_record(r)
            for r in load_records(args.dir, args.mesh, args.schedule)]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print_table(rows)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
