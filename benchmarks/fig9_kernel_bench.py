"""Fig. 9: linear-layer (decode GEMM) speedups of W4Ax vs baselines.

The paper measures wall-clock on A100. On this CPU container the v5e
TARGET latency is derived from the fused-kernel roofline: bytes = exactly
what the Pallas kernel streams HBM→VMEM (packed weights + packed acts +
group scales + f32 output), compute = MXU time at the operand precision
(int8 path = 2× bf16; TPU has no int4 MXU — DESIGN.md §2 documents that
the paper's int4-tensor-core 2× does NOT transfer, only the bandwidth
win does). Byte counts are cross-checked against the actual packed
buffer sizes produced by the quantizer.

Workloads: the paper's models' FFN up-projection at batch {16, 64, 256}
(token-generation phase linear layers, as in §6.3).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks import hw
from repro.core import quantizer as Q

WORKLOADS = {
    "llama3-8b": (4096, 14336),
    "llama3-70b": (8192, 28672),
    "mistral-nemo": (5120, 14336),
    "qwen2-72b": (8192, 29568),
}
BATCHES = (16, 64, 256)
GROUP = 128
SCALE_BYTES = 4.0


def packed_bytes(m, k, n, w_bits, a_bits_eff):
    """Fused-kernel HBM traffic (verified against quantizer buffer sizes)."""
    w = k * n * w_bits / 8
    w_scales = (k // GROUP) * n * SCALE_BYTES if w_bits < 16 else 0
    a = m * k * a_bits_eff / 8
    a_scales = m * (k // GROUP) * SCALE_BYTES if a_bits_eff < 16 else 0
    out = m * n * 4
    return w + w_scales + a + a_scales + out


def verify_packed_sizes():
    """The byte model must match the real packed buffers bit-for-bit."""
    k, n, m = 512, 256, 16
    w = jnp.zeros((k, n), jnp.float32)
    wq = Q.quantize_weight_int4(w + 0.01, group_size=GROUP)
    assert wq.data.nbytes == k * n // 2
    assert wq.scale.nbytes == (k // GROUP) * n * 4
    x = jnp.ones((m, k), jnp.float32)
    q4, s4 = Q.quantize_act_groupwise(x, GROUP, bits=4)
    a4 = Q.pack_int4_interleaved(q4, axis=1, block_size=GROUP)
    assert a4.nbytes == m * k // 2
    assert s4.nbytes == m * (k // GROUP) * 4


def latency(m, k, n, w_bits, a_bits_eff):
    by = packed_bytes(m, k, n, w_bits, a_bits_eff)
    flops = 2.0 * m * k * n
    int_path = w_bits <= 8 and a_bits_eff <= 8
    t_c = flops / (hw.PEAK_INT8 if int_path else hw.PEAK_BF16)
    t_m = by / hw.HBM_BW
    return max(t_c, t_m), ("compute" if t_c > t_m else "memory")


KERNELS = {
    # name: (w_bits, effective activation bits)
    "W16A16": (16, 16),
    "W8A8": (8, 8),
    "W4A16": (4, 16),
    "W4Ax": (4, 4.5),   # 87.5 % INT4 + 12.5 % INT8 blocks
}


def run(verbose=True):
    verify_packed_sizes()
    speed = {kk: [] for kk in KERNELS if kk != "W16A16"}
    rows = []
    for model, (d, dff) in WORKLOADS.items():
        for batch in BATCHES:
            lat = {kk: latency(batch, d, dff, *bits)
                   for kk, bits in KERNELS.items()}
            base = lat["W16A16"][0]
            row = {"model": model, "batch": batch,
                   **{kk: base / v[0] for kk, v in lat.items()}}
            rows.append(row)
            for kk in speed:
                speed[kk].append(base / lat[kk][0])
            if verbose:
                print(f"{model:14s} b={batch:3d}  " + "  ".join(
                    f"{kk}:{base/lat[kk][0]:5.2f}×({lat[kk][1][0]})"
                    for kk in KERNELS))
    return rows, {kk: float(np.mean(v)) for kk, v in speed.items()}


def main():
    t0 = time.time()
    print("\n== Fig. 9 proxy: derived v5e kernel speedups vs W16A16 ==")
    rows, means = run()
    dt = time.time() - t0
    print(f"\nmean speedups vs W16A16: " + "  ".join(
        f"{k}={v:.2f}×" for k, v in means.items()))
    print("(paper on A100: W4Ax 2.88× vs cuBLAS, 1.77× vs W4A16, "
          "1.33× vs W8A8; on v5e the int4-MXU term does not transfer —"
          " W4Ax ≥ W8A8 via bandwidth, equal at the compute-bound limit)")
    ok = (means["W4Ax"] >= means["W4A16"]
          and means["W4Ax"] >= means["W8A8"] - 1e-9)
    print(f"fig9_kernel_bench,{dt*1e6:.0f},w4ax_mean={means['W4Ax']:.2f}x;"
          f"w4a16={means['W4A16']:.2f}x;w8a8={means['W8A8']:.2f}x;"
          f"w4ax_fastest={ok}")


if __name__ == "__main__":
    main()
