"""Fig. 11/12: end-to-end serving throughput, W4AxKV4 vs baselines.

Two parts:

(a) **Derived throughput model** (the paper's A100-80G experiment mapped
    to one v5e pod slice with the same memory budget): for each precision
    config, the max decode batch is what fits the memory budget after
    weights, and throughput = batch / step_time(batch) where step_time is
    the decode roofline (weights + KV bytes per token — decode is
    memory-bound). Input/output lengths follow the paper (1024/512 and
    128/128).

(b) **Measured engine throughput** on the tiny smoke model (CPU): real
    tokens/s of the continuous-batching engine for KV16 vs KV4 page
    budgets, showing KV4 admits ~4× the batch.

(c) **Gather vs paged decode attention**: the same engine/workload with
    `decode_attention="gather"` (per-token O(context) copy of every
    sequence's packed KV before each step — the seed's dataflow) vs
    `"paged"` (block-table-aware kernel reads the pools directly,
    O(pages touched)). Reported as tok/s and per-step decode-path bytes,
    so the gather-free win is measured rather than asserted.

(d) **Chunked vs whole-prompt prefill**: short requests decode while a
    long prompt streams in. Whole-prompt prefill runs one O(T²) fp
    forward per admitted request (decode stalls behind it — the max
    step-time spike); chunked prefill packs `prefill_chunk_tokens` from
    all partially-prefilled requests into one ragged forward per step,
    bounding the fp activation footprint and interleaving with decode.
    Reported: aggregate tok/s, time-to-first-token (mean/max), peak fp
    prefill tokens, max step time, and interleaved-step count.

(e) **Unified vs split step**: the same ragged workload with
    ``unified_step=True`` (decode rows folded into the ragged prefill
    chunk — ONE bucketed-shape jitted forward per step) vs the split
    step (a prefill forward plus a decode forward, each ragged shape a
    fresh trace). Reported: aggregate tok/s, mean/max step time,
    forwards per step, and compiled forward variants (``trace_count``) —
    the retrace-churn win is measured rather than asserted.

(f) **Prefix cache on vs off**: a stream of requests sharing a long
    system prompt, cache-off vs the refcounted published-page prefix
    cache. Asserted via engine COUNTERS, not wall-clock (CI-safe):
    ``prefix_hit_tokens`` > 0, prefill chunk tokens strictly fewer than
    cache-off, greedy-token-identical output, and the unified step's
    one-forward/trace-plateau structure preserved.

(g) **Tensor-parallel parity** (``--smoke --sharded``): the mixed
    workload on one device vs a (1, m) local mesh with heads/KV pools
    sharded over the model axis. Greedy tokens must be identical, the
    one-forward-per-step invariant must hold, and the per-shard
    ``attn_work_items`` counters must split the work-queue items evenly.
    Skips (with a message) on a single-device host.

(h) **Speculative decode ablation**: a repetitive decode-heavy workload
    (the prompt-lookup draft's favorable regime) with
    ``SamplingParams.speculation`` 0 vs 4. Asserted via counters, not
    wall-clock: greedy tokens bitwise identical across arms, acceptance
    rate > 0, mean accepted draft tokens per step > 1, and strictly
    fewer forwards than tokens generated (several tokens commit per
    forward). Wall-clock tok/s is reported for the record.

``--smoke`` runs parts (d), (e), (f) and (h) — the CI end-to-end
exercise of the prefill/decode interleave path, the unified-step
dataflow, the prefix-cached request lifecycle, and the speculative
verify path. ``--smoke --sharded`` runs ONLY part (g), under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--attention-schedule work_queue|dense`` selects the paged-attention
grid schedule for every measured engine part (default: the Stream-K
work queue; ``dense`` is the fig10-ablated baseline rectangle).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks import hw
from repro.configs.base import get_config, get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig, SamplingParams

MODELS = ["llama3_8b", "mistral_nemo_12b", "llama3_70b", "qwen2_72b"]
MEM_BUDGET = 80e9           # paper: single A100-80G
CONFIGS = {
    #            w_bits a_bits kv_bits
    "W16A16":   (16, 16, 16),
    "W8A8":     (8, 8, 8),
    "W4A16":    (4, 16, 16),
    "W4AxKV4":  (4, 4.5, 4),   # 87.5 % A4 + 12.5 % A8 → 4.5 eff. bits
}


def decode_step_time(cfg, batch, ctx_len, w_bits, a_bits, kv_bits):
    """Memory-bound decode step: stream weights once + KV per sequence."""
    n_active = cfg.active_param_count()
    w_bytes = n_active * w_bits / 8
    kv_bytes_per_seq = (2 * cfg.num_layers * cfg.kv_dim * ctx_len
                        * kv_bits / 8)
    act_bytes = batch * cfg.d_model * cfg.num_layers * 12 * a_bits / 8
    t_mem = (w_bytes + batch * kv_bytes_per_seq + act_bytes) / hw.HBM_BW
    flops = 2.0 * n_active * batch
    t_cmp = flops / (hw.PEAK_INT8 if max(w_bits, a_bits) <= 8
                     else hw.PEAK_BF16)
    return max(t_mem, t_cmp)


def max_batch(cfg, ctx_len, w_bits, kv_bits, budget=MEM_BUDGET):
    w_bytes = cfg.param_count() * w_bits / 8
    kv_per_seq = 2 * cfg.num_layers * cfg.kv_dim * ctx_len * kv_bits / 8
    free = budget - w_bytes - 2e9          # 2 GB activations/runtime
    if free <= 0:
        return 0
    return max(0, int(free // kv_per_seq))


def derived_table(in_len, out_len, verbose=True):
    ctx = in_len + out_len
    rel_rows = {}
    for model in MODELS:
        cfg = get_config(model)
        tput = {}
        for name, (wb, ab, kb) in CONFIGS.items():
            b = max_batch(cfg, ctx, wb, kb)
            if b == 0:
                tput[name] = 0.0
                continue
            t = decode_step_time(cfg, b, ctx, wb, ab, kb)
            tput[name] = b / t
        base = tput["W4A16"] or 1.0
        rel = {k: v / base for k, v in tput.items()}
        rel_rows[model] = rel
        if verbose:
            bb = {k: max_batch(cfg, ctx, v[0], v[2])
                  for k, v in CONFIGS.items()}
            print(f"{model:16s} " + "  ".join(
                f"{k}:{rel[k]:5.2f}×(b={bb[k]})" for k in CONFIGS))
    return rel_rows


def measured_engine(verbose=True, sched="work_queue"):
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(int4_fraction=0.875, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    results = {}
    # same page-memory budget: KV4 gets 4× the pages of KV16 per byte —
    # emulate by giving the KV16-equivalent run 1/4 the pages.
    for name, pages in (("KV16-budget", 16), ("KV4-budget", 64)):
        eng = Engine(cfg, qparams, qc, EngineConfig(
            max_batch=8, num_pages=pages, page_size=16,
            attention_schedule=sched))
        for i in range(8):
            eng.add_request(i, list(range(1, 17)), 16)
        t0 = time.time()
        eng.run(max_steps=400)
        dt = time.time() - t0
        results[name] = {
            "tok_s": eng.tokens_generated / dt,
            "preemptions": eng.sched.preemptions,
            "steps": eng.steps,
        }
        if verbose:
            print(f"engine {name:12s}: {results[name]['tok_s']:7.1f} tok/s "
                  f"steps={eng.steps} preemptions={eng.sched.preemptions}")
    return results


def measured_gather_vs_paged(verbose=True, sched="work_queue"):
    """Same workload, gather vs paged decode path. Long generations make
    the gather copy's O(context)·layers byte traffic dominate."""
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(int4_fraction=0.875, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    in_len, out_len, nreq = 16, 48, 6
    results = {}
    for mode in ("gather", "paged"):
        eng = Engine(cfg, qparams, qc, EngineConfig(
            max_batch=8, num_pages=96, page_size=8, max_pages_per_seq=16,
            decode_attention=mode, attention_schedule=sched))
        for i in range(nreq):
            eng.add_request(i, list(range(1, in_len + 1)), out_len)
        t0 = time.time()
        eng.run(max_steps=600)
        dt = time.time() - t0
        # decode-path KV bytes actually moved per generated token:
        # gather copies the whole packed context; paged touches it in
        # place (the kernel reads pages, no materialized copy).
        ctx = in_len + out_len / 2
        kv_bytes = (2 * cfg.num_layers * cfg.num_kv_heads
                    * (cfg.head_dim // 2) * ctx)
        results[mode] = {
            "tok_s": eng.tokens_generated / dt,
            "steps": eng.steps,
            "copied_bytes_per_tok": kv_bytes if mode == "gather" else 0.0,
        }
        if verbose:
            print(f"decode path {mode:7s}: {results[mode]['tok_s']:7.1f} "
                  f"tok/s  steps={eng.steps}  "
                  f"gathered≈{results[mode]['copied_bytes_per_tok']:.0f} "
                  f"B/token")
    if verbose:
        sp = results["paged"]["tok_s"] / max(results["gather"]["tok_s"], 1e-9)
        print(f"paged/gather speedup: {sp:.2f}×")
    return results


def measured_prefill_modes(verbose=True, sched="work_queue"):
    """Chunked vs whole-prompt prefill on a mixed workload: 4 ragged
    short requests decode while a 96-token prompt streams in. Chunked
    must be no slower in aggregate tok/s, bound its fp footprint by the
    chunk budget, and keep decode steps flowing during the long prefill.

    Short prompts are deliberately ragged (realistic traffic): the
    whole-prompt baseline pays one fp forward PER request (each a fresh
    trace), while chunked packs all of them plus the long prompt's first
    slice into ONE ragged forward — the batched-prefill amortization."""
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(int4_fraction=0.875, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    short_lens, long_len, out_len = (5, 8, 11, 14), 96, 12
    results = {}
    for mode in ("whole", "chunked"):
        # unified_step=False on BOTH arms: part (d) isolates the prefill
        # mode; the unified-step/bucketing win is part (e)'s variable
        eng = Engine(cfg, qparams, qc, EngineConfig(
            max_batch=6, num_pages=128, page_size=8, max_pages_per_seq=32,
            prefill_mode=mode, prefill_chunk_tokens=48,
            unified_step=False, attention_schedule=sched))
        for i, n in enumerate(short_lens):
            eng.add_request(i, list(range(1, n + 1)), out_len)
        eng.add_request(4, list(range(1, long_len + 1)), out_len)
        step_times = []
        t0 = time.time()
        while eng.sched.has_work and eng.steps < 400:
            s0 = time.time()
            eng.step()
            step_times.append(time.time() - s0)
        dt = time.time() - t0
        ttfts = [r.first_token_at - r.arrived_at
                 for r in eng.sched.finished if r.first_token_at]
        results[mode] = {
            "tok_s": eng.tokens_generated / dt,
            "ttft_mean_ms": 1e3 * float(np.mean(ttfts)),
            "ttft_max_ms": 1e3 * float(np.max(ttfts)),
            "peak_fp_tokens": eng.peak_prefill_fp_tokens,
            "max_step_ms": 1e3 * max(step_times),
            "interleaved_steps": eng.interleaved_steps,
        }
        if verbose:
            r = results[mode]
            print(f"prefill {mode:7s}: {r['tok_s']:7.1f} tok/s  "
                  f"ttft mean/max {r['ttft_mean_ms']:6.0f}/"
                  f"{r['ttft_max_ms']:6.0f} ms  "
                  f"peak fp prefill {r['peak_fp_tokens']:3d} tok  "
                  f"max step {r['max_step_ms']:6.0f} ms  "
                  f"interleaved {r['interleaved_steps']}")
    if verbose:
        w, c = results["whole"], results["chunked"]
        print(f"chunked/whole: tok/s {c['tok_s']/max(w['tok_s'],1e-9):.2f}×, "
              f"peak fp {c['peak_fp_tokens']}/{w['peak_fp_tokens']} tok, "
              f"decode interleaved during long prefill: "
              f"{c['interleaved_steps']} vs {w['interleaved_steps']} steps")
    return results


def measured_unified_vs_split(verbose=True, sched="work_queue"):
    """Unified one-forward-per-step vs the split (prefill + decode)
    step on a ragged mixed workload. Raggedness is the point: every
    distinct (nseq, cmax, ttot) the split path packs is a fresh trace,
    while the unified path buckets shapes and reuses its jitted forward
    — trace churn is what dominates the CPU smoke engine."""
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(int4_fraction=0.875, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    lens, out_len = (40, 7, 23, 64, 13, 29), 12
    rng = np.random.default_rng(0)
    results = {}
    for mode in ("split", "unified"):
        eng = Engine(cfg, qparams, qc, EngineConfig(
            max_batch=6, num_pages=128, page_size=8, max_pages_per_seq=32,
            prefill_chunk_tokens=24, unified_step=(mode == "unified"),
            attention_schedule=sched))
        for i, n in enumerate(lens):
            eng.add_request(
                i, rng.integers(1, cfg.vocab_size, n).tolist(), out_len)
        step_times = []
        t0 = time.time()
        while eng.sched.has_work and eng.steps < 400:
            s0 = time.time()
            eng.step()
            step_times.append(time.time() - s0)
        dt = time.time() - t0
        results[mode] = {
            "tok_s": eng.tokens_generated / dt,
            "steps": eng.steps,
            "forwards": eng.forward_calls,
            "traces": eng.trace_count,
            "mean_step_ms": 1e3 * float(np.mean(step_times)),
            "max_step_ms": 1e3 * float(np.max(step_times)),
        }
        if verbose:
            r = results[mode]
            print(f"step {mode:7s}: {r['tok_s']:7.1f} tok/s  "
                  f"steps={r['steps']:3d}  forwards={r['forwards']:3d}  "
                  f"traces={r['traces']:3d}  "
                  f"step mean/max {r['mean_step_ms']:5.0f}/"
                  f"{r['max_step_ms']:5.0f} ms")
    if verbose:
        u, s = results["unified"], results["split"]
        print(f"unified/split: tok/s {u['tok_s']/max(s['tok_s'],1e-9):.2f}×, "
              f"forwards/step {u['forwards']/u['steps']:.2f} vs "
              f"{s['forwards']/s['steps']:.2f}, "
              f"traces {u['traces']} vs {s['traces']}")
    return results


def measured_prefix_cache(verbose=True, sched="work_queue"):
    """Prefix cache on vs off: one request publishes a 48-token system
    prompt, then a wave of requests sharing it arrives. Weight-only +
    calibrated kv_range (the parity regime) keeps greedy output
    token-identical across arms, so the cache win is pure accounting:
    hit tokens served from published pages instead of prefill forwards."""
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    rng = np.random.default_rng(0)
    prefix = rng.integers(1, cfg.vocab_size, 48).tolist()
    suffixes = [rng.integers(1, cfg.vocab_size, n).tolist()
                for n in (5, 9, 7, 12)]
    results = {}
    for mode in ("off", "on"):
        eng = Engine(cfg, qparams, qc, EngineConfig(
            max_batch=6, num_pages=128, page_size=8, max_pages_per_seq=32,
            prefill_chunk_tokens=24, kv_range=4.0,
            prefix_cache=(mode == "on"), attention_schedule=sched))
        t0 = time.time()
        eng.add_request(0, prefix + suffixes[0], 8)
        eng.run(max_steps=200)          # publisher completes → pages cached
        for i, sfx in enumerate(suffixes[1:], start=1):
            eng.add_request(i, prefix + sfx, 8)
        eng.run(max_steps=400)
        dt = time.time() - t0
        results[mode] = {
            "tok_s": eng.tokens_generated / dt,
            "tokens": {r.request_id: list(r.generated)
                       for r in eng.sched.finished},
            "prefill_tokens": eng.prefill_tokens,
            "hit_tokens": eng.prefix_hit_tokens,
            "steps": eng.steps,
            "forwards": eng.forward_calls,
            "traces": eng.trace_count,
        }
        if verbose:
            r = results[mode]
            print(f"prefix cache {mode:3s}: {r['tok_s']:7.1f} tok/s  "
                  f"prefill_tokens={r['prefill_tokens']:4d}  "
                  f"hit_tokens={r['hit_tokens']:3d}  "
                  f"steps={r['steps']:3d}  forwards={r['forwards']:3d}  "
                  f"traces={r['traces']}")
    if verbose:
        on, off = results["on"], results["off"]
        total = on["prefill_tokens"] + on["hit_tokens"]
        print(f"prefix cache: hit rate {on['hit_tokens']/total:.0%}, "
              f"prefill tokens {on['prefill_tokens']} vs "
              f"{off['prefill_tokens']} (cache off), "
              f"greedy-identical={on['tokens'] == off['tokens']}")
    return results


def measured_speculation(verbose=True, sched="work_queue", k=4):
    """(h) Speculation off vs on over a repetitive decode-heavy
    workload. The tiny random smoke model's greedy decode falls into
    short absorbing cycles, and the prompts repeat their own n-grams —
    exactly the regime where prompt-lookup drafting shines — so the
    verify path gets real multi-token accepts. Weight-only +
    calibrated kv_range is the greedy-parity regime: the verify
    chunk's fake-quantized in-flight KV matches the int4 readback, and
    the asserted bitwise-identical output is meaningful."""
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    prompts = [[188] * 8, [139, 133, 188, 188] * 2, [188] * 12]
    out_len = 24
    results = {}
    for spec in (0, k):
        eng = Engine(cfg, qparams, qc, EngineConfig(
            max_batch=6, num_pages=128, page_size=8, max_pages_per_seq=32,
            prefill_chunk_tokens=24, kv_range=4.0, unified_step=True,
            sanitize=True, attention_schedule=sched))
        t0 = time.time()
        for i, p in enumerate(prompts):
            eng.submit(p, SamplingParams(max_new_tokens=out_len,
                                         temperature=0.0,
                                         speculation=spec),
                       request_id=i)
        done = eng.run(max_steps=400)
        dt = time.time() - t0
        name = f"spec{spec}"
        results[name] = {
            "tok_s": eng.tokens_generated / dt,
            "tokens": {r.request_id: list(r.generated) for r in done},
            "steps": eng.steps,
            "forwards": eng.forward_calls,
            "drafted": eng.spec_draft_tokens,
            "accepted": eng.spec_accepted_tokens,
            "rollback": eng.spec_rollback_tokens,
            "internal_errors": eng.internal_errors,
        }
        if verbose:
            r = results[name]
            acc = r["accepted"] / max(1, r["drafted"])
            print(f"speculation k={spec}: {r['tok_s']:7.1f} tok/s  "
                  f"steps={r['steps']:3d}  forwards={r['forwards']:3d}  "
                  f"drafted={r['drafted']:3d}  accepted={r['accepted']:3d} "
                  f"({acc:.0%})  rollback={r['rollback']}")
    if verbose:
        off, on = results["spec0"], results[f"spec{k}"]
        print(f"speculation: forwards {on['forwards']} vs "
              f"{off['forwards']} (×{off['forwards']/on['forwards']:.1f} "
              f"fewer), accepted/step "
              f"{on['accepted']/max(1, on['steps']):.2f}, "
              f"greedy-identical={on['tokens'] == off['tokens']}")
    return results


def measured_sharded_parity(verbose=True, sched="work_queue"):
    """(g) Tensor-parallel parity: the same mixed prefill+decode workload
    on one device vs a (1, m) mesh sharding heads/pools over the model
    axis. Asserted via counters and greedy tokens, not wall-clock: the
    sharded engine must be token-identical (int4_fraction=1.0 keeps the
    per-shard act-quant blocks bit-exact), keep one forward per step,
    and split the attention work items evenly across shards."""
    import dataclasses as _dc

    from repro.launch.mesh import make_local_mesh

    ndev = len(jax.devices())
    if ndev < 2:
        print("sharded parity: SKIPPED — 1 device (run under XLA_FLAGS="
              "--xla_force_host_platform_device_count=8)")
        return None
    # smoke configs have q_dim=128 (too small to split a 128-channel
    # act-quant block); head_dim=64 gives q_dim=256 = 2 shardable blocks
    cfg = _dc.replace(get_smoke_config("llama3_8b"), head_dim=64)
    tp = min(2, cfg.num_kv_heads)                   # llama3_8b smoke: 2 kv
    qc = QuantConfig(int4_fraction=1.0, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, qaxes = LM(cfg, quant=qc).quantize(params, axes)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
               for n in (11, 19, 7, 26)]
    results = {}
    for mode in ("single", "sharded"):
        mesh = make_local_mesh(1, tp) if mode == "sharded" else None
        eng = Engine(cfg, qparams, qc, EngineConfig(
            max_batch=4, num_pages=64, page_size=8, kv_range=4.0,
            attention_schedule=sched),
            mesh=mesh, param_axes=qaxes if mesh is not None else None)
        t0 = time.time()
        for i, p in enumerate(prompts):
            eng.add_request(i, p, 8)
        done = eng.run(max_steps=200)
        dt = time.time() - t0
        results[mode] = {
            "tok_s": eng.tokens_generated / dt,
            "tokens": {r.request_id: list(r.generated) for r in done},
            "steps": eng.steps,
            "forwards": eng.forward_calls,
            "traces": eng.trace_count,
            "work_items": eng.attn_work_items,
            "per_shard": list(eng.attn_work_items_per_shard),
        }
        if verbose:
            r = results[mode]
            print(f"{mode:7s} (tp={eng.tp_size}): {r['tok_s']:7.1f} tok/s  "
                  f"steps={r['steps']:3d}  forwards={r['forwards']:3d}  "
                  f"traces={r['traces']}  work_items={r['work_items']:4d}  "
                  f"per_shard={r['per_shard']}")
    if verbose:
        s, sh = results["single"], results["sharded"]
        print(f"sharded parity: greedy-identical="
              f"{s['tokens'] == sh['tokens']}, per-shard spread="
              f"{max(sh['per_shard']) - min(sh['per_shard'])}")
    return results


def main(smoke: bool = False, sched: str = "work_queue",
         sharded: bool = False):
    t0 = time.time()
    if smoke and sharded:
        print("== fig11 --smoke --sharded: tensor-parallel parity "
              "(tiny model, forced CPU mesh) ==")
        sp = measured_sharded_parity(sched=sched)
        dt = time.time() - t0
        if sp is None:
            print(f"fig11_e2e_throughput,{dt*1e6:.0f},sharded=SKIPPED")
            return
        s, sh = sp["single"], sp["sharded"]
        assert sh["tokens"] == s["tokens"], (
            "sharded engine changed greedy output")
        assert sh["forwards"] == sh["steps"], (
            "sharding broke the one-forward-per-step invariant")
        assert sh["traces"] <= s["traces"], (
            "sharding must not add compiled forward variants")
        assert sum(sh["per_shard"]) == sh["work_items"], (
            "per-shard attention work must account for every item")
        assert max(sh["per_shard"]) == min(sh["per_shard"]), (
            "head-sharded work queue must split items evenly")
        print(f"fig11_e2e_throughput,{dt*1e6:.0f},"
              f"sharded_parity=identical;"
              f"tp={len(sh['per_shard'])};"
              f"work_items_per_shard={sh['per_shard'][0]};"
              f"forwards={sh['forwards']}of{sh['steps']}steps")
        return
    if smoke:
        print("== fig11 --smoke: chunked vs whole-prompt prefill "
              "(tiny model, CPU) ==")
        prefill = measured_prefill_modes(sched=sched)
        c, w = prefill["chunked"], prefill["whole"]
        assert c["peak_fp_tokens"] < w["peak_fp_tokens"], (
            "chunked prefill must bound the fp activation footprint")
        assert c["interleaved_steps"] > w["interleaved_steps"], (
            "decode must interleave with chunked long-prompt prefill")
        print("== fig11 --smoke: unified vs split step (tiny model, "
              "CPU) ==")
        step = measured_unified_vs_split(sched=sched)
        dt = time.time() - t0
        u, s = step["unified"], step["split"]
        assert u["forwards"] == u["steps"], (
            "unified step must issue exactly ONE forward per step")
        assert u["traces"] < s["traces"], (
            "bucketed unified shapes must compile fewer variants than "
            "the split step's ragged churn")
        # wall-clock is noisy on shared CI runners — the structural
        # asserts above carry the guarantee; gate only a gross
        # regression (measured margin is ~2.5×)
        assert u["tok_s"] >= 0.8 * s["tok_s"], (
            "unified step grossly slower than the split baseline")
        print("== fig11 --smoke: prefix cache on vs off (tiny model, "
              "CPU) ==")
        px = measured_prefix_cache(sched=sched)
        dt = time.time() - t0
        on, off = px["on"], px["off"]
        # counters, not wall-clock: cache hits must exist, prefill chunk
        # tokens must strictly shrink, output must not change, and the
        # unified one-forward/bucketed-trace structure must survive
        assert on["hit_tokens"] > 0, "no prefix-cache hits on shared prompts"
        assert on["prefill_tokens"] < off["prefill_tokens"], (
            "prefix cache must forward strictly fewer prompt tokens")
        assert on["tokens"] == off["tokens"], (
            "prefix cache changed greedy output")
        assert on["forwards"] == on["steps"], (
            "prefix cache broke the one-forward-per-step invariant")
        assert on["traces"] <= off["traces"], (
            "prefix cache must not add compiled forward variants")
        print("== fig11 --smoke: speculative decode off vs on (tiny "
              "model, CPU) ==")
        sp = measured_speculation(sched=sched)
        dt = time.time() - t0
        s0, s4 = sp["spec0"], sp["spec4"]
        # counters, not wall-clock: drafts must flow and be accepted,
        # several tokens must commit per forward, and greedy output
        # must not change by a single bit
        assert s4["tokens"] == s0["tokens"], (
            "speculative decode changed greedy output")
        assert s4["internal_errors"] == 0 and s0["internal_errors"] == 0, (
            "speculation smoke tripped the engine backstop")
        assert s4["drafted"] > 0 and s4["accepted"] > 0, (
            "speculation smoke produced no accepted drafts")
        assert s4["accepted"] / max(1, s4["steps"]) > 1.0, (
            "mean accepted draft tokens per step must exceed 1 on the "
            "repetitive workload")
        assert s4["forwards"] < s0["forwards"], (
            "speculation must finish the workload in fewer forwards")
        print(f"fig11_e2e_throughput,{dt*1e6:.0f},"
              f"smoke_chunked_vs_whole_tok_s="
              f"{c['tok_s']/max(w['tok_s'],1e-9):.2f}x;"
              f"ttft_chunked={c['ttft_mean_ms']:.0f}ms;"
              f"ttft_whole={w['ttft_mean_ms']:.0f}ms;"
              f"peak_fp={c['peak_fp_tokens']}vs{w['peak_fp_tokens']}tok;"
              f"unified_vs_split_tok_s="
              f"{u['tok_s']/max(s['tok_s'],1e-9):.2f}x;"
              f"traces={u['traces']}vs{s['traces']};"
              f"prefix_hit_tokens={on['hit_tokens']};"
              f"prefill_tokens_on_off="
              f"{on['prefill_tokens']}vs{off['prefill_tokens']};"
              f"spec_forwards={s4['forwards']}vs{s0['forwards']};"
              f"spec_acceptance="
              f"{s4['accepted']/max(1, s4['drafted']):.2f}")
        return
    print("\n== Fig. 11 proxy: derived e2e throughput vs W4A16 "
          "(80 GB budget) ==")
    print("--- in/out 1024/512 ---")
    rel_long = derived_table(1024, 512)
    print("--- in/out 128/128 ---")
    rel_short = derived_table(128, 128)
    print("\n== measured engine (tiny model, equal page-byte budget) ==")
    meas = measured_engine(sched=sched)
    print("\n== measured decode path: gather vs paged (tiny model) ==")
    paths = measured_gather_vs_paged(sched=sched)
    print("\n== measured prefill path: chunked vs whole-prompt "
          "(tiny model) ==")
    prefill = measured_prefill_modes(sched=sched)
    print("\n== measured step structure: unified vs split (tiny model) ==")
    step = measured_unified_vs_split(sched=sched)
    print("\n== measured prefix cache: on vs off (tiny model) ==")
    px = measured_prefix_cache(sched=sched)
    dt = time.time() - t0
    mean_long = float(np.mean([r["W4AxKV4"] for r in rel_long.values()]))
    mean_short = float(np.mean([r["W4AxKV4"] for r in rel_short.values()]))
    print(f"(paper: 2.02× @1024/512, 1.63× @128/128 over TRT-LLM-W4A16)")
    print(f"fig11_e2e_throughput,{dt*1e6:.0f},"
          f"w4axkv4_vs_w4a16_long={mean_long:.2f}x;"
          f"short={mean_short:.2f}x;"
          f"engine_kv4_vs_kv16="
          f"{meas['KV4-budget']['tok_s']/max(meas['KV16-budget']['tok_s'],1e-9):.2f}x;"
          f"paged_vs_gather="
          f"{paths['paged']['tok_s']/max(paths['gather']['tok_s'],1e-9):.2f}x;"
          f"chunked_vs_whole_prefill="
          f"{prefill['chunked']['tok_s']/max(prefill['whole']['tok_s'],1e-9):.2f}x;"
          f"unified_vs_split="
          f"{step['unified']['tok_s']/max(step['split']['tok_s'],1e-9):.2f}x;"
          f"prefix_cache_prefill_tokens="
          f"{px['on']['prefill_tokens']}vs{px['off']['prefill_tokens']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI: only the engine runs — chunked-vs-whole "
                         "prefill (d), unified-vs-split step (e), and "
                         "prefix cache on-vs-off (f)")
    ap.add_argument("--attention-schedule", default="work_queue",
                    choices=["work_queue", "dense"],
                    help="paged-attention grid schedule for every "
                         "measured engine part (fig10 ablates the two)")
    ap.add_argument("--sharded", action="store_true",
                    help="with --smoke: run ONLY part (g), single-device "
                         "vs tensor-parallel parity on a local mesh "
                         "(needs >=2 devices, e.g. XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    args = ap.parse_args()
    main(smoke=args.smoke, sched=args.attention_schedule,
         sharded=args.sharded)
