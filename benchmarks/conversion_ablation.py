"""§4.3 ablation: fast INT4→INT8 conversion (zero-extension + fold) vs
naive sign-extension — op counts in the lowered unpack and end-to-end
kernel equality.

Paper claim: 10 instructions → 2 per conversion on CUDA cores. On the
TPU VPU the analogous counts are the vector ops in the unpack dataflow:
zero-ext = {and, shift} (+ one amortized correction per 128-block);
sign-ext = {and, shift, subtract×2} per byte.
"""

from __future__ import annotations

import re
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantizer as Q
from repro.kernels import ref
from repro.kernels import w4ax_matmul as WK

VECTOR_OPS = ("stablehlo.and", "stablehlo.or", "stablehlo.add",
              "stablehlo.subtract", "stablehlo.shift_right_logical",
              "stablehlo.shift_right_arithmetic", "stablehlo.shift_left")


def count_unpack_ops(conversion: str) -> int:
    packed = jnp.zeros((64, 128), jnp.uint8)

    if conversion == "zeroext":
        fn = lambda p: WK._unpack_zeroext_rows(p)
    else:
        fn = lambda p: WK._unpack_signext_rows(p)
    hlo = jax.jit(fn).lower(packed).as_text()
    return sum(hlo.count(op) for op in VECTOR_OPS)


def run():
    ops_zero = count_unpack_ops("zeroext")
    ops_sign = count_unpack_ops("signext")
    print(f"unpack vector ops: zero-extension={ops_zero} "
          f"sign-extension={ops_sign}")

    # end-to-end: both conversions give identical kernel results
    rng = np.random.default_rng(0)
    m, k, n = 32, 256, 128
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = (rng.normal(size=(k, n)) * 0.05).astype(np.float32)
    q4, s4 = Q.quantize_act_groupwise(jnp.asarray(x), 128, bits=4)
    a4 = Q.pack_int4_interleaved(q4, axis=1, block_size=128)
    wq = Q.quantize_weight_int4(jnp.asarray(w), group_size=128)
    outs = {}
    for conv in ("zeroext", "signext"):
        outs[conv] = np.asarray(WK.w4a4_matmul(
            a4, s4, wq.data, wq.scale, conversion=conv, interpret=True))
    np.testing.assert_allclose(outs["zeroext"], outs["signext"],
                               rtol=1e-5, atol=1e-4)
    print("zero-ext and sign-ext kernels agree (allclose)")
    return ops_zero, ops_sign


def main():
    t0 = time.time()
    print("\n== §4.3 fast INT4→INT8 conversion ablation ==")
    oz, os_ = run()
    dt = time.time() - t0
    print(f"(paper: 10 → 2 instructions per conversion on CUDA cores)")
    print(f"conversion_ablation,{dt*1e6:.0f},zeroext_ops={oz};"
          f"signext_ops={os_};reduction_ok={oz < os_}")


if __name__ == "__main__":
    main()
