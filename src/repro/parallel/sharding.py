"""Logical-axis → mesh PartitionSpec resolution (DP/FSDP/TP/EP/SP).

Two rule tables:

``TRAIN_RULES``  2-D sharding for training: "embed" (and other fan-in
                 dims) shard over the **data** axis (ZeRO/FSDP — params,
                 grads, and optimizer state are all sharded so 70B-class
                 models fit v5e HBM), TP dims over **model**, batch over
                 (pod, data). Pods are pure DP replicas (gradient
                 all-reduce crosses pods once per step) — the fault
                 containment boundary.

``SERVE_RULES``  latency-oriented pure TP for serving: params replicated
                 over data (no per-layer all-gather on the decode path),
                 TP dims over model, batch over (pod, data); for the
                 batch=1 long-context cell the KV cache time axis shards
                 over data instead (sequence parallelism).

Divisibility: any dim not divisible by its mesh axis size falls back to
replicated (None) for that dim — never a lowering failure.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "TRAIN_RULES",
    "SERVE_RULES",
    "spec_for_axes",
    "tree_pspecs",
    "tree_shardings",
    "batch_spec",
    "cache_pspecs",
    "maybe_shard",
]


def _ambient_mesh():
    """The mesh from either jax.set_mesh or the legacy ``with mesh:``.

    Both probes reach into version-dependent jax surfaces, so each is
    narrowed to the exact failure its jax version produces — a missing
    accessor (older/newer jax) degrades to the next probe; anything
    else is a real bug and propagates."""
    try:
        m = jax.sharding.get_abstract_mesh()
    except AttributeError:      # jax < get_abstract_mesh
        m = None
    if m is not None and not m.empty:
        return m
    try:
        from jax._src.mesh import thread_resources
    except ImportError:         # private module moved/removed
        return None
    pm = getattr(getattr(thread_resources, "env", None),
                 "physical_mesh", None)
    if pm is not None and not pm.empty:
        return pm
    return None


def maybe_shard(x: jax.Array, *axes):
    """with_sharding_constraint that degrades to identity off-mesh.

    ``axes`` entries are mesh axis names (or None); any axis missing from
    the ambient mesh, or not dividing the dim, is dropped. Used by layers
    (e.g. the MoE dispatch buffer) to pin internal activation shardings
    without making the layer mesh-dependent.
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    spec = []
    used = set()
    for dim, name in zip(x.shape, axes):
        if (name is None or name not in mesh.axis_names or name in used
                or (mesh.shape[name] and dim % mesh.shape[name] != 0)):
            spec.append(None)
        else:
            spec.append(name)
            used.add(name)
    return jax.lax.with_sharding_constraint(x, P(*spec))

TRAIN_RULES = {
    "embed": "data",
    "vocab": "model",
    "heads": "model",
    "kv": "model",
    "qdim": "model",
    "kvdim": "model",
    "mlp": "model",
    "experts": "model",
    "layers": None,
    None: None,
}

SERVE_RULES = {
    **TRAIN_RULES,
    "embed": None,          # replicate fan-in dims: no gather on decode path
}


def spec_for_axes(axes: tuple, shape: tuple, mesh: Mesh, rules: dict) -> P:
    """Logical axes tuple (+ concrete shape) → PartitionSpec."""
    out = []
    used = set()
    for dim, name in zip(shape, axes):
        mesh_axis = rules.get(name, None)
        if (
            mesh_axis is None
            or mesh_axis not in mesh.axis_names
            or mesh_axis in used
            or dim % mesh.shape[mesh_axis] != 0
        ):
            out.append(None)
        else:
            out.append(mesh_axis)
            used.add(mesh_axis)
    return P(*out)


def tree_pspecs(axes_tree, params_tree, mesh: Mesh, rules: dict):
    """Axes tree + params tree → PartitionSpec tree."""
    return jax.tree.map(
        lambda a, p: spec_for_axes(a, p.shape, mesh, rules),
        axes_tree, params_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(axes_tree, params_tree, mesh: Mesh, rules: dict):
    specs = tree_pspecs(axes_tree, params_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh) -> P:
    """Batch dimension spec: (pod, data) when the pod axis exists."""
    if "pod" in mesh.axis_names:
        return P(("pod", "data"))
    return P("data")


def _dim_axis(dim: int, mesh: Mesh, axis: str) -> Optional[str]:
    if axis in mesh.axis_names and dim % mesh.shape[axis] == 0:
        return axis
    return None


def cache_pspecs(cache_tree, mesh: Mesh, *, seq_parallel: bool = False,
                 time_shard_model: bool = True):
    """PartitionSpecs for a decode cache pytree (by structural key).

    Leaf layouts (leading L = stacked layers / groups):
      q4:   k_packed/v_packed [L, B, Hkv, T, D/2]; k_scale… [L, B, Hkv, 1, D]
      fp:   k/v [L, B, T, Hkv, D]
      rwkv: s [L, B, H, d, d]; shift_* [L, B, 1, D]
      mamba: ssm [L, B, H, N, P]; conv [L, B, K-1, Ch]
      vlm cross_kv: k/v [L, B, T_img, Hkv, D]
      length [L, B]
      paged q4 (the serving engine's live pools):
            k_pool/v_pool [L, P, ps, Hkv, D/2] — kv heads over "model"
            (page identity is host-global; every shard holds the full
            page set for its head slice), and their static per-channel
            k_scale/k_zero/v_scale/v_zero [Hkv, 1, D] sharded to match
    Batch shards over (pod, data) when divisible; with ``seq_parallel``
    (batch=1 long-context) the cache time axis shards over data instead.

    ``time_shard_model`` (§Perf iteration 1): when the KV-head count does
    not divide the model axis, shard the cache **time** axis over "model"
    instead of replicating — flash-decode over a T-sharded cache is a
    per-shard partial softmax plus an O(B·H·D) combine, and per-device
    cache bytes drop by the model-axis size (the difference between a
    72B 32k-ctx decode cache fitting v5e HBM or not).
    """
    bspec = batch_spec(mesh)
    baxes = bspec[0]

    def t_axis(dim, h_ax):
        axes = []
        if seq_parallel and dim % mesh.shape["data"] == 0:
            axes.append("data")
        if (time_shard_model and h_ax is None
                and "model" in mesh.axis_names
                and dim % (mesh.shape["model"]
                           * (mesh.shape["data"] if axes else 1)) == 0):
            axes.append("model")
        if not axes:
            return None
        return axes[0] if len(axes) == 1 else tuple(axes)

    def leaf_spec(path, leaf):
        name = path[-1] if path else ""
        shape = leaf.shape

        def bdim(i=1):
            if shape[i] % _axes_size(mesh, baxes) == 0 and not seq_parallel:
                return baxes
            return None

        if name in ("k_pool", "v_pool"):
            # paged serving pools [L, P, ps, Hkv, D/2]: ONLY the kv-head
            # dim shards — pages are a host-global namespace (the block
            # tables and work-queue descriptors index physical pages
            # identically on every shard)
            h_ax = _dim_axis(shape[3], mesh, "model")
            return P(None, None, None, h_ax, None)
        if name in ("k_packed", "v_packed"):
            # [L, B, Hkv, T, D/2]
            h_ax = _dim_axis(shape[2], mesh, "model")
            return P(None, bdim(), h_ax, t_axis(shape[3], h_ax), None)
        if name in ("k_scale", "k_zero", "v_scale", "v_zero"):
            if leaf.ndim == 3:
                # paged-pool static scales [Hkv, 1, D]
                h_ax = _dim_axis(shape[0], mesh, "model")
                return P(h_ax, None, None)
            h_ax = _dim_axis(shape[2], mesh, "model")
            return P(None, bdim(), h_ax, None, None)
        if name in ("k", "v"):
            # fp cache or cross_kv: [L, B, T, Hkv, D]
            h_ax = _dim_axis(shape[3], mesh, "model")
            return P(None, bdim(), t_axis(shape[2], h_ax), h_ax, None)
        if name == "s":
            h_ax = _dim_axis(shape[2], mesh, "model")
            return P(None, bdim(), h_ax, None, None)
        if name == "ssm":
            h_ax = _dim_axis(shape[2], mesh, "model")
            return P(None, bdim(), h_ax, None, None)
        if name == "conv":
            c_ax = _dim_axis(shape[3], mesh, "model")
            return P(None, bdim(), None, c_ax)
        if name in ("shift_tm", "shift_cm"):
            return P(None, bdim(), None, None)
        if name == "length":
            return P(None, bdim())
        return P(*([None] * leaf.ndim))

    return _map_with_path(cache_tree, leaf_spec)


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _map_with_path(tree, fn, path=()):
    if isinstance(tree, dict):
        return {k: _map_with_path(v, fn, path + (k,)) for k, v in tree.items()}
    return fn(path, tree)
