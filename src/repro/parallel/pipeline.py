"""GPipe-style pipeline parallelism over a stage-stacked layer stack.

For >2-pod scaling the layer dimension becomes the natural third
parallelism axis. This module implements the standard single-program
JAX pipelining pattern: layers are split into S equal stages whose
parameters carry a leading stage axis (sharded over the mesh's "stage"
axis); every pipeline tick runs all stages in parallel via vmap (each
stage on its own devices under SPMD) and shifts activations one stage
down — the shift lowers to a `collective_permute` between stage shards.
A microbatched input stream of M microbatches drains in M + S − 1 ticks
(the classic GPipe bubble of (S−1)/(M+S−1)).

This composes with the existing DP/TP axes: the mesh becomes
(stage, data, model) and the per-stage block params keep their TP specs.

`pipeline_apply` is family-agnostic: it takes any per-layer block apply
function (signature (block_params, x) → x) and the scanned layer stack.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["stage_params", "pipeline_apply"]


def stage_params(stacked_params, num_stages: int):
    """[L, ...] layer-stacked tree → [S, L/S, ...] stage-stacked tree."""
    def reshape(a):
        l = a.shape[0]
        assert l % num_stages == 0, (l, num_stages)
        return a.reshape(num_stages, l // num_stages, *a.shape[1:])
    return jax.tree.map(reshape, stacked_params)


def pipeline_apply(block_fn, staged_params, x_micro, *, unroll_stage=False):
    """Run the pipeline. x_micro: [M, mb, ...] microbatched activations.

    block_fn(block_params, x) applies ONE layer; each stage applies its
    L/S layers with an inner lax.scan. Returns [M, mb, ...] outputs.
    """
    num_stages = jax.tree.leaves(staged_params)[0].shape[0]
    m = x_micro.shape[0]
    ticks = m + num_stages - 1
    mb_shape = x_micro.shape[1:]

    def stage_apply(params_s, h):
        def body(h, bp):
            return block_fn(bp, h), None
        h, _ = jax.lax.scan(body, h, params_s)
        return h

    v_stage = jax.vmap(stage_apply)          # over the stage axis

    def tick(carry, t):
        prev_outs = carry                     # [S, mb, ...] last tick's outs
        # stage 0 ingests microbatch t (zeros once the stream is drained);
        # stage s>0 ingests stage s-1's previous output — a shift that
        # lowers to a collective_permute between stage shards under SPMD.
        nxt = jnp.where(t < m, x_micro[jnp.minimum(t, m - 1)],
                        jnp.zeros(mb_shape, x_micro.dtype))
        bufs = jnp.concatenate([nxt[None], prev_outs[:-1]], axis=0)
        outs = v_stage(staged_params, bufs)   # all stages advance together
        return outs, outs[-1]                 # last stage's output each tick

    outs0 = jnp.zeros((num_stages, *mb_shape), x_micro.dtype)
    _, drained = jax.lax.scan(tick, outs0, jnp.arange(ticks))
    # microbatch i enters stage 0 at tick i and exits at tick i + S - 1
    return drained[num_stages - 1:]


def pipeline_bubble_fraction(num_stages: int, num_micro: int) -> float:
    return (num_stages - 1) / (num_micro + num_stages - 1)
