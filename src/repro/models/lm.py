"""Model assembly for all assigned architecture families.

Families: dense | moe | hybrid (zamba2) | ssm (rwkv6) | audio (encoder) |
vlm (cross-attn). Layer stacks are `lax.scan`-scanned over stacked
parameters (leading "layers" axis) so the HLO stays compact for 54–100
layer configs; hybrid/vlm use a two-level (python-group × inner-scan)
layout around their shared/periodic blocks.

Two parameter modes, same code path:
  fp     — bf16-compute training/serving.
  quant  — COMET W4AxKV4 serving: ``LM.quantize`` structurally replaces
           every block projection's ``{"w": ...}`` with packed W4 payloads
           (``{"w_packed", "w_scale"}``); ``layers.common.linear``
           dispatches on that structure into the W4Ax GEMM, and the KV
           cache becomes the packed int4 cache. Scan-uniform INT4
           fraction comes from ``QuantConfig.int4_fraction``.

API (pure functions; ``LM`` only holds static config):
  lm = LM(cfg, quant=None | QuantConfig(...))
  params, axes = lm.init(key)
  qparams, qaxes = lm.quantize(params, axes)           # offline PTQ
  logits, aux = lm.train_logits(params, tokens, extra)
  logits, cache = lm.prefill(params, tokens, cache, extra)
  logits, cache = lm.decode(params, tokens, cache)
  cache = lm.init_cache(batch, max_len)
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import qlinear as QL
from repro.layers import attention as ATT
from repro.layers import common as C
from repro.layers import mamba2 as M2
from repro.layers import mlp as MLP
from repro.layers import rwkv6 as RW
from repro.layers.common import Annotated

__all__ = ["LM", "QuantConfig"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    int4_fraction: float = 0.875     # scan-uniform W4A4 block fraction
    schedule: str = "split"          # split | mixed (paper baseline)
    impl: str = "auto"               # kernel impl: auto | pallas | ref
    kv4: bool = True                 # int4 KV cache vs bf16
    weight_group: int = 128
    weight_only: bool = False        # W4A16 baseline mode


QUANT_KEYS = frozenset({
    "wq", "wk", "wv", "wo", "w_up", "w_gate", "w_down",
    "w_r", "w_k", "w_v", "w_g", "w_o", "in_proj", "out_proj",
})


def _stack_layers(trees):
    """List of Annotated trees → one tree with leading 'layers' axis."""
    def stack(*leaves):
        vals = jnp.stack([l.value for l in leaves])
        return Annotated(vals, ("layers",) + leaves[0].axes)
    return jax.tree.map(stack, *trees, is_leaf=C.is_annotated)


class LM:
    def __init__(self, cfg: ModelConfig, quant: Optional[QuantConfig] = None):
        self.cfg = cfg
        self.quant = quant
        if cfg.family == "hybrid":
            assert cfg.num_layers % cfg.attn_period == 0
            self.n_groups = cfg.num_layers // cfg.attn_period
        elif cfg.family == "vlm":
            assert cfg.num_layers % cfg.cross_attn_period == 0
            self.n_groups = cfg.num_layers // cfg.cross_attn_period
            self.self_per_group = cfg.cross_attn_period - 1
        else:
            self.n_groups = 0

    def _ctx(self):
        if self.quant is None:
            return contextlib.nullcontext()
        return QL.quant_runtime(QL.QuantRuntime(
            int4_fraction=self.quant.int4_fraction,
            schedule=self.quant.schedule,
            impl=self.quant.impl,
            weight_only=self.quant.weight_only,
        ))

    # ------------------------------------------------------------------ init

    def init(self, key):
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        tree: dict = {}
        if cfg.family != "audio":
            tree["embed"] = C.init_embedding(keys[0], cfg.vocab_size, cfg.d_model)
        else:
            # stub frontend: conv positional embedding over frame embeddings
            tree["conv_pos"] = {
                "w": Annotated(
                    0.02 * jax.random.normal(
                        keys[0], (cfg.conv_pos_width, cfg.d_model), jnp.float32),
                    (None, "embed")),
                "b": Annotated(jnp.zeros((cfg.d_model,), jnp.float32), ("embed",)),
            }
        tree["final_norm"] = C.init_norm(cfg.norm, cfg.d_model)
        if not cfg.tie_embeddings:
            tree["lm_head"] = C.init_linear(
                keys[1], cfg.d_model, cfg.vocab_size, ("embed", "vocab"))

        lkeys = jax.random.split(keys[2], max(cfg.num_layers, 1))
        fam = cfg.family
        if fam in ("dense", "audio"):
            tree["blocks"] = _stack_layers(
                [self._init_dense_block(lkeys[i]) for i in range(cfg.num_layers)])
        elif fam == "moe":
            tree["blocks"] = _stack_layers(
                [self._init_moe_block(lkeys[i]) for i in range(cfg.num_layers)])
        elif fam == "ssm":
            tree["blocks"] = _stack_layers(
                [self._init_rwkv_block(lkeys[i]) for i in range(cfg.num_layers)])
        elif fam == "hybrid":
            tree["blocks"] = _stack_layers(
                [self._init_mamba_block(lkeys[i]) for i in range(cfg.num_layers)])
            tree["shared_attn"] = self._init_shared_attn(keys[3])
        elif fam == "vlm":
            n_self = self.n_groups * self.self_per_group
            tree["blocks"] = _stack_layers(
                [self._init_dense_block(lkeys[i]) for i in range(n_self)])
            ckeys = jax.random.split(keys[4], self.n_groups)
            tree["cross_blocks"] = _stack_layers(
                [self._init_cross_block(ckeys[i]) for i in range(self.n_groups)])
        else:
            raise ValueError(fam)
        return C.split_annotations(tree)

    def _init_dense_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "attn_norm": C.init_norm(cfg.norm, cfg.d_model),
            "attn": ATT.init_attention(k1, cfg),
            "mlp_norm": C.init_norm(cfg.norm, cfg.d_model),
            "mlp": MLP.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act),
        }

    def _init_moe_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "attn_norm": C.init_norm(cfg.norm, cfg.d_model),
            "attn": ATT.init_attention(k1, cfg),
            "mlp_norm": C.init_norm(cfg.norm, cfg.d_model),
            "moe": MLP.init_moe(k2, cfg),
        }

    def _init_rwkv_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "tm_norm": C.init_norm("layernorm", cfg.d_model),
            "tmix": RW.init_rwkv6(k1, cfg),
            "cm_norm": C.init_norm("layernorm", cfg.d_model),
            "cmix": RW.init_rwkv6_cmix(k2, cfg),
        }

    def _init_mamba_block(self, key):
        cfg = self.cfg
        return {
            "norm": C.init_norm(cfg.norm, cfg.d_model),
            "mamba": M2.init_mamba2(key, cfg),
        }

    def _init_shared_attn(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "attn_norm": C.init_norm(cfg.norm, cfg.d_model),
            "attn": ATT.init_attention(k1, cfg),
            "mlp_norm": C.init_norm(cfg.norm, cfg.d_model),
            "mlp": MLP.init_mlp(k2, cfg.d_model, cfg.d_ff, "swiglu"),
        }

    def _init_cross_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "attn_norm": C.init_norm(cfg.norm, cfg.d_model),
            "attn": ATT.init_attention(k1, cfg, cross=True),
            "mlp_norm": C.init_norm(cfg.norm, cfg.d_model),
            "mlp": MLP.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act),
            "gate": Annotated(jnp.zeros((), jnp.float32), ()),
        }

    # ------------------------------------------------------- offline PTQ

    def quantize(self, params, axes):
        """fp params → packed W4 params (structural tree transform).

        Embedding table and LM head are stored bf16 for serving (§Perf
        cell A, iteration 4): they stay unquantized per the paper, but f32
        storage would double their decode-step HBM reads for no accuracy
        benefit (compute is bf16 anyway).
        """
        assert self.quant is not None
        qcfg = self.quant
        params = dict(params)
        if "embed" in params:
            params["embed"] = {
                "table": params["embed"]["table"].astype(jnp.bfloat16)}
        if "lm_head" in params:
            lh = dict(params["lm_head"])
            lh["w"] = lh["w"].astype(jnp.bfloat16)
            params["lm_head"] = lh

        def transform(p, a):
            if not isinstance(p, dict):
                return p, a
            out_p, out_a = {}, {}
            for key, val in p.items():
                quantizable = (
                    key in QUANT_KEYS and isinstance(val, dict) and "w" in val
                    and val["w"].shape[-2] % QL.BLOCK_K == 0
                )
                if quantizable:
                    w = val["w"]
                    lead = w.shape[:-2]
                    k, n = w.shape[-2:]
                    w2 = w.reshape(-1, k, n)
                    packed, scale = jax.vmap(
                        lambda wi: _quant_one(wi, qcfg))(w2)
                    packed = packed.reshape(*lead, k // 2, n)
                    scale = scale.reshape(*lead, k // QL.BLOCK_K, n)
                    nd = {"w_packed": packed, "w_scale": scale}
                    na = {"w_packed": a[key]["w"], "w_scale": a[key]["w"]}
                    if "b" in val:
                        nd["b"], na["b"] = val["b"], a[key]["b"]
                    out_p[key], out_a[key] = nd, na
                elif isinstance(val, dict):
                    out_p[key], out_a[key] = transform(val, a[key])
                else:
                    out_p[key], out_a[key] = val, a[key]
            return out_p, out_a

        return transform(params, axes)

    # ------------------------------------------------------- block pieces

    def _attn_mlp_block(self, bp, x, mode, cache, positions=None, aux=0.0):
        cfg = self.cfg
        h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
        new_cache = None
        if mode == "train":
            a = ATT.attention_train(bp["attn"], cfg, h, positions)
        elif mode == "prefill":
            if "k_packed" in cache:
                a, new_cache = ATT.attention_prefill_q4(
                    bp["attn"], cfg, h, cache, positions)
            else:
                a, new_cache = ATT.attention_prefill(
                    bp["attn"], cfg, h, cache, positions)
        else:
            if "k_packed" in cache:
                a, new_cache = ATT.attention_decode_q4(
                    bp["attn"], cfg, h, cache,
                    impl=self.quant.impl if self.quant else "auto")
            else:
                a, new_cache = ATT.attention_decode_fp(bp["attn"], cfg, h, cache)
        x = x + a
        h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
        if "moe" in bp:
            y, l_aux = MLP.moe_apply(bp["moe"], h, cfg)
            aux = aux + l_aux
        else:
            y = MLP.mlp_apply(bp["mlp"], h, cfg.mlp_act)
        x = x + y
        return x, new_cache, aux

    # ------------------------------------------------------- cache init

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        quantized = self.quant is not None and self.quant.kv4

        def attn_cache():
            if quantized:
                return ATT.init_q4_cache(cfg, batch, max_len)
            return ATT.init_fp_cache(cfg, batch, max_len)

        def stack(n, fn):
            one = fn()
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n, *a.shape)).copy(), one)

        fam = cfg.family
        if fam in ("dense", "moe"):
            return {"attn": stack(cfg.num_layers, attn_cache)}
        if fam == "ssm":
            return {"rwkv": stack(cfg.num_layers,
                                  lambda: RW.init_rwkv6_state(cfg, batch))}
        if fam == "hybrid":
            return {
                "mamba": stack(cfg.num_layers,
                               lambda: M2.init_mamba2_state(cfg, batch)),
                "shared_attn": stack(self.n_groups, attn_cache),
            }
        if fam == "vlm":
            img = cfg.num_image_tokens

            def cross_kv():
                shp = (batch, img, cfg.num_kv_heads, cfg.head_dim)
                return {"k": jnp.zeros(shp, jnp.bfloat16),
                        "v": jnp.zeros(shp, jnp.bfloat16)}

            return {
                "attn": stack(self.n_groups * self.self_per_group, attn_cache),
                "cross_kv": stack(self.n_groups, cross_kv),
            }
        if fam == "audio":
            return {}
        raise ValueError(fam)

    # ------------------------------------------------------- forward passes

    def _embed(self, params, tokens):
        x = jnp.take(params["embed"]["table"], tokens, axis=0)
        return x.astype(jnp.bfloat16)

    def _head(self, params, x):
        if self.cfg.tie_embeddings:
            w = params["embed"]["table"].astype(jnp.bfloat16)
            return (x @ w.T).astype(jnp.float32)
        return C.linear(params["lm_head"], x).astype(jnp.float32)

    def train_logits(self, params, tokens, extra=None):
        """Returns (logits [B, S, V] f32, aux scalar)."""
        with self._ctx():
            hidden, aux = self._train_hidden(params, tokens, extra)
            return self._head(params, hidden), aux

    def train_hidden(self, params, tokens, extra=None):
        """Backbone forward up to (incl.) final norm: (hidden, aux).

        Used by the chunked-CE training loss so the full [B, S, V] logits
        are never materialized.
        """
        with self._ctx():
            return self._train_hidden(params, tokens, extra)

    def _train_hidden(self, params, tokens, extra):
        cfg = self.cfg
        fam = cfg.family
        if fam == "audio":
            x = extra["frames"].astype(jnp.bfloat16)      # [B, T, D]
            x = x + _conv_pos(params["conv_pos"], x)
        else:
            x = self._embed(params, tokens)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        aux = jnp.zeros((), jnp.float32)

        if fam in ("dense", "moe", "audio"):
            def body(carry, bp):
                h, aux = carry
                h, _, aux = self._attn_mlp_block(bp, h, "train", None,
                                                 positions, aux)
                return (h, aux), None
            (x, aux), _ = jax.lax.scan(
                jax.checkpoint(body), (x, aux), params["blocks"])
        elif fam == "ssm":
            def body(carry, bp):
                h, aux = carry
                return (self._rwkv_block_train(bp, h), aux), None
            (x, aux), _ = jax.lax.scan(
                jax.checkpoint(body), (x, aux), params["blocks"])
        elif fam == "hybrid":
            x = self._hybrid_forward(params, x, positions, "train")
        elif fam == "vlm":
            x, aux = self._vlm_forward(params, x, positions, extra, aux)
        else:
            raise ValueError(fam)

        x = C.apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
        return x, aux

    def _rwkv_block_train(self, bp, x):
        cfg = self.cfg
        h = C.apply_norm(bp["tm_norm"], x, "layernorm", cfg.norm_eps)
        y, _ = RW.rwkv6_train(bp["tmix"], cfg, h)
        x = x + y
        h = C.apply_norm(bp["cm_norm"], x, "layernorm", cfg.norm_eps)
        y, _ = RW.rwkv6_cmix(
            bp["cmix"], cfg, h,
            jnp.zeros((x.shape[0], 1, cfg.d_model), x.dtype))
        return x + y

    # hybrid (zamba2): groups of (shared attn block → attn_period mamba layers)
    def _hybrid_forward(self, params, x, positions, mode, cache=None):
        cfg = self.cfg
        per = cfg.attn_period
        blocks = params["blocks"]
        new_mamba, new_attn = [], []
        from repro.parallel.sharding import maybe_shard
        for gi in range(self.n_groups):
            # §Perf cell C iteration 4 (sequence parallelism): the
            # residual stream [B, L, d_model] otherwise replicates over
            # the model axis — at 2.7B×4k×16/dev it dominates train HBM
            # traffic. Shard L over "model" between blocks; XLA gathers
            # at the attention/SSD boundaries that need full sequence.
            # (no-op at decode where L == 1.)
            x = maybe_shard(x, "data", "model", None)
            sl = jax.tree.map(lambda a: a[gi * per:(gi + 1) * per], blocks)
            sp = params["shared_attn"]
            h = C.apply_norm(sp["attn_norm"], x, cfg.norm, cfg.norm_eps)
            if mode == "train":
                a = ATT.attention_train(sp["attn"], cfg, h, positions)
            else:
                c = jax.tree.map(lambda a: a[gi], cache["shared_attn"])
                if mode == "prefill":
                    if "k_packed" in c:
                        a, nc = ATT.attention_prefill_q4(
                            sp["attn"], cfg, h, c, positions)
                    else:
                        a, nc = ATT.attention_prefill(
                            sp["attn"], cfg, h, c, positions)
                else:
                    if "k_packed" in c:
                        a, nc = ATT.attention_decode_q4(
                            sp["attn"], cfg, h, c,
                            impl=self.quant.impl if self.quant else "auto")
                    else:
                        a, nc = ATT.attention_decode_fp(sp["attn"], cfg, h, c)
                new_attn.append(nc)
            x = x + a
            h = C.apply_norm(sp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
            x = x + MLP.mlp_apply(sp["mlp"], h, "swiglu")

            if mode == "train":
                def mbody(h, bp):
                    hn = C.apply_norm(bp["norm"], h, cfg.norm, cfg.norm_eps)
                    return h + M2.mamba2_train(bp["mamba"], cfg, hn), None
                x, _ = jax.lax.scan(jax.checkpoint(mbody), x, sl)
            elif mode == "prefill":
                def pbody(h, bp):
                    hn = C.apply_norm(bp["norm"], h, cfg.norm, cfg.norm_eps)
                    y, st = M2.mamba2_train(bp["mamba"], cfg, hn,
                                            return_state=True)
                    return h + y, st
                x, sts = jax.lax.scan(pbody, x, sl)
                new_mamba.append(sts)
            else:
                mc = jax.tree.map(
                    lambda a: a[gi * per:(gi + 1) * per], cache["mamba"])
                def dbody(h, bp_c):
                    bp, c = bp_c
                    hn = C.apply_norm(bp["norm"], h, cfg.norm, cfg.norm_eps)
                    y, nc = M2.mamba2_decode(bp["mamba"], cfg, hn, c)
                    return h + y, nc
                x, ncs = jax.lax.scan(dbody, x, (sl, mc))
                new_mamba.append(ncs)
        if mode == "train":
            return x
        new_cache = {
            "mamba": jax.tree.map(
                lambda *xs: jnp.concatenate(xs, 0), *new_mamba),
            "shared_attn": jax.tree.map(
                lambda *xs: jnp.stack(xs, 0), *new_attn),
        }
        return x, new_cache

    # vlm: groups of (self_per_group self layers → 1 gated cross layer)
    def _vlm_forward(self, params, x, positions, extra, aux,
                     mode="train", cache=None):
        cfg = self.cfg
        spg = self.self_per_group
        img = (extra["image_embeds"].astype(jnp.bfloat16)
               if extra is not None else None)
        new_self, new_cross = [], []
        for gi in range(self.n_groups):
            sl = jax.tree.map(
                lambda a: a[gi * spg:(gi + 1) * spg], params["blocks"])
            if mode == "train":
                def body(carry, bp):
                    h, aux = carry
                    h, _, aux = self._attn_mlp_block(
                        bp, h, "train", None, positions, aux)
                    return (h, aux), None
                (x, aux), _ = jax.lax.scan(jax.checkpoint(body), (x, aux), sl)
            else:
                cl = jax.tree.map(
                    lambda a: a[gi * spg:(gi + 1) * spg], cache["attn"])
                def body(carry, bp_c):
                    h, aux = carry
                    bp, c = bp_c
                    h, nc, aux = self._attn_mlp_block(
                        bp, h, mode, c, positions, aux)
                    return (h, aux), nc
                (x, aux), ncs = jax.lax.scan(body, (x, aux), (sl, cl))
                new_self.append(ncs)

            cb = jax.tree.map(lambda a: a[gi], params["cross_blocks"])
            h = C.apply_norm(cb["attn_norm"], x, cfg.norm, cfg.norm_eps)
            if mode == "decode":
                ckv = jax.tree.map(lambda a: a[gi], cache["cross_kv"])
                a = _cross_decode(cfg, cb["attn"], h, ckv)
                new_cross.append(ckv)
            else:
                a = ATT.attention_train(cb["attn"], cfg, h, positions,
                                        kv_override=img)
                if mode == "prefill":
                    new_cross.append(_cross_kv(cfg, cb["attn"], img))
            x = x + jnp.tanh(cb["gate"]).astype(x.dtype) * a
            h = C.apply_norm(cb["mlp_norm"], x, cfg.norm, cfg.norm_eps)
            x = x + MLP.mlp_apply(cb["mlp"], h, cfg.mlp_act)
        if mode == "train":
            return x, aux
        new_cache = {
            "attn": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_self),
            "cross_kv": jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_cross),
        }
        return x, aux, new_cache

    # ------------------------------------------------------- prefill / decode

    def prefill(self, params, tokens, cache, extra=None):
        with self._ctx():
            return self._prefill(params, tokens, cache, extra)

    def _prefill(self, params, tokens, cache, extra):
        cfg = self.cfg
        fam = cfg.family
        if fam == "audio":
            raise ValueError("encoder-only model has no prefill/decode")
        x = self._embed(params, tokens)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        aux = jnp.zeros((), jnp.float32)

        if fam in ("dense", "moe"):
            def body(carry, bp_c):
                h, aux = carry
                bp, c = bp_c
                h, nc, aux = self._attn_mlp_block(
                    bp, h, "prefill", c, positions, aux)
                return (h, aux), nc
            (x, aux), ncs = jax.lax.scan(
                body, (x, aux), (params["blocks"], cache["attn"]))
            new_cache = {"attn": ncs}
        elif fam == "ssm":
            def body(carry, bp_c):
                h, aux = carry
                bp, c = bp_c
                h, nc = self._rwkv_block_prefill(bp, h, c)
                return (h, aux), nc
            (x, aux), ncs = jax.lax.scan(
                body, (x, aux), (params["blocks"], cache["rwkv"]))
            new_cache = {"rwkv": ncs}
        elif fam == "hybrid":
            x, new_cache = self._hybrid_forward(
                params, x, positions, "prefill", cache)
        elif fam == "vlm":
            x, aux, new_cache = self._vlm_forward(
                params, x, positions, extra, aux, "prefill", cache)
        else:
            raise ValueError(fam)

        x = C.apply_norm(params["final_norm"], x[:, -1:], cfg.norm, cfg.norm_eps)
        return self._head(params, x), new_cache

    def _rwkv_block_prefill(self, bp, x, c):
        cfg = self.cfg
        h = C.apply_norm(bp["tm_norm"], x, "layernorm", cfg.norm_eps)
        y, tm = RW.rwkv6_train(bp["tmix"], cfg, h, {"shift_tm": c["shift_tm"]})
        x = x + y
        h = C.apply_norm(bp["cm_norm"], x, "layernorm", cfg.norm_eps)
        y, cm_shift = RW.rwkv6_cmix(bp["cmix"], cfg, h, c["shift_cm"])
        x = x + y
        return x, {"s": tm["s"], "shift_tm": tm["shift_tm"],
                   "shift_cm": cm_shift}

    def decode(self, params, tokens, cache):
        """tokens: [B, 1] int32 → (logits [B, 1, V], new cache)."""
        with self._ctx():
            return self._decode(params, tokens, cache)

    def _decode(self, params, tokens, cache):
        cfg = self.cfg
        fam = cfg.family
        x = self._embed(params, tokens)
        aux = jnp.zeros((), jnp.float32)

        if fam in ("dense", "moe"):
            def body(carry, bp_c):
                h, aux = carry
                bp, c = bp_c
                h, nc, aux = self._attn_mlp_block(bp, h, "decode", c, None, aux)
                return (h, aux), nc
            (x, aux), ncs = jax.lax.scan(
                body, (x, aux), (params["blocks"], cache["attn"]))
            new_cache = {"attn": ncs}
        elif fam == "ssm":
            def body(carry, bp_c):
                h, aux = carry
                bp, c = bp_c
                h, nc = self._rwkv_block_decode(bp, h, c)
                return (h, aux), nc
            (x, aux), ncs = jax.lax.scan(
                body, (x, aux), (params["blocks"], cache["rwkv"]))
            new_cache = {"rwkv": ncs}
        elif fam == "hybrid":
            x, new_cache = self._hybrid_forward(params, x, None, "decode", cache)
        elif fam == "vlm":
            x, aux, new_cache = self._vlm_forward(
                params, x, None, None, aux, "decode", cache)
        else:
            raise ValueError(fam)

        x = C.apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
        return self._head(params, x), new_cache

    def _rwkv_block_decode(self, bp, x, c):
        cfg = self.cfg
        h = C.apply_norm(bp["tm_norm"], x, "layernorm", cfg.norm_eps)
        y, tm = RW.rwkv6_decode(bp["tmix"], cfg, h,
                                {"s": c["s"], "shift_tm": c["shift_tm"]})
        x = x + y
        h = C.apply_norm(bp["cm_norm"], x, "layernorm", cfg.norm_eps)
        y, cm_shift = RW.rwkv6_cmix(bp["cmix"], cfg, h, c["shift_cm"])
        x = x + y
        return x, {"s": tm["s"], "shift_tm": tm["shift_tm"],
                   "shift_cm": cm_shift}


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _quant_one(w, qcfg: QuantConfig):
    qp, _ = QL.quantize_linear_fraction(
        w, int4_fraction=qcfg.int4_fraction,
        schedule=qcfg.schedule, impl=qcfg.impl)
    return qp["w_packed"].value, qp["w_scale"].value


def _conv_pos(params, x):
    """Depthwise conv positional embedding (HuBERT)."""
    w, b = params["w"], params["b"]                     # [K, D], [D]
    k = w.shape[0]
    pad = jnp.pad(x.astype(jnp.float32),
                  ((0, 0), (k // 2, k - 1 - k // 2), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.gelu(out + b).astype(x.dtype)


def _cross_kv(cfg: ModelConfig, ap, img):
    """Project image embeddings to cross-attn KV once (prefill)."""
    b = img.shape[0]
    k = C.linear(ap["wk"], img).reshape(b, -1, cfg.num_kv_heads, cfg.head_dim)
    v = C.linear(ap["wv"], img).reshape(b, -1, cfg.num_kv_heads, cfg.head_dim)
    return {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}


def _cross_decode(cfg: ModelConfig, ap, x, ckv):
    """Decode-step cross attention against cached image KV. x: [B, 1, D].

    The cached image KV stays bf16 end-to-end with f32 MXU accumulation
    (``preferred_element_type``) — materializing an f32 upcast of the
    [groups, B, T_img, Hkv, D] cache costs ~100 GB of spurious HBM
    traffic on the 90B decode cell (§Perf cell B, iteration 2).
    """
    b = x.shape[0]
    q = C.linear(ap["wq"], x).reshape(b, cfg.num_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = C.rmsnorm(q, ap["q_norm"]["scale"], cfg.norm_eps)
    g = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(b, cfg.num_kv_heads, g, cfg.head_dim).astype(jnp.bfloat16)
    import math
    sm = jnp.bfloat16(1.0 / math.sqrt(cfg.head_dim))
    sc = jnp.einsum("bhgd,bThd->bhgT", qg * sm, ckv["k"],
                    preferred_element_type=jnp.float32)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgT,bThd->bhgd", p.astype(jnp.bfloat16), ckv["v"],
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, cfg.q_dim).astype(x.dtype)
    return C.linear(ap["wo"], o)
