"""jit'd public wrappers around the COMET kernels.

Every op takes ``impl`` ∈ {"auto", "pallas", "ref"}:

* ``auto``   — Pallas on TPU backends, pure-jnp reference elsewhere
               (CPU dry-run lowering, tests of the ref path). The ref
               consumes identical packed buffers, so XLA cost/memory
               analysis of the serving graph reflects true packed bytes.
* ``pallas`` — force the Pallas kernel (``interpret=True`` off-TPU).
* ``ref``    — force the jnp oracle.

Shape policy: wrappers accept [..., K] activations, flatten leading dims
to M, pad M up to the tile multiple, and strip padding on return.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels import w4ax_matmul as WK
from repro.kernels import kv4_attention as AK
from repro.kernels import paged_attention as PK
from repro.kernels import act_quant as QK

BLOCK_K = WK.BLOCK_K

__all__ = [
    "w4ax_matmul",
    "kv4_decode_attention",
    "paged_kv4_decode_attention",
    "paged_kv4_prefill_attention",
    "paged_kv4_decode_attention_wq",
    "paged_kv4_prefill_attention_wq",
    "act_quant",
    "default_impl",
]


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(impl: str):
    """→ (use_pallas: bool, interpret: bool)."""
    if impl == "auto":
        impl = default_impl()
    if impl == "pallas":
        return True, jax.default_backend() != "tpu"
    if impl == "ref":
        return False, False
    raise ValueError(f"impl must be auto|pallas|ref, got {impl}")


def _pad_rows(x: jax.Array, multiple: int):
    m = x.shape[0]
    pad = (-m) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, m


# ---------------------------------------------------------------------------
# W4Ax GEMM
# ---------------------------------------------------------------------------

def w4ax_matmul(
    a4_packed: jax.Array,   # [..., K4/2] uint8
    a4_scale: jax.Array,    # [..., K4/128] f32
    a8_q: jax.Array,        # [..., K8] int8
    a8_scale: jax.Array,    # [..., K8/128] f32
    w_packed: jax.Array,    # [K/2, N] uint8
    w_scale: jax.Array,     # [K/128, N] f32
    *,
    schedule: str = "split",     # "split" (optimized) | "mixed" (paper baseline)
    impl: str = "auto",
    bm: int = 128,
    bn: int = 128,
) -> jax.Array:
    """Mixed-precision W4Ax GEMM: out = dequant(a) @ dequant(w). → [..., N] f32."""
    lead = a4_packed.shape[:-1]
    n = w_packed.shape[1]
    m_lead = math.prod(lead) if lead else 1

    a4p = a4_packed.reshape(m_lead, a4_packed.shape[-1])
    a4s = a4_scale.reshape(m_lead, a4_scale.shape[-1])
    a8q = a8_q.reshape(m_lead, a8_q.shape[-1])
    a8s = a8_scale.reshape(m_lead, a8_scale.shape[-1])

    use_pallas, interp = _resolve(impl)
    nb4 = a4s.shape[1] if a4p.shape[1] else 0
    k4p = nb4 * WK.PACKED_BLOCK

    if not use_pallas:
        out = R.w4ax_matmul_ref(
            a4p, a4s, a8q, a8s,
            w_packed[:k4p], w_scale[:nb4],
            w_packed[k4p:], w_scale[nb4:],
        )
        return out.reshape(*lead, n)

    m0 = a4p.shape[0] if a4p.shape[1] else a8q.shape[0]
    eff_bm = min(bm, max(8, 1 << (m0 - 1).bit_length())) if m0 else bm
    a4p, m = _pad_rows(a4p, eff_bm)
    a4s, _ = _pad_rows(a4s, eff_bm)
    a8q, _ = _pad_rows(a8q, eff_bm)
    a8s, _ = _pad_rows(a8s, eff_bm)
    if schedule == "split":
        out = WK.w4ax_matmul_split(
            a4p, a4s, a8q, a8s, w_packed, w_scale,
            bm=eff_bm, bn=bn, interpret=interp,
        )
    elif schedule == "mixed":
        out = WK.w4ax_matmul_mixed(
            a4p, a4s, a8q, a8s, w_packed, w_scale,
            bm=eff_bm, bn=bn, interpret=interp,
        )
    else:
        raise ValueError(f"schedule must be split|mixed, got {schedule}")
    return out[:m].reshape(*lead, n)


# ---------------------------------------------------------------------------
# KV4 decode attention
# ---------------------------------------------------------------------------

def kv4_decode_attention(
    q: jax.Array,
    k_packed: jax.Array,
    k_scale: jax.Array,
    k_zero: jax.Array,
    v_packed: jax.Array,
    v_scale: jax.Array,
    v_zero: jax.Array,
    length: jax.Array | None = None,
    *,
    impl: str = "auto",
    bt: int = 256,
) -> jax.Array:
    use_pallas, interp = _resolve(impl)
    t = k_packed.shape[2]
    if length is None:
        length = jnp.full((q.shape[0],), t, jnp.int32)
    if not use_pallas:
        return R.kv4_decode_attention_ref(
            q, k_packed, k_scale, k_zero, v_packed, v_scale, v_zero, length,
            compute_dtype=jnp.bfloat16,
        )
    bt = min(bt, t)
    return AK.kv4_decode_attention(
        q, k_packed, k_scale, k_zero, v_packed, v_scale, v_zero, length,
        bt=bt, interpret=interp,
    )


# ---------------------------------------------------------------------------
# Paged KV4 decode attention (gather-free serving hot path)
# ---------------------------------------------------------------------------

def paged_kv4_decode_attention(
    q: jax.Array,             # [B, Hq, D]
    k_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8
    k_scale: jax.Array,       # [Hkv, 1, D] or [B, Hkv, 1, D]
    k_zero: jax.Array,
    v_pool: jax.Array,
    v_scale: jax.Array,
    v_zero: jax.Array,
    block_tables: jax.Array,  # [B, NP] int32
    length: jax.Array,        # [B] int32
    *,
    impl: str = "auto",
) -> jax.Array:
    """Decode attention straight off the paged pools — no gather_kv.

    The Pallas path resolves ``(seq, logical page) → physical page``
    inside the kernel via scalar-prefetched block tables; the ref path
    gathers pages in jnp (same semantics, used for CPU serving + tests).
    """
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        return R.paged_kv4_decode_attention_ref(
            q, k_pool, k_scale, k_zero, v_pool, v_scale, v_zero,
            block_tables, length,
        )
    return PK.paged_kv4_decode_attention(
        q, k_pool, k_scale, k_zero, v_pool, v_scale, v_zero,
        block_tables, length, interpret=interp,
    )


# ---------------------------------------------------------------------------
# Paged KV4 chunked prefill attention (ragged prompt hot path)
# ---------------------------------------------------------------------------

def paged_kv4_prefill_attention(
    q: jax.Array,             # [B, C, Hq, D] — one prefill chunk's queries
    k_new: jax.Array,         # [B, C, Hkv, D] fp in-flight chunk keys
    v_new: jax.Array,         # [B, C, Hkv, D] fp in-flight chunk values
    k_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8
    k_scale: jax.Array,       # [Hkv, 1, D]
    k_zero: jax.Array,
    v_pool: jax.Array,
    v_scale: jax.Array,
    v_zero: jax.Array,
    block_tables: jax.Array,  # [B, NP] int32
    ctx_lens: jax.Array,      # [B] int32 — tokens already paged
    q_lens: jax.Array,        # [B] int32 — valid chunk tokens (≤ C)
    *,
    impl: str = "auto",
) -> jax.Array:
    """Chunked prefill attention: fp chunk queries over int4 paged history
    plus the causal in-flight fp chunk — the prompt path never holds more
    than one chunk of fp KV. Returns [B, C, Hq, D] f32 (rows past
    ``q_lens`` are padding garbage; mask outside)."""
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        return R.paged_kv4_prefill_attention_ref(
            q, k_new, v_new, k_pool, k_scale, k_zero,
            v_pool, v_scale, v_zero, block_tables, ctx_lens, q_lens,
        )
    return PK.paged_kv4_prefill_attention(
        q, k_new, v_new, k_pool, k_scale, k_zero,
        v_pool, v_scale, v_zero, block_tables, ctx_lens, q_lens,
        interpret=interp,
    )


# ---------------------------------------------------------------------------
# Work-queue (Stream-K) paged attention: flat descriptors + split-KV combine
# ---------------------------------------------------------------------------

def paged_kv4_decode_attention_wq(
    q: jax.Array,             # [B, Hq, D]
    k_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8
    k_scale: jax.Array,       # [Hkv, 1, D] or [B, Hkv, 1, D]
    k_zero: jax.Array,
    v_pool: jax.Array,
    v_scale: jax.Array,
    v_zero: jax.Array,
    work_items: jax.Array,    # [W, 4] int32 (row, phys_page, count, kind)
    *,
    impl: str = "auto",
) -> jax.Array:
    """Work-queue decode attention: the grid walks flat ``(seq, kv_head,
    page)`` descriptors covering only real pages (Stream-K one-to-many
    binding), each emitting a partial flash state merged by the split-KV
    combine — no ``B × max_npages`` padding rectangle. Descriptors come
    from ``serving.kv_cache.build_work_queue``."""
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        return R.paged_kv4_decode_attention_wq_ref(
            q, k_pool, k_scale, k_zero, v_pool, v_scale, v_zero,
            work_items,
        )
    return PK.paged_kv4_decode_attention_wq(
        q, k_pool, k_scale, k_zero, v_pool, v_scale, v_zero,
        work_items, interpret=interp,
    )


def paged_kv4_prefill_attention_wq(
    q: jax.Array,             # [B, C, Hq, D] — one prefill chunk's queries
    k_new: jax.Array,         # [B, C, Hkv, D] fp in-flight chunk keys
    v_new: jax.Array,         # [B, C, Hkv, D] fp in-flight chunk values
    k_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8
    k_scale: jax.Array,       # [Hkv, 1, D]
    k_zero: jax.Array,
    v_pool: jax.Array,
    v_scale: jax.Array,
    v_zero: jax.Array,
    work_items: jax.Array,    # [W, 4] int32 (row, phys_page, count, kind)
    *,
    impl: str = "auto",
) -> jax.Array:
    """Work-queue chunked-prefill attention: same semantics as
    ``paged_kv4_prefill_attention`` (rows past a row's q_len are padding
    garbage — mask outside) but scheduled over flat work items — history
    pages AND the per-row causal fp chunk are uniform entries in one
    divisible pool, so a ragged batch's grid is Σ real work, not
    ``B × (max_npages + 1)``."""
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        return R.paged_kv4_prefill_attention_wq_ref(
            q, k_new, v_new, k_pool, k_scale, k_zero,
            v_pool, v_scale, v_zero, work_items,
        )
    return PK.paged_kv4_prefill_attention_wq(
        q, k_new, v_new, k_pool, k_scale, k_zero,
        v_pool, v_scale, v_zero, work_items, interpret=interp,
    )


# ---------------------------------------------------------------------------
# Activation quantization
# ---------------------------------------------------------------------------

def act_quant(
    x: jax.Array, *, bits: int = 4, impl: str = "auto", bm: int = 256
):
    """[..., K] float → (payload, scales [..., K/128]).

    bits=4 → packed uint8 [..., K/2]; bits=8 → int8 [..., K].
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k).astype(jnp.float32)
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        payload, scale = R.act_quant_ref(x2, block_size=BLOCK_K, bits=bits)
    else:
        x2p, m = _pad_rows(x2, min(bm, max(8, x2.shape[0])))
        eff_bm = min(bm, x2p.shape[0])
        if bits == 4:
            payload, scale = QK.act_quant_int4(x2p, bm=eff_bm, interpret=interp)
        else:
            payload, scale = QK.act_quant_int8(x2p, bm=eff_bm, interpret=interp)
        payload, scale = payload[:m], scale[:m]
    pk = payload.shape[-1]
    return payload.reshape(*lead, pk), scale.reshape(*lead, k // BLOCK_K)
