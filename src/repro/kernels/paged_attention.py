"""Paged KV4 attention (COMET §5 serving path) under two grid
schedules: the dense per-sequence page walk and the Stream-K
**work-queue** schedule with a split-KV combine.

All kernels are gather-free: instead of materializing each sequence's
packed KV contiguously before the kernel (a per-token O(context) copy),
they consume the *physical page pools* directly, with scalar-prefetched
indirection resolved in each BlockSpec index_map before the DMA is
issued — the vLLM/QServe dataflow on TPU.

**Dense schedule** (``paged_kv4_decode_attention`` /
``paged_kv4_prefill_attention``): grid ``(B·Hkv, max_npages)`` — one
lane per output row, walked page-by-page with online softmax in VMEM
scratch. Pages past a sequence's length are skipped (``pl.when``), so
the *compute* is O(real pages), but the grid itself is the padded
rectangle: every short row in a ragged batch still steps through
``max_npages`` iterations, and one long-context row serializes its
whole history on a single lane while other lanes idle — exactly the SM
under-utilization COMET §4.4 / Fig. 8 attacks with tile decomposition.

**Work-queue schedule** (``paged_kv4_decode_attention_wq`` /
``paged_kv4_prefill_attention_wq``): the TPU analogue of Fig. 8e's
divisible tile pool (Stream-K one-to-many binding + FlashDecoding
split-KV). The host flattens the batch into a descriptor array
``[W, 4]`` of ``(row, phys_page, count, kind)`` items covering only
*real* pages (``serving.kv_cache.build_work_queue``), and the kernel
grid is ``(W,)`` — grid size ≈ Σ pages, not ``B × max_npages``. Each
grid step processes ONE page (or one in-flight fp chunk) for ONE
``(seq, kv_head)`` row and emits a partial flash triple ``(acc, l, m)``
— a local softmax numerator, denominator, and running max. No
cross-step state: a long row's pages land on *different* grid steps
(they parallelize across cores instead of serializing), and short rows
contribute exactly their real pages (no padding iterations). A
log-sum-exp **split-KV combine** (``combine_work_partials``, a segment
reduce over the descriptor's row ids) then merges partials:

    M_r = max_i m_i,   w_i = exp(m_i − M_r)
    out_r = (Σ_i w_i · acc_i) / (Σ_i w_i · l_i)

which is the dense online-softmax result, reassociated — so the two
schedules are numerically equivalent up to float reassociation.
Work-item padding (to a power of two) carries ``count = 0`` and a
sentinel row: its partial has ``m = NEG_INF``, so its combine weight
underflows to exactly 0 and the scatter drops the sentinel segment.

Quantization algebra is shared by both schedules: channel-wise
asymmetric int4 with the TPU-native zero-point fold — the hot loop
touches only raw nibbles (mask + shift). For decode all affine terms
are O(D) pre/post work outside the kernel; prefill mixes int4 history
with fp chunk values, so the V affine is applied per history item
in-kernel (``p@n_v ⊙ s_v − (Σp)·s_v⊙z_v`` — the matmul still runs on
raw nibbles; the affine is linear in ``p``, so it commutes with the
combine).

Layout: pools are ``[num_pages, page_size, Hkv, D/2]`` uint8 — one page
per grid step; dense block tables are ``[B, max_pages]`` int32 with
unmapped entries clamped to 0 (masked by length in-kernel, never read
semantically); work-queue descriptors address physical pages directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.kernels.kv4_attention import NEG_INF, _unpack_nibbles_f32

__all__ = [
    "paged_kv4_decode_attention",
    "paged_kv4_prefill_attention",
    "paged_kv4_decode_attention_wq",
    "paged_kv4_prefill_attention_wq",
    "combine_work_partials",
]


def combine_work_partials(acc: jax.Array, l: jax.Array, m: jax.Array,
                          rows: jax.Array, num_rows: int) -> jax.Array:
    """Split-KV log-sum-exp combine: merge per-work-item flash partials.

    acc ``[W, R, D]`` partial numerators (value space), l/m ``[W, R, 1]``
    partial denominators / local maxima, rows ``[W]`` segment ids (ids
    ≥ ``num_rows`` are padding — the scatter drops them). Returns the
    normalized ``[num_rows, R, D]`` attention output; rows with no items
    come back 0 (finite — callers mask padding rows anyway).
    """
    rows = rows.astype(jnp.int32)
    mmax = jax.ops.segment_max(m, rows, num_segments=num_rows)
    # rows with no work items get segment_max's -inf identity; clamp to
    # the finite NEG_INF so fully-masked partials (m == NEG_INF) weight
    # as exp(0) · dropped instead of exp(+inf)
    mmax = jnp.maximum(mmax, NEG_INF)
    w = jnp.exp(m - mmax[jnp.minimum(rows, num_rows - 1)])
    num = jax.ops.segment_sum(acc * w, rows, num_segments=num_rows)
    den = jax.ops.segment_sum(l * w, rows, num_segments=num_rows)
    return num / jnp.maximum(den, 1e-30)


def _paged_kv4_decode_kernel(
    tbl_ref,               # scalar prefetch: [B, NP] int32 physical page ids
    len_ref,               # scalar prefetch: [B] int32 valid lengths
    qt_ref,                # [1, G, D] f32  — q·s_k/√D (pre-scaled)
    c_ref,                 # [1, G, 1] f32  — zero-point fold Σ q̃·z_k
    kp_ref,                # [1, ps, 1, D/2] uint8 — one K page
    vp_ref,                # [1, ps, 1, D/2] uint8 — one V page
    o_ref,                 # [1, G, D] f32 — unnormalized Σ p̃·n_v
    l_ref,                 # [1, G, 1] f32 — softmax denominator
    acc_ref, m_ref, d_ref, # scratch: [G, D], [G, 1], [G, 1]
    *,
    ps: int,
    npages: int,
    hkv: int,
):
    bh = pl.program_id(0)
    pi = pl.program_id(1)
    b = bh // hkv

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    length = len_ref[b]
    chunk_start = pi * ps

    @pl.when(chunk_start < length)
    def _compute():
        qt = qt_ref[0]                                 # [G, D]
        c = c_ref[0]                                   # [G, 1]
        nk = _unpack_nibbles_f32(kp_ref[0, :, 0, :])   # [ps, D]
        s = jax.lax.dot_general(
            qt, nk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) - c                                          # [G, ps]
        pos = chunk_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...]                            # [G, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                         # [G, ps]
        nv = _unpack_nibbles_f32(vp_ref[0, :, 0, :])   # [ps, D]
        pv = jax.lax.dot_general(
            p, nv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                              # [G, D]
        acc_ref[...] = acc_ref[...] * alpha + pv
        d_ref[...] = d_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(pi == npages - 1)
    def _done():
        o_ref[0] = acc_ref[...]
        l_ref[0] = d_ref[...]


def paged_kv4_decode_attention(
    q: jax.Array,             # [B, Hq, D] — decode-step queries
    k_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical K pages
    k_scale: jax.Array,       # [Hkv, 1, D] (or [B, Hkv, 1, D]) f32
    k_zero: jax.Array,        # [Hkv, 1, D] f32
    v_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical V pages
    v_scale: jax.Array,       # [Hkv, 1, D] f32
    v_zero: jax.Array,        # [Hkv, 1, D] f32
    block_tables: jax.Array,  # [B, NP] int32 physical page per logical page
    length: jax.Array,        # [B] int32 — valid KV lengths (≤ NP·ps)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Flash-decode straight off the paged pools. Returns [B, Hq, D] f32."""
    b, hq, d = q.shape
    ps, hkv = k_pool.shape[1], k_pool.shape[2]
    g = hq // hkv
    npages = block_tables.shape[1]
    tables = jnp.maximum(block_tables.astype(jnp.int32), 0)

    def bcast(s):
        return jnp.broadcast_to(s, (b, hkv, 1, d))

    # --- affine pre-fold (outside the kernel, O(B·H·D)) ---
    sm = 1.0 / jnp.sqrt(jnp.float32(d))
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    qt = qg * bcast(k_scale) * sm                      # [B, Hkv, G, D]
    c = jnp.sum(qt * bcast(k_zero), axis=-1, keepdims=True)

    qt2 = qt.reshape(b * hkv, g, d)
    c2 = c.reshape(b * hkv, g, 1)

    kernel = functools.partial(
        _paged_kv4_decode_kernel, ps=ps, npages=npages, hkv=hkv)

    def page_map(bh, pi, tbl, lens):
        return (tbl[bh // hkv, pi], 0, bh % hkv, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * hkv, npages),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, pi, tbl, lens: (bh, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda bh, pi, tbl, lens: (bh, 0, 0)),
            pl.BlockSpec((1, ps, 1, d // 2), page_map),
            pl.BlockSpec((1, ps, 1, d // 2), page_map),
        ],
        out_specs=[
            pl.BlockSpec((1, g, d), lambda bh, pi, tbl, lens: (bh, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda bh, pi, tbl, lens: (bh, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    acc, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, g, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(tables, length.astype(jnp.int32), qt2, c2, k_pool, v_pool)

    # --- affine post-fold: out = s_v ⊙ (acc / l) − s_v ⊙ z_v ---
    acc = acc.reshape(b, hkv, g, d)
    l = l.reshape(b, hkv, g, 1)
    sv = bcast(v_scale)
    zv = bcast(v_zero)
    out = sv * (acc / l) - sv * zv
    return out.reshape(b, hq, d)


# ---------------------------------------------------------------------------
# Chunked ragged prefill
# ---------------------------------------------------------------------------

def _paged_kv4_prefill_kernel(
    tbl_ref,               # scalar prefetch: [B, NP] int32 physical page ids
    ctx_ref,               # scalar prefetch: [B] int32 paged-history lengths
    qlen_ref,              # scalar prefetch: [B] int32 valid chunk tokens
    qt_ref,                # [1, CG, D] f32 — q·s_k/√D (history pre-fold)
    c_ref,                 # [1, CG, 1] f32 — zero-point fold Σ q̃·z_k
    qs_ref,                # [1, CG, D] f32 — q/√D (raw, for the fp chunk)
    kn_ref,                # [1, C, D] f32 — in-flight fp chunk keys
    vn_ref,                # [1, C, D] f32 — in-flight fp chunk values
    vs_ref,                # [1, 1, D] f32 — v_scale (history V dequant)
    vz_ref,                # [1, 1, D] f32 — v_zero
    kp_ref,                # [1, ps, 1, D/2] uint8 — one K history page
    vp_ref,                # [1, ps, 1, D/2] uint8 — one V history page
    o_ref,                 # [1, CG, D] f32 — unnormalized output
    l_ref,                 # [1, CG, 1] f32 — softmax denominator
    acc_ref, m_ref, d_ref, # scratch: [CG, D], [CG, 1], [CG, 1]
    *,
    ps: int,
    npages: int,
    hkv: int,
    g: int,
):
    bh = pl.program_id(0)
    pi = pl.program_id(1)
    b = bh // hkv

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    ctx = ctx_ref[b]
    qlen = qlen_ref[b]

    def online_update(s, pv_fn):
        """Shared online-softmax step; pv_fn(p) → [CG, D] value partial."""
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        pv = pv_fn(p)
        acc_ref[...] = acc_ref[...] * alpha + pv
        d_ref[...] = d_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new

    # --- int4 history pages: all chunk queries see all valid history ---
    @pl.when((pi < npages) & (pi * ps < ctx))
    def _history():
        qt = qt_ref[0]                                 # [CG, D]
        cc = c_ref[0]                                  # [CG, 1]
        nk = _unpack_nibbles_f32(kp_ref[0, :, 0, :])   # [ps, D]
        s = jax.lax.dot_general(
            qt, nk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) - cc                                         # [CG, ps]
        pos = pi * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < ctx, s, NEG_INF)

        def vals(p):
            nv = _unpack_nibbles_f32(vp_ref[0, :, 0, :])
            pv = jax.lax.dot_general(
                p, nv, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )                                          # [CG, D]
            sv = vs_ref[0, 0]                          # [D]
            zv = vz_ref[0, 0]
            return pv * sv - jnp.sum(p, axis=1, keepdims=True) * (sv * zv)

        online_update(s, vals)

    # --- in-flight fp chunk: intra-chunk causal mask, then write out ---
    @pl.when(pi == npages)
    def _chunk():
        qs = qs_ref[0]                                 # [CG, D]
        kn = kn_ref[0]                                 # [C, D]
        s = jax.lax.dot_general(
            qs, kn, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                              # [CG, C]
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
        kj = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where((kj <= qi) & (kj < qlen), s, NEG_INF)
        online_update(s, lambda p: jax.lax.dot_general(
            p, vn_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32))
        o_ref[0] = acc_ref[...]
        l_ref[0] = d_ref[...]


def paged_kv4_prefill_attention(
    q: jax.Array,             # [B, C, Hq, D] — one prefill chunk's queries
    k_new: jax.Array,         # [B, C, Hkv, D] fp in-flight chunk keys
    v_new: jax.Array,         # [B, C, Hkv, D] fp in-flight chunk values
    k_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical K pages
    k_scale: jax.Array,       # [Hkv, 1, D] f32
    k_zero: jax.Array,        # [Hkv, 1, D] f32
    v_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical V pages
    v_scale: jax.Array,       # [Hkv, 1, D] f32
    v_zero: jax.Array,        # [Hkv, 1, D] f32
    block_tables: jax.Array,  # [B, NP] int32 physical page per logical page
    ctx_lens: jax.Array,      # [B] int32 — tokens already paged (history)
    q_lens: jax.Array,        # [B] int32 — valid chunk tokens (≤ C)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Chunked prefill flash attention off the paged pools.

    Query i of sequence b (absolute position ``ctx_lens[b] + i``) attends
    over the int4 history pages [0, ctx_lens[b]) and the causal prefix of
    the fp chunk. Rows i ≥ q_lens[b] are padding (finite garbage — mask
    outside). Returns [B, C, Hq, D] f32.
    """
    b, c, hq, d = q.shape
    ps, hkv = k_pool.shape[1], k_pool.shape[2]
    g = hq // hkv
    npages = block_tables.shape[1]
    tables = jnp.maximum(block_tables.astype(jnp.int32), 0)
    if npages == 0:                    # pure-chunk call (no history yet)
        tables = jnp.zeros((b, 1), jnp.int32)

    # --- affine pre-fold for the history pages (outside the kernel) ---
    sm = 1.0 / jnp.sqrt(jnp.float32(d))
    qg = jnp.moveaxis(q.reshape(b, c, hkv, g, d).astype(jnp.float32), 1, 2)
    ksb = jnp.broadcast_to(k_scale, (hkv, 1, d)).reshape(1, hkv, 1, 1, d)
    kzb = jnp.broadcast_to(k_zero, (hkv, 1, d)).reshape(1, hkv, 1, 1, d)
    qt = qg * ksb * sm                                 # [B, Hkv, C, G, D]
    cterm = jnp.sum(qt * kzb, axis=-1, keepdims=True)
    qt2 = qt.reshape(b * hkv, c * g, d)
    c2 = cterm.reshape(b * hkv, c * g, 1)
    qs2 = (qg * sm).reshape(b * hkv, c * g, d)
    kn2 = k_new.astype(jnp.float32).swapaxes(1, 2).reshape(b * hkv, c, d)
    vn2 = v_new.astype(jnp.float32).swapaxes(1, 2).reshape(b * hkv, c, d)
    vs2 = jnp.broadcast_to(v_scale, (hkv, 1, d))
    vz2 = jnp.broadcast_to(v_zero, (hkv, 1, d))

    kernel = functools.partial(
        _paged_kv4_prefill_kernel, ps=ps, npages=npages, hkv=hkv, g=g)

    def page_map(bh, pi, tbl, ctx, qlen):
        return (tbl[bh // hkv, jnp.maximum(jnp.minimum(pi, npages - 1), 0)],
                0, bh % hkv, 0)

    def row_map(bh, pi, tbl, ctx, qlen):
        return (bh, 0, 0)

    def head_map(bh, pi, tbl, ctx, qlen):
        return (bh % hkv, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b * hkv, npages + 1),
        in_specs=[
            pl.BlockSpec((1, c * g, d), row_map),       # qt
            pl.BlockSpec((1, c * g, 1), row_map),       # c
            pl.BlockSpec((1, c * g, d), row_map),       # qs
            pl.BlockSpec((1, c, d), row_map),           # k_new
            pl.BlockSpec((1, c, d), row_map),           # v_new
            pl.BlockSpec((1, 1, d), head_map),          # v_scale
            pl.BlockSpec((1, 1, d), head_map),          # v_zero
            pl.BlockSpec((1, ps, 1, d // 2), page_map), # K page
            pl.BlockSpec((1, ps, 1, d // 2), page_map), # V page
        ],
        out_specs=[
            pl.BlockSpec((1, c * g, d), row_map),
            pl.BlockSpec((1, c * g, 1), row_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((c * g, d), jnp.float32),
            pltpu.VMEM((c * g, 1), jnp.float32),
            pltpu.VMEM((c * g, 1), jnp.float32),
        ],
    )
    acc, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, c * g, d), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, c * g, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(tables, ctx_lens.astype(jnp.int32), q_lens.astype(jnp.int32),
      qt2, c2, qs2, kn2, vn2, vs2, vz2, k_pool, v_pool)

    # V affine for history already applied in-kernel; just normalize.
    out = (acc / l).reshape(b, hkv, c, g, d)
    out = jnp.moveaxis(out, 2, 1)                      # [B, C, Hkv, G, D]
    return out.reshape(b, c, hq, d)


# ---------------------------------------------------------------------------
# Work-queue (Stream-K) schedule: flat descriptor walk + split-KV combine
# ---------------------------------------------------------------------------

def _paged_kv4_decode_wq_kernel(
    desc_ref,              # scalar prefetch: [W, 4] (row, page, count, kind)
    qt_ref,                # [1, G, D] f32 — the item's row q·s_k/√D
    c_ref,                 # [1, G, 1] f32 — zero-point fold Σ q̃·z_k
    kp_ref,                # [1, ps, 1, D/2] uint8 — the item's K page
    vp_ref,                # [1, ps, 1, D/2] uint8 — the item's V page
    o_ref,                 # [1, G, D] f32 — partial Σ p·n_v (nibble space)
    l_ref,                 # [1, G, 1] f32 — partial denominator Σ p
    m_ref,                 # [1, G, 1] f32 — the item's local max
):
    wi = pl.program_id(0)
    count = desc_ref[wi, 2]

    qt = qt_ref[0]                                     # [G, D]
    nk = _unpack_nibbles_f32(kp_ref[0, :, 0, :])       # [ps, D]
    s = jax.lax.dot_general(
        qt, nk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) - c_ref[0]                                       # [G, ps]
    pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < count, s, NEG_INF)
    # padding items (count == 0) produce m == NEG_INF: their combine
    # weight exp(m − M) underflows to exactly 0, so the garbage p == 1
    # rows below never reach an output
    m = jnp.max(s, axis=1, keepdims=True)              # [G, 1]
    p = jnp.exp(s - m)
    nv = _unpack_nibbles_f32(vp_ref[0, :, 0, :])
    o_ref[0] = jax.lax.dot_general(
        p, nv, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    l_ref[0] = jnp.sum(p, axis=1, keepdims=True)
    m_ref[0] = m


def paged_kv4_decode_attention_wq(
    q: jax.Array,             # [B, Hq, D] — decode-step queries
    k_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical K pages
    k_scale: jax.Array,       # [Hkv, 1, D] (or [B, Hkv, 1, D]) f32
    k_zero: jax.Array,        # [Hkv, 1, D] f32
    v_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical V pages
    v_scale: jax.Array,       # [Hkv, 1, D] f32
    v_zero: jax.Array,        # [Hkv, 1, D] f32
    work_items: jax.Array,    # [W, 4] int32 (row, phys_page, count, kind)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Work-queue flash-decode: grid = (W,) real-page work items, partial
    (acc, l, m) per item, split-KV combine. Returns [B, Hq, D] f32."""
    b, hq, d = q.shape
    ps, hkv = k_pool.shape[1], k_pool.shape[2]
    g = hq // hkv
    nrows = b * hkv
    w = work_items.shape[0]
    desc = work_items.astype(jnp.int32)

    def bcast(s):
        return jnp.broadcast_to(s, (b, hkv, 1, d))

    # --- affine pre-fold (outside the kernel, O(B·H·D)) ---
    sm = 1.0 / jnp.sqrt(jnp.float32(d))
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    qt = qg * bcast(k_scale) * sm                      # [B, Hkv, G, D]
    c = jnp.sum(qt * bcast(k_zero), axis=-1, keepdims=True)
    qt2 = qt.reshape(nrows, g, d)
    c2 = c.reshape(nrows, g, 1)

    def row_map(wi, desc):
        return (jnp.minimum(desc[wi, 0], nrows - 1), 0, 0)

    def page_map(wi, desc):
        return (desc[wi, 1], 0,
                jnp.minimum(desc[wi, 0], nrows - 1) % hkv, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1, g, d), row_map),
            pl.BlockSpec((1, g, 1), row_map),
            pl.BlockSpec((1, ps, 1, d // 2), page_map),
            pl.BlockSpec((1, ps, 1, d // 2), page_map),
        ],
        out_specs=[
            pl.BlockSpec((1, g, d), lambda wi, desc: (wi, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda wi, desc: (wi, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda wi, desc: (wi, 0, 0)),
        ],
    )
    acc, l, m = pl.pallas_call(
        _paged_kv4_decode_wq_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((w, g, d), jnp.float32),
            jax.ShapeDtypeStruct((w, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((w, g, 1), jnp.float32),
        ],
        # every step writes its own output block — the grid is a
        # divisible pool with no cross-step carry, so the whole axis is
        # parallel (the Stream-K property the dense schedule lacks)
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(desc, qt2, c2, k_pool, v_pool)

    comb = combine_work_partials(acc, l, m, desc[:, 0], nrows)
    # --- affine post-fold: out = s_v ⊙ (Σp·n_v / Σp) − s_v ⊙ z_v ---
    sv = bcast(v_scale)
    zv = bcast(v_zero)
    out = sv * comb.reshape(b, hkv, g, d) - sv * zv
    return out.reshape(b, hq, d)


def _paged_kv4_prefill_wq_kernel(
    desc_ref,              # scalar prefetch: [W, 4] (row, page, count, kind)
    qt_ref,                # [1, CG, D] f32 — q·s_k/√D (history pre-fold)
    c_ref,                 # [1, CG, 1] f32 — zero-point fold Σ q̃·z_k
    qs_ref,                # [1, CG, D] f32 — q/√D (raw, for the fp chunk)
    kn_ref,                # [1, C, D] f32 — the row's in-flight fp keys
    vn_ref,                # [1, C, D] f32 — the row's in-flight fp values
    vs_ref,                # [1, 1, D] f32 — v_scale (history V dequant)
    vz_ref,                # [1, 1, D] f32 — v_zero
    kp_ref,                # [1, ps, 1, D/2] uint8 — the item's K page
    vp_ref,                # [1, ps, 1, D/2] uint8 — the item's V page
    o_ref,                 # [1, CG, D] f32 — partial numerator (value space)
    l_ref,                 # [1, CG, 1] f32 — partial denominator
    m_ref,                 # [1, CG, 1] f32 — the item's local max
    *,
    g: int,
):
    wi = pl.program_id(0)
    count = desc_ref[wi, 2]
    kind = desc_ref[wi, 3]

    # --- kind 0: one int4 history page (V affine folded per item) ---
    @pl.when(kind == 0)
    def _history():
        qt = qt_ref[0]                                 # [CG, D]
        nk = _unpack_nibbles_f32(kp_ref[0, :, 0, :])   # [ps, D]
        s = jax.lax.dot_general(
            qt, nk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) - c_ref[0]                                   # [CG, ps]
        pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < count, s, NEG_INF)
        m = jnp.max(s, axis=1, keepdims=True)
        p = jnp.exp(s - m)
        nv = _unpack_nibbles_f32(vp_ref[0, :, 0, :])
        pv = jax.lax.dot_general(
            p, nv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # [CG, D]
        sv = vs_ref[0, 0]
        zv = vz_ref[0, 0]
        lsum = jnp.sum(p, axis=1, keepdims=True)
        o_ref[0] = pv * sv - lsum * (sv * zv)
        l_ref[0] = lsum
        m_ref[0] = m

    # --- kind 1: the row's in-flight fp chunk, causal over count ---
    @pl.when(kind != 0)
    def _chunk():
        qs = qs_ref[0]                                 # [CG, D]
        kn = kn_ref[0]                                 # [C, D]
        s = jax.lax.dot_general(
            qs, kn, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                              # [CG, C]
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
        kj = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where((kj <= qi) & (kj < count), s, NEG_INF)
        m = jnp.max(s, axis=1, keepdims=True)
        p = jnp.exp(s - m)
        o_ref[0] = jax.lax.dot_general(
            p, vn_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        l_ref[0] = jnp.sum(p, axis=1, keepdims=True)
        m_ref[0] = m


def paged_kv4_prefill_attention_wq(
    q: jax.Array,             # [B, C, Hq, D] — one prefill chunk's queries
    k_new: jax.Array,         # [B, C, Hkv, D] fp in-flight chunk keys
    v_new: jax.Array,         # [B, C, Hkv, D] fp in-flight chunk values
    k_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical K pages
    k_scale: jax.Array,       # [Hkv, 1, D] f32
    k_zero: jax.Array,        # [Hkv, 1, D] f32
    v_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical V pages
    v_scale: jax.Array,       # [Hkv, 1, D] f32
    v_zero: jax.Array,        # [Hkv, 1, D] f32
    work_items: jax.Array,    # [W, 4] int32 (row, phys_page, count, kind)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Work-queue chunked-prefill flash attention: grid = (W,) descriptor
    items (real history pages + one causal chunk item per row), split-KV
    combined. Same semantics as ``paged_kv4_prefill_attention`` — rows
    past a row's ``q_len`` are padding garbage, mask outside. Returns
    [B, C, Hq, D] f32."""
    b, c, hq, d = q.shape
    ps, hkv = k_pool.shape[1], k_pool.shape[2]
    g = hq // hkv
    nrows = b * hkv
    w = work_items.shape[0]
    desc = work_items.astype(jnp.int32)

    # --- affine pre-fold for the history pages (outside the kernel) ---
    sm = 1.0 / jnp.sqrt(jnp.float32(d))
    qg = jnp.moveaxis(q.reshape(b, c, hkv, g, d).astype(jnp.float32), 1, 2)
    ksb = jnp.broadcast_to(k_scale, (hkv, 1, d)).reshape(1, hkv, 1, 1, d)
    kzb = jnp.broadcast_to(k_zero, (hkv, 1, d)).reshape(1, hkv, 1, 1, d)
    qt = qg * ksb * sm                                 # [B, Hkv, C, G, D]
    cterm = jnp.sum(qt * kzb, axis=-1, keepdims=True)
    qt2 = qt.reshape(nrows, c * g, d)
    c2 = cterm.reshape(nrows, c * g, 1)
    qs2 = (qg * sm).reshape(nrows, c * g, d)
    kn2 = k_new.astype(jnp.float32).swapaxes(1, 2).reshape(nrows, c, d)
    vn2 = v_new.astype(jnp.float32).swapaxes(1, 2).reshape(nrows, c, d)
    vs2 = jnp.broadcast_to(v_scale, (hkv, 1, d))
    vz2 = jnp.broadcast_to(v_zero, (hkv, 1, d))

    kernel = functools.partial(_paged_kv4_prefill_wq_kernel, g=g)

    def row_map(wi, desc):
        return (jnp.minimum(desc[wi, 0], nrows - 1), 0, 0)

    def head_map(wi, desc):
        return (jnp.minimum(desc[wi, 0], nrows - 1) % hkv, 0, 0)

    def page_map(wi, desc):
        return (desc[wi, 1], 0,
                jnp.minimum(desc[wi, 0], nrows - 1) % hkv, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1, c * g, d), row_map),       # qt
            pl.BlockSpec((1, c * g, 1), row_map),       # c
            pl.BlockSpec((1, c * g, d), row_map),       # qs
            pl.BlockSpec((1, c, d), row_map),           # k_new
            pl.BlockSpec((1, c, d), row_map),           # v_new
            pl.BlockSpec((1, 1, d), head_map),          # v_scale
            pl.BlockSpec((1, 1, d), head_map),          # v_zero
            pl.BlockSpec((1, ps, 1, d // 2), page_map), # K page
            pl.BlockSpec((1, ps, 1, d // 2), page_map), # V page
        ],
        out_specs=[
            pl.BlockSpec((1, c * g, d), lambda wi, desc: (wi, 0, 0)),
            pl.BlockSpec((1, c * g, 1), lambda wi, desc: (wi, 0, 0)),
            pl.BlockSpec((1, c * g, 1), lambda wi, desc: (wi, 0, 0)),
        ],
    )
    acc, l, m = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((w, c * g, d), jnp.float32),
            jax.ShapeDtypeStruct((w, c * g, 1), jnp.float32),
            jax.ShapeDtypeStruct((w, c * g, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(desc, qt2, c2, qs2, kn2, vn2, vs2, vz2, k_pool, v_pool)

    # partials are already in value space — combine IS the output
    out = combine_work_partials(acc, l, m, desc[:, 0], nrows)
    out = out.reshape(b, hkv, c, g, d)
    out = jnp.moveaxis(out, 2, 1)                      # [B, C, Hkv, G, D]
    return out.reshape(b, c, hq, d)
