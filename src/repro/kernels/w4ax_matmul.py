"""COMET W4Ax mixed-precision GEMM — Pallas TPU kernels (paper §4).

Three schedules are provided:

``w4a4_matmul``      uniform W4A4: packed int4 activations × packed int4
                     weights, per-(row, K-block) × per-(K-block, col)
                     scales, int32 MXU accumulation, f32 epilogue.
``w4a8_matmul``      uniform W4A8: int8 activations × packed int4 weights
                     with the in-kernel fast INT4→INT8 conversion (§4.3).
``w4ax_matmul_mixed``the paper-faithful single mixed kernel: the grid's
                     K dimension walks INT4 blocks then INT8 blocks and
                     switches precision per step (`lax.cond`) — the TPU
                     analogue of issuing INT4/INT8 mma tiles to SMs
                     (Fig. 5b). Used as the §Perf *baseline*.
``w4ax_matmul_split``the TPU-native optimized schedule (DESIGN.md §2):
                     FMPQ's channel permutation makes INT8 blocks
                     contiguous at the K tail, so the mixed GEMM is two
                     *uniform* sub-GEMMs with no per-step branching —
                     the static-schedule realization of the paper's tile
                     remapping + decomposition (load balance by
                     construction).

Fast INT4→INT8 conversion (§4.3, TPU adaptation)
------------------------------------------------
Nibbles are stored **biased** (+8 → unsigned [0,15]) in the blocked
"location switch" interleave (`pack_int4_interleaved`), so the in-kernel
unpack is exactly two VPU ops — mask and logical shift — i.e. *zero
extension*, never sign extension. The algebra is restored at the int32
accumulation boundary:

    dot(a'+0, w') = dot(a, w) + 8·Σa + 8·Σw + 64·Kb        (a'=a+8, w'=w+8)

so ``dot(a, w) = dot(a', w') − 8·rowsum(a') − 8·colsum(w') + 8192`` for a
128-channel block. The row/col sums are one cheap VPU reduction per tile,
amortized over the [bm,128]×[128,bn] MXU dot — this is the paper's
"fold the correction into the scaling parameters" made additive.

The naive sign-extension path (``conversion="signext"``, arithmetic
shifts, no correction) is retained for the Fig. 10-style ablation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

BLOCK_K = 128        # quantization block (channels) == one K grid step
PACKED_BLOCK = 64    # bytes per block row-pair (BLOCK_K / 2)

__all__ = [
    "w4a4_matmul",
    "w4a8_matmul",
    "w4ax_matmul_mixed",
    "w4ax_matmul_split",
]


# ---------------------------------------------------------------------------
# In-kernel unpack primitives
# ---------------------------------------------------------------------------

def _unpack_zeroext_rows(packed):
    """[64, bn] packed uint8 → biased int8-valued [128, bn] (values 0..15).

    Two VPU ops (mask, logical shift); the blocked interleave means the
    two nibble panels concatenate in order with no element shuffle.
    """
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.int8)
    hi = (packed >> jnp.uint8(4)).astype(jnp.int8)
    return jnp.concatenate([lo, hi], axis=0)


def _unpack_zeroext_cols(packed):
    """[bm, 64] packed uint8 → biased [bm, 128] (values 0..15)."""
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.int8)
    hi = (packed >> jnp.uint8(4)).astype(jnp.int8)
    return jnp.concatenate([lo, hi], axis=1)


def _unpack_signext_rows(packed):
    """Naive sign-extension unpack (ablation baseline): 3+ ops, no bias."""
    p = packed.astype(jnp.int8)
    lo = jnp.left_shift(p, 4) >> 4          # arithmetic shifts sign-extend
    hi = p >> 4                              # arithmetic on int8
    # stored biased, so convert: biased-nibble arithmetic-shift path needs
    # the bias removed explicitly (extra op vs zeroext+correction)
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.int8) - jnp.int8(8)
    hi = (packed >> jnp.uint8(4)).astype(jnp.int8) - jnp.int8(8)
    return jnp.concatenate([lo, hi], axis=0)


def _unpack_signext_cols(packed):
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.int8) - jnp.int8(8)
    hi = (packed >> jnp.uint8(4)).astype(jnp.int8) - jnp.int8(8)
    return jnp.concatenate([lo, hi], axis=1)


def _int_dot(a, b):
    """int8 × int8 → int32 MXU dot."""
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


# ---------------------------------------------------------------------------
# Uniform W4A4 kernel
# ---------------------------------------------------------------------------

def _w4a4_kernel(a_ref, asc_ref, w_ref, wsc_ref, o_ref, acc_ref, *, nsteps, conversion):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if conversion == "zeroext":
        a = _unpack_zeroext_cols(a_ref[...])       # [bm, 128] biased
        w = _unpack_zeroext_rows(w_ref[...])       # [128, bn] biased
        d = _int_dot(a, w)                         # D' int32
        ra = jnp.sum(a.astype(jnp.int32), axis=1, keepdims=True)   # Σa' [bm,1]
        cw = jnp.sum(w.astype(jnp.int32), axis=0, keepdims=True)   # Σw' [1,bn]
        d = d - 8 * ra - 8 * cw + (8 * 8 * BLOCK_K)
    else:
        a = _unpack_signext_cols(a_ref[...])
        w = _unpack_signext_rows(w_ref[...])
        d = _int_dot(a, w)

    scale = asc_ref[...].astype(jnp.float32) * wsc_ref[...].astype(jnp.float32)
    acc_ref[...] += d.astype(jnp.float32) * scale

    @pl.when(ki == nsteps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def w4a4_matmul(
    a_packed: jax.Array,   # [M, K/2] uint8 (blocked interleave, biased)
    a_scale: jax.Array,    # [M, K/128] f32
    w_packed: jax.Array,   # [K/2, N] uint8
    w_scale: jax.Array,    # [K/128, N] f32
    *,
    bm: int = 128,
    bn: int = 128,
    conversion: str = "zeroext",
    interpret: bool = False,
) -> jax.Array:
    m = a_packed.shape[0]
    n = w_packed.shape[1]
    kb = a_scale.shape[1]                      # number of 128-channel blocks
    assert a_packed.shape[1] == kb * PACKED_BLOCK
    assert w_packed.shape[0] == kb * PACKED_BLOCK
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), kb)

    kernel = functools.partial(_w4a4_kernel, nsteps=kb, conversion=conversion)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, PACKED_BLOCK), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, k)),
            pl.BlockSpec((PACKED_BLOCK, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_packed, a_scale, w_packed, w_scale)


# ---------------------------------------------------------------------------
# Uniform W4A8 kernel (fast INT4→INT8 conversion for the weights)
# ---------------------------------------------------------------------------

def _w4a8_kernel(a_ref, asc_ref, w_ref, wsc_ref, o_ref, acc_ref, *, nsteps, conversion):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                                  # [bm, 128] int8 (true values)
    if conversion == "zeroext":
        w = _unpack_zeroext_rows(w_ref[...])        # [128, bn] biased
        d = _int_dot(a, w)
        ra = jnp.sum(a.astype(jnp.int32), axis=1, keepdims=True)    # Σa
        d = d - 8 * ra                              # dot(a, w'+? ) − 8Σa
    else:
        w = _unpack_signext_rows(w_ref[...])
        d = _int_dot(a, w)

    scale = asc_ref[...].astype(jnp.float32) * wsc_ref[...].astype(jnp.float32)
    acc_ref[...] += d.astype(jnp.float32) * scale

    @pl.when(ki == nsteps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def w4a8_matmul(
    a_q: jax.Array,        # [M, K] int8
    a_scale: jax.Array,    # [M, K/128] f32
    w_packed: jax.Array,   # [K/2, N] uint8
    w_scale: jax.Array,    # [K/128, N] f32
    *,
    bm: int = 128,
    bn: int = 128,
    conversion: str = "zeroext",
    interpret: bool = False,
) -> jax.Array:
    m, k = a_q.shape
    n = w_packed.shape[1]
    kb = k // BLOCK_K
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), kb)
    kernel = functools.partial(_w4a8_kernel, nsteps=kb, conversion=conversion)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, BLOCK_K), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, k)),
            pl.BlockSpec((PACKED_BLOCK, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_q, a_scale, w_packed, w_scale)


# ---------------------------------------------------------------------------
# Paper-faithful mixed kernel: one grid, per-step precision switch
# ---------------------------------------------------------------------------

def _w4ax_mixed_kernel(
    a4_ref, a4s_ref, a8_ref, a8s_ref, w_ref, wsc_ref, o_ref, acc_ref,
    *, nb4, nsteps,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack_zeroext_rows(w_ref[...])            # [128, bn] biased
    cw = jnp.sum(w.astype(jnp.int32), axis=0, keepdims=True)

    def int4_branch(_):
        a = _unpack_zeroext_cols(a4_ref[...])       # biased
        d = _int_dot(a, w)
        ra = jnp.sum(a.astype(jnp.int32), axis=1, keepdims=True)
        d = d - 8 * ra - 8 * cw + (8 * 8 * BLOCK_K)
        return d.astype(jnp.float32) * a4s_ref[...].astype(jnp.float32)

    def int8_branch(_):
        a = a8_ref[...]                             # int8 true values
        d = _int_dot(a, w)
        ra = jnp.sum(a.astype(jnp.int32), axis=1, keepdims=True)
        d = d - 8 * ra
        return d.astype(jnp.float32) * a8s_ref[...].astype(jnp.float32)

    if nb4 == 0:
        contrib = int8_branch(None)
    elif nb4 == nsteps:
        contrib = int4_branch(None)
    else:
        contrib = jax.lax.cond(ki < nb4, int4_branch, int8_branch, None)
    acc_ref[...] += contrib * wsc_ref[...].astype(jnp.float32)

    @pl.when(ki == nsteps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def w4ax_matmul_mixed(
    a4_packed: jax.Array,  # [M, K4/2] uint8
    a4_scale: jax.Array,   # [M, K4/128]
    a8_q: jax.Array,       # [M, K8] int8
    a8_scale: jax.Array,   # [M, K8/128]
    w_packed: jax.Array,   # [K/2, N] uint8 (K = K4 + K8)
    w_scale: jax.Array,    # [K/128, N]
    *,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Single-kernel mixed W4Ax GEMM (paper-faithful baseline schedule).

    Both activation operands are passed full-size; the K grid walks all
    blocks and each step reads only the operand matching its precision
    (the other ref's index_map is clamped — Pallas still prefetches that
    block but the branch ignores it; this mirrors the paper's naive mixed
    issue where INT4 tiles stall on INT8 neighbours, and is exactly the
    inefficiency the *split* schedule removes).
    """
    m = a4_packed.shape[0]
    n = w_packed.shape[1]
    nb4 = a4_scale.shape[1] if a4_packed.shape[1] else 0
    nb8 = a8_scale.shape[1] if a8_q.shape[1] else 0
    nsteps = nb4 + nb8
    if nsteps == 0:
        raise ValueError("empty GEMM")
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), nsteps)

    # Degenerate uniform cases fall back to the uniform kernels.
    if nb4 == 0:
        return w4a8_matmul(
            a8_q, a8_scale, w_packed, w_scale, bm=bm, bn=bn, interpret=interpret
        )
    if nb8 == 0:
        return w4a4_matmul(
            a4_packed, a4_scale, w_packed, w_scale, bm=bm, bn=bn, interpret=interpret
        )

    kernel = functools.partial(_w4ax_mixed_kernel, nb4=nb4, nsteps=nsteps)

    def a4_map(i, j, k):
        return (i, jnp.minimum(k, nb4 - 1))

    def a4s_map(i, j, k):
        return (i, jnp.minimum(k, nb4 - 1))

    def a8_map(i, j, k):
        return (i, jnp.clip(k - nb4, 0, nb8 - 1))

    def a8s_map(i, j, k):
        return (i, jnp.clip(k - nb4, 0, nb8 - 1))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, PACKED_BLOCK), a4_map),
            pl.BlockSpec((bm, 1), a4s_map),
            pl.BlockSpec((bm, BLOCK_K), a8_map),
            pl.BlockSpec((bm, 1), a8s_map),
            pl.BlockSpec((PACKED_BLOCK, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a4_packed, a4_scale, a8_q, a8_scale, w_packed, w_scale)


# ---------------------------------------------------------------------------
# Optimized split schedule (TPU-native tile remapping, DESIGN.md §2)
# ---------------------------------------------------------------------------

def w4ax_matmul_split(
    a4_packed: jax.Array,
    a4_scale: jax.Array,
    a8_q: jax.Array,
    a8_scale: jax.Array,
    w_packed: jax.Array,
    w_scale: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    conversion: str = "zeroext",
    interpret: bool = False,
) -> jax.Array:
    """Two uniform sub-GEMMs over the contiguous K4 / K8 channel ranges.

    Load-balanced by construction: every grid step of each sub-kernel
    does identical work, so no "SM" ever waits on a slower-precision
    neighbour — the static realization of the paper's tile remapping +
    Stream-K decomposition (§4.4).
    """
    nb4 = a4_scale.shape[1] if a4_packed.shape[1] else 0
    k4p = nb4 * PACKED_BLOCK
    out = None
    if nb4 > 0:
        out = w4a4_matmul(
            a4_packed, a4_scale, w_packed[:k4p], w_scale[:nb4],
            bm=bm, bn=bn, conversion=conversion, interpret=interpret,
        )
    if a8_q.shape[1] > 0:
        o8 = w4a8_matmul(
            a8_q, a8_scale, w_packed[k4p:], w_scale[nb4:],
            bm=bm, bn=bn, conversion=conversion, interpret=interpret,
        )
        out = o8 if out is None else out + o8
    if out is None:
        raise ValueError("empty GEMM")
    return out
