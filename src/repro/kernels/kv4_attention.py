"""KV4 decode attention — flash-decoding over a channel-wise asymmetric
int4 KV cache (COMET §3.2 KV quantization, adapted to TPU).

TPU-native zero-point folding (beyond-paper optimization, recorded in
EXPERIMENTS.md): with *channel-wise asymmetric* int4 KV quantization the
dequantization affine terms fold entirely out of the inner loop:

  scores[g,t] = Σ_d q[g,d]·(n_k[t,d] − z_k[d])·s_k[d]
              = Σ_d (q·s_k)[g,d]·n_k[t,d]  −  Σ_d (q·s_k)[g,d]·z_k[d]
              =      q̃ @ n_kᵀ             −  c[g]          (c: per-head scalar)

  out[g,d]    = Σ_t p[g,t]·(n_v[t,d] − z_v[d])·s_v[d]
              = s_v[d]·(p @ n_v)[g,d] − s_v[d]·z_v[d]       (since Σ_t p = 1)

so the kernel's hot loop touches only the raw nibbles — zero dequant
arithmetic per (t, d) element beyond the nibble unpack (2 VPU ops/byte).
The affine pre/post terms (q̃, c, the s_v/z_v epilogue) are O(D) work done
outside the kernel.

The kernel is a standard online-softmax flash-decode: grid over
(batch·kv_head, T chunks), running max/денominator in VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

__all__ = ["kv4_decode_attention"]

NEG_INF = -1e30


def _unpack_nibbles_f32(packed):
    """[bt, D/2] uint8 → [bt, D] f32 nibbles in [0, 15].

    Channel pairs are packed sequentially (2j, 2j+1): unpack with the
    blocked layout along the last axis — lo nibbles are channels [0, D/2),
    hi nibbles [D/2, D) — matching `pack_int4_kv` in ops.py (location
    switch along channels so no element interleave is needed).
    """
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.float32)
    hi = (packed >> jnp.uint8(4)).astype(jnp.float32)
    return jnp.concatenate([lo, hi], axis=-1)


def _kv4_decode_kernel(
    length_ref,            # scalar prefetch: [B] int32 valid lengths
    qt_ref,                # [1, G, D] f32  — q·s_k/√D (pre-scaled)
    c_ref,                 # [1, G, 1] f32  — zero-point fold Σ q̃·z_k
    kp_ref,                # [1, bt, D/2] uint8
    vp_ref,                # [1, bt, D/2] uint8
    o_ref,                 # [1, G, D] f32 — unnormalized Σ p̃·n_v
    l_ref,                 # [1, G, 1] f32 — softmax denominator
    acc_ref, m_ref, d_ref, # scratch: [G, D], [G, 1], [G, 1]
    *,
    bt: int,
    nt: int,
    hkv: int,
):
    bh = pl.program_id(0)
    ti = pl.program_id(1)
    b = bh // hkv

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    length = length_ref[b]
    chunk_start = ti * bt

    @pl.when(chunk_start < length)
    def _compute():
        qt = qt_ref[0]                                # [G, D]
        c = c_ref[0]                                  # [G, 1]
        nk = _unpack_nibbles_f32(kp_ref[0])           # [bt, D]
        s = jax.lax.dot_general(
            qt, nk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) - c                                          # [G, bt]
        pos = chunk_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...]                            # [G, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                         # [G, bt]
        nv = _unpack_nibbles_f32(vp_ref[0])            # [bt, D]
        pv = jax.lax.dot_general(
            p, nv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                              # [G, D]
        acc_ref[...] = acc_ref[...] * alpha + pv
        d_ref[...] = d_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(ti == nt - 1)
    def _done():
        o_ref[0] = acc_ref[...]
        l_ref[0] = d_ref[...]


def kv4_decode_attention(
    q: jax.Array,          # [B, Hq, D] — decode-step queries
    k_packed: jax.Array,   # [B, Hkv, T, D/2] uint8 (lo=ch [0,D/2), hi=[D/2,D))
    k_scale: jax.Array,    # [B, Hkv, 1, D] f32
    k_zero: jax.Array,     # [B, Hkv, 1, D] f32
    v_packed: jax.Array,   # [B, Hkv, T, D/2] uint8
    v_scale: jax.Array,    # [B, Hkv, 1, D] f32
    v_zero: jax.Array,     # [B, Hkv, 1, D] f32
    length: jax.Array,     # [B] int32 — valid KV lengths
    *,
    bt: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Decode attention over the quantized cache. Returns [B, Hq, D] f32."""
    b, hq, d = q.shape
    hkv, t = k_packed.shape[1], k_packed.shape[2]
    g = hq // hkv
    nt = pl.cdiv(t, bt)

    # --- affine pre-fold (outside the kernel, O(B·H·D)) ---
    sm = 1.0 / jnp.sqrt(jnp.float32(d))
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    qt = qg * k_scale * sm                            # [B, Hkv, G, D]
    c = jnp.sum(qt * k_zero, axis=-1, keepdims=True)  # [B, Hkv, G, 1]

    qt2 = qt.reshape(b * hkv, g, d)
    c2 = c.reshape(b * hkv, g, 1)
    kp2 = k_packed.reshape(b * hkv, t, d // 2)
    vp2 = v_packed.reshape(b * hkv, t, d // 2)

    kernel = functools.partial(_kv4_decode_kernel, bt=bt, nt=nt, hkv=hkv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, nt),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, ti, L: (bh, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda bh, ti, L: (bh, 0, 0)),
            pl.BlockSpec((1, bt, d // 2), lambda bh, ti, L: (bh, ti, 0)),
            pl.BlockSpec((1, bt, d // 2), lambda bh, ti, L: (bh, ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, g, d), lambda bh, ti, L: (bh, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda bh, ti, L: (bh, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    acc, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, g, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(length.astype(jnp.int32), qt2, c2, kp2, vp2)

    # --- affine post-fold: out = s_v ⊙ (acc / l) − s_v ⊙ z_v ---
    acc = acc.reshape(b, hkv, g, d)
    l = l.reshape(b, hkv, g, 1)
    sv = v_scale                                       # [B, Hkv, 1, D]
    zv = v_zero
    out = sv * (acc / l) - sv * zv
    return out.reshape(b, hq, d)
