"""JAX version-compatibility shims for the Pallas TPU kernels.

The TPU compiler-params dataclass was renamed across JAX releases
(``pltpu.TPUCompilerParams`` → ``pltpu.CompilerParams``). Every kernel in
this package goes through :func:`tpu_compiler_params` so the rest of the
code is pinned-version agnostic.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["tpu_compiler_params"]

_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", None
) or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build the TPU compiler-params object under either JAX naming."""
    return _COMPILER_PARAMS_CLS(**kwargs)
