"""On-the-fly activation quantization kernel (the FMPQ runtime step).

Quantizes a float activation tile to packed int4 (biased nibbles, blocked
interleave) or int8, emitting per-(row, 128-block) scales. Fused into a
single pass over the data so the serving path pays one HBM read of the
fp activation and one write of the (4×/2× smaller) quantized payload.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

BLOCK_K = 128
HALF = BLOCK_K // 2

__all__ = ["act_quant_int4", "act_quant_int8"]


def _act_quant4_kernel(x_ref, p_ref, s_ref, *, nblk):
    x = x_ref[...]                                     # [bm, nblk*128] f32
    bm = x.shape[0]
    xb = x.reshape(bm, nblk, BLOCK_K)
    amax = jnp.max(jnp.abs(xb), axis=2, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 7.0
    q = jnp.clip(jnp.round(xb / scale), -8, 7).astype(jnp.int32) + 8
    qu = q.astype(jnp.uint8)
    lo = qu[:, :, :HALF]
    hi = qu[:, :, HALF:]
    packed = (lo | (hi << 4)).astype(jnp.uint8)        # [bm, nblk, 64]
    p_ref[...] = packed.reshape(bm, nblk * HALF)
    s_ref[...] = scale[:, :, 0].astype(jnp.float32)


def _act_quant8_kernel(x_ref, q_ref, s_ref, *, nblk):
    x = x_ref[...]
    bm = x.shape[0]
    xb = x.reshape(bm, nblk, BLOCK_K)
    amax = jnp.max(jnp.abs(xb), axis=2, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xb / scale), -128, 127).astype(jnp.int8)
    q_ref[...] = q.reshape(bm, nblk * BLOCK_K)
    s_ref[...] = scale[:, :, 0].astype(jnp.float32)


def act_quant_int4(
    x: jax.Array, *, bm: int = 256, bk: int = 512, interpret: bool = False
):
    """x: [M, K] float → (packed uint8 [M, K/2], scale f32 [M, K/128])."""
    m, k = x.shape
    if k % BLOCK_K:
        raise ValueError(f"K={k} must be a multiple of {BLOCK_K}")
    bk = min(bk, k)
    nblk = bk // BLOCK_K
    grid = (pl.cdiv(m, bm), k // bk)
    kernel = functools.partial(_act_quant4_kernel, nblk=nblk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bk // 2), lambda i, j: (i, j)),
            pl.BlockSpec((bm, nblk), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k // 2), jnp.uint8),
            jax.ShapeDtypeStruct((m, k // BLOCK_K), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x)


def act_quant_int8(
    x: jax.Array, *, bm: int = 256, bk: int = 512, interpret: bool = False
):
    """x: [M, K] float → (int8 [M, K], scale f32 [M, K/128])."""
    m, k = x.shape
    if k % BLOCK_K:
        raise ValueError(f"K={k} must be a multiple of {BLOCK_K}")
    bk = min(bk, k)
    nblk = bk // BLOCK_K
    grid = (pl.cdiv(m, bm), k // bk)
    kernel = functools.partial(_act_quant8_kernel, nblk=nblk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, nblk), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, k // BLOCK_K), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x)
