"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth for tests (``assert_allclose`` sweeps) and the
portable fallback used on CPU (dry-run lowering) where a TPU Pallas body
would otherwise run through the slow interpreter.

Each oracle consumes the *same packed data structures* as its kernel so
that XLA's cost/memory analysis of the ref path reflects the true packed
byte traffic (this is what the roofline reads).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantizer as Q
from repro.kernels.kv4_attention import NEG_INF, _unpack_nibbles_f32
from repro.kernels.paged_attention import combine_work_partials

__all__ = [
    "w4a4_matmul_ref",
    "w4a8_matmul_ref",
    "w4ax_matmul_ref",
    "kv4_decode_attention_ref",
    "paged_kv4_decode_attention_ref",
    "paged_kv4_prefill_attention_ref",
    "paged_kv4_decode_attention_wq_ref",
    "paged_kv4_prefill_attention_wq_ref",
    "act_quant_ref",
]


def _unpack_w(w_packed: jax.Array, block_size: int) -> jax.Array:
    """Interleaved packed [K/2, N] uint8 → int8 [K, N] (sign-corrected)."""
    return Q.unpack_int4_interleaved(w_packed, axis=0, block_size=block_size)


def w4a4_matmul_ref(
    a_packed: jax.Array,   # [M, K/2] uint8 — blocked-interleave packed int4 acts
    a_scale: jax.Array,    # [M, K/B] f32 per-(row, block) scales
    w_packed: jax.Array,   # [K/2, N] uint8 — interleaved packed int4 weights
    w_scale: jax.Array,    # [K/B, N] f32 per-(block, col) scales
    block_size: int = 128,
) -> jax.Array:
    """Uniform W4A4 GEMM with per-block dequant at the int32 boundary."""
    a = Q.unpack_int4_interleaved(a_packed, axis=1, block_size=block_size)
    w = _unpack_w(w_packed, block_size)           # [K, N] int8 in [-8, 7]
    m, k = a.shape
    n = w.shape[1]
    nb = k // block_size
    ab = a.reshape(m, nb, block_size).astype(jnp.int32)
    wb = w.reshape(nb, block_size, n).astype(jnp.int32)
    # int32 per-block partial dot: [M, nb, N]
    part = jnp.einsum("mbk,bkn->mbn", ab, wb)
    out = jnp.einsum(
        "mbn,mb,bn->mn",
        part.astype(jnp.float32),
        a_scale.astype(jnp.float32),
        w_scale.astype(jnp.float32),
    )
    return out


def w4a8_matmul_ref(
    a_q: jax.Array,        # [M, K] int8 activations
    a_scale: jax.Array,    # [M, K/B] f32
    w_packed: jax.Array,   # [K/2, N] uint8 packed int4 weights
    w_scale: jax.Array,    # [K/B, N] f32
    block_size: int = 128,
) -> jax.Array:
    """Uniform W4A8 GEMM: int4 weights are converted up to int8 (§4.3)."""
    w = _unpack_w(w_packed, block_size)
    m, k = a_q.shape
    n = w.shape[1]
    nb = k // block_size
    ab = a_q.reshape(m, nb, block_size).astype(jnp.int32)
    wb = w.reshape(nb, block_size, n).astype(jnp.int32)
    part = jnp.einsum("mbk,bkn->mbn", ab, wb)
    return jnp.einsum(
        "mbn,mb,bn->mn",
        part.astype(jnp.float32),
        a_scale.astype(jnp.float32),
        w_scale.astype(jnp.float32),
    )


def w4ax_matmul_ref(
    a4_packed: jax.Array,  # [M, K4/2] uint8 — INT4 blocks (leading K4 channels)
    a4_scale: jax.Array,   # [M, K4/B]
    a8_q: jax.Array,       # [M, K8] int8 — INT8 blocks (trailing channels)
    a8_scale: jax.Array,   # [M, K8/B]
    w4_packed: jax.Array,  # [K4/2, N]
    w4_scale: jax.Array,   # [K4/B, N]
    w8_packed: jax.Array,  # [K8/2, N]  (weights stay int4 in both halves)
    w8_scale: jax.Array,   # [K8/B, N]
    block_size: int = 128,
) -> jax.Array:
    """Mixed-precision W4Ax GEMM (paper's kernel): K4 channels in W4A4,
    the remaining K8 in W4A8, accumulated into one output.

    Channel permutation (FMPQ) guarantees the INT8 blocks are the trailing
    channels, so the mixed GEMM is exactly the sum of two uniform GEMMs.
    """
    parts = []
    if a4_packed.shape[1] > 0:
        parts.append(
            w4a4_matmul_ref(a4_packed, a4_scale, w4_packed, w4_scale, block_size)
        )
    if a8_q.shape[1] > 0:
        parts.append(w4a8_matmul_ref(a8_q, a8_scale, w8_packed, w8_scale, block_size))
    if not parts:
        raise ValueError("empty GEMM")
    out = parts[0]
    for p in parts[1:]:
        out = out + p
    return out


def kv4_decode_attention_ref(
    q: jax.Array,          # [B, Hq, D] f32/bf16 — one decode step's queries
    k_packed: jax.Array,   # [B, Hkv, T, D/2] uint8 — int4 KV cache (asym)
    k_scale: jax.Array,    # [B, Hkv, 1, D]
    k_zero: jax.Array,     # [B, Hkv, 1, D]
    v_packed: jax.Array,   # [B, Hkv, T, D/2]
    v_scale: jax.Array,    # [B, Hkv, 1, D]
    v_zero: jax.Array,     # [B, Hkv, 1, D]
    length: jax.Array | None = None,  # [B] valid KV lengths (<= T)
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Decode attention over a channel-wise-asymmetric int4 KV cache.

    GQA: Hq = G * Hkv; query head h attends with KV head h // G.
    Returns [B, Hq, D] in float32.

    ``compute_dtype=bf16`` (serving path, §Perf cell A iteration 3):
    the Pallas kernel keeps the nibble expansion in VMEM; the portable
    path at least halves the materialized convert traffic by keeping the
    dequantized operands bf16 with f32 MXU accumulation. Tests use the
    f32 default as the exact oracle.
    """
    b, hq, d = q.shape
    hkv = k_packed.shape[1]
    g = hq // hkv
    t = k_packed.shape[2]

    k_deq = Q.dequantize_kv_channelwise(
        k_packed, k_scale, k_zero).astype(compute_dtype)
    v_deq = Q.dequantize_kv_channelwise(
        v_packed, v_scale, v_zero).astype(compute_dtype)

    qg = q.reshape(b, hkv, g, d).astype(compute_dtype)
    scores = jnp.einsum("bhgd,bhtd->bhgt", qg, k_deq,
                        preferred_element_type=jnp.float32) / jnp.sqrt(
        float(d))
    if length is not None:
        mask = jnp.arange(t)[None, None, None, :] < length[:, None, None, None]
        scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", p.astype(compute_dtype), v_deq,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, d)


def paged_kv4_decode_attention_ref(
    q: jax.Array,             # [B, Hq, D] — decode-step queries
    k_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical K pages
    k_scale: jax.Array,       # [Hkv, 1, D] (or [B, Hkv, 1, D]) f32
    k_zero: jax.Array,        # [Hkv, 1, D] f32
    v_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical V pages
    v_scale: jax.Array,       # [Hkv, 1, D] f32
    v_zero: jax.Array,        # [Hkv, 1, D] f32
    block_tables: jax.Array,  # [B, NP] int32 (-1/unmapped → clamped to 0)
    length: jax.Array,        # [B] int32 valid lengths
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Oracle for the paged kernel: gather pages in jnp, then run the
    contiguous oracle. The gather is what the Pallas kernel's block-table
    index_map eliminates; here it *defines* the expected semantics."""
    b, hq, d = q.shape
    ps, hkv = k_pool.shape[1], k_pool.shape[2]
    npages = block_tables.shape[1]
    tables = jnp.maximum(block_tables.astype(jnp.int32), 0)

    def gather(pool):
        pages = pool[tables]                     # [B, NP, ps, Hkv, D/2]
        flat = pages.reshape(b, npages * ps, hkv, d // 2)
        return flat.swapaxes(1, 2)               # [B, Hkv, NP·ps, D/2]

    def bcast(s):
        return jnp.broadcast_to(s, (b, hkv, 1, d))

    return kv4_decode_attention_ref(
        q, gather(k_pool), bcast(k_scale), bcast(k_zero),
        gather(v_pool), bcast(v_scale), bcast(v_zero), length,
        compute_dtype=compute_dtype,
    )


def paged_kv4_prefill_attention_ref(
    q: jax.Array,             # [B, C, Hq, D] — one prefill chunk's queries
    k_new: jax.Array,         # [B, C, Hkv, D] fp — the chunk's in-flight keys
    v_new: jax.Array,         # [B, C, Hkv, D] fp — the chunk's in-flight values
    k_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical K pages
    k_scale: jax.Array,       # [Hkv, 1, D] f32
    k_zero: jax.Array,        # [Hkv, 1, D] f32
    v_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical V pages
    v_scale: jax.Array,       # [Hkv, 1, D] f32
    v_zero: jax.Array,        # [Hkv, 1, D] f32
    block_tables: jax.Array,  # [B, NP] int32 (-1/unmapped → clamped to 0)
    ctx_lens: jax.Array,      # [B] int32 — tokens already paged (history)
    q_lens: jax.Array,        # [B] int32 — valid chunk tokens (≤ C)
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Oracle for the chunked-prefill kernel.

    Query i of sequence b sits at absolute position ``ctx_lens[b] + i``
    and attends over (a) the int4 paged history [0, ctx_lens[b]) gathered
    and dequantized here, and (b) the causal fp prefix of the in-flight
    chunk ``k_new[b, :i+1]``. Rows i ≥ q_lens[b] are padding: they get
    finite garbage (never NaN) and must be masked by the caller.
    Returns [B, C, Hq, D] f32.
    """
    b, c, hq, d = q.shape
    ps, hkv = k_pool.shape[1], k_pool.shape[2]
    g = hq // hkv
    npages = block_tables.shape[1]
    t_hist = npages * ps
    tables = jnp.maximum(block_tables.astype(jnp.int32), 0)

    def bcast(s):
        return jnp.broadcast_to(s, (b, hkv, 1, d))

    def gather_deq(pool, scale, zero):
        pages = pool[tables]                     # [B, NP, ps, Hkv, D/2]
        flat = pages.reshape(b, t_hist, hkv, d // 2).swapaxes(1, 2)
        return Q.dequantize_kv_channelwise(
            flat, bcast(scale), bcast(zero)).astype(compute_dtype)

    kh = gather_deq(k_pool, k_scale, k_zero)     # [B, Hkv, Th, D]
    vh = gather_deq(v_pool, v_scale, v_zero)
    kn = k_new.swapaxes(1, 2).astype(compute_dtype)   # [B, Hkv, C, D]
    vn = v_new.swapaxes(1, 2).astype(compute_dtype)
    keys = jnp.concatenate([kh, kn], axis=2)     # [B, Hkv, Th+C, D]
    vals = jnp.concatenate([vh, vn], axis=2)

    qg = q.reshape(b, c, hkv, g, d).astype(compute_dtype)
    scores = jnp.einsum("bchgd,bhtd->bhgct", qg, keys,
                        preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.float32(d))                          # [B, Hkv, G, C, Th+C]

    tpos = jnp.arange(t_hist + c)
    hist_valid = tpos[None, :] < ctx_lens[:, None]          # [B, T]
    j = tpos - t_hist                                        # chunk-local key
    i = jnp.arange(c)
    chunk_valid = ((j[None, None, :] <= i[None, :, None])
                   & (j[None, None, :] < q_lens[:, None, None]))  # [B, C, T]
    valid = jnp.where((tpos < t_hist)[None, None, :],
                      hist_valid[:, None, :], chunk_valid)   # [B, C, T]
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgct,bhtd->bhgcd", p.astype(compute_dtype), vals,
                     preferred_element_type=jnp.float32)
    out = jnp.moveaxis(out, 3, 1)                # [B, C, Hkv, G, D]
    return out.reshape(b, c, hq, d)


def _wq_item_pages(pool, pages, heads):
    """Gather each work item's page for its kv head → [W, ps, D/2]."""
    return jax.vmap(lambda p, h: pool[p, :, h])(pages, heads)


def paged_kv4_decode_attention_wq_ref(
    q: jax.Array,             # [B, Hq, D] — decode-step queries
    k_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical K pages
    k_scale: jax.Array,       # [Hkv, 1, D] (or [B, Hkv, 1, D]) f32
    k_zero: jax.Array,        # [Hkv, 1, D] f32
    v_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical V pages
    v_scale: jax.Array,       # [Hkv, 1, D] f32
    v_zero: jax.Array,        # [Hkv, 1, D] f32
    work_items: jax.Array,    # [W, 4] int32 (row, phys_page, count, kind)
) -> jax.Array:
    """Oracle for the work-queue decode kernel: compute every item's
    partial flash triple in one vectorized pass, then run the SAME
    split-KV combine the Pallas wrapper uses. The descriptor walk here
    *defines* the schedule's semantics — Σ real pages of work, combined
    by row segment — independent of how the grid binds items to cores."""
    b, hq, d = q.shape
    ps, hkv = k_pool.shape[1], k_pool.shape[2]
    g = hq // hkv
    nrows = b * hkv
    desc = jnp.asarray(work_items, jnp.int32)
    rcl = jnp.minimum(desc[:, 0], nrows - 1)
    heads = rcl % hkv
    counts = desc[:, 2]

    def bcast(s):
        return jnp.broadcast_to(s, (b, hkv, 1, d))

    sm = 1.0 / jnp.sqrt(jnp.float32(d))
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    qt = (qg * bcast(k_scale) * sm).reshape(nrows, g, d)
    c = jnp.sum(qt.reshape(b, hkv, g, d) * bcast(k_zero),
                axis=-1, keepdims=True).reshape(nrows, g, 1)

    nk = _unpack_nibbles_f32(_wq_item_pages(k_pool, desc[:, 1], heads))
    nv = _unpack_nibbles_f32(_wq_item_pages(v_pool, desc[:, 1], heads))
    s = jnp.einsum("wgd,wpd->wgp", qt[rcl], nk,
                   preferred_element_type=jnp.float32) - c[rcl]
    pos = jnp.arange(ps)[None, None, :]
    s = jnp.where(pos < counts[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)             # [W, G, 1]
    p = jnp.exp(s - m)
    acc = jnp.einsum("wgp,wpd->wgd", p, nv,
                     preferred_element_type=jnp.float32)
    l = jnp.sum(p, axis=-1, keepdims=True)

    comb = combine_work_partials(acc, l, m, desc[:, 0], nrows)
    sv = bcast(v_scale)
    zv = bcast(v_zero)
    out = sv * comb.reshape(b, hkv, g, d) - sv * zv
    return out.reshape(b, hq, d)


def paged_kv4_prefill_attention_wq_ref(
    q: jax.Array,             # [B, C, Hq, D] — one prefill chunk's queries
    k_new: jax.Array,         # [B, C, Hkv, D] fp in-flight chunk keys
    v_new: jax.Array,         # [B, C, Hkv, D] fp in-flight chunk values
    k_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical K pages
    k_scale: jax.Array,       # [Hkv, 1, D] f32
    k_zero: jax.Array,        # [Hkv, 1, D] f32
    v_pool: jax.Array,        # [P, ps, Hkv, D/2] uint8 physical V pages
    v_scale: jax.Array,       # [Hkv, 1, D] f32
    v_zero: jax.Array,        # [Hkv, 1, D] f32
    work_items: jax.Array,    # [W, 4] int32 (row, phys_page, count, kind)
) -> jax.Array:
    """Oracle for the work-queue prefill kernel: per-item partials for
    both item kinds (int4 history page / causal fp chunk), selected by
    ``kind``, then the shared split-KV combine. Rows past a row's q_len
    are padding garbage — mask outside. Returns [B, C, Hq, D] f32."""
    b, c, hq, d = q.shape
    ps, hkv = k_pool.shape[1], k_pool.shape[2]
    g = hq // hkv
    cg = c * g
    nrows = b * hkv
    desc = jnp.asarray(work_items, jnp.int32)
    rcl = jnp.minimum(desc[:, 0], nrows - 1)
    heads = rcl % hkv
    counts = desc[:, 2]
    kinds = desc[:, 3]

    sm = 1.0 / jnp.sqrt(jnp.float32(d))
    qg = jnp.moveaxis(q.reshape(b, c, hkv, g, d).astype(jnp.float32), 1, 2)
    ksb = jnp.broadcast_to(k_scale, (hkv, 1, d)).reshape(1, hkv, 1, 1, d)
    kzb = jnp.broadcast_to(k_zero, (hkv, 1, d)).reshape(1, hkv, 1, 1, d)
    qt = qg * ksb * sm
    cterm = jnp.sum(qt * kzb, axis=-1, keepdims=True)
    qt2 = qt.reshape(nrows, cg, d)
    c2 = cterm.reshape(nrows, cg, 1)
    qs2 = (qg * sm).reshape(nrows, cg, d)
    kn2 = k_new.astype(jnp.float32).swapaxes(1, 2).reshape(nrows, c, d)
    vn2 = v_new.astype(jnp.float32).swapaxes(1, 2).reshape(nrows, c, d)
    vsb = jnp.broadcast_to(v_scale, (hkv, 1, d))[heads]     # [W, 1, D]
    vzb = jnp.broadcast_to(v_zero, (hkv, 1, d))[heads]

    # --- kind 0: int4 history pages (V affine folded per item) ---
    nk = _unpack_nibbles_f32(_wq_item_pages(k_pool, desc[:, 1], heads))
    nv = _unpack_nibbles_f32(_wq_item_pages(v_pool, desc[:, 1], heads))
    s_h = jnp.einsum("wgd,wpd->wgp", qt2[rcl], nk,
                     preferred_element_type=jnp.float32) - c2[rcl]
    pos = jnp.arange(ps)[None, None, :]
    s_h = jnp.where(pos < counts[:, None, None], s_h, NEG_INF)
    m_h = jnp.max(s_h, axis=-1, keepdims=True)         # [W, CG, 1]
    p_h = jnp.exp(s_h - m_h)
    l_h = jnp.sum(p_h, axis=-1, keepdims=True)
    pv = jnp.einsum("wgp,wpd->wgd", p_h, nv,
                    preferred_element_type=jnp.float32)
    acc_h = pv * vsb - l_h * (vsb * vzb)

    # --- kind 1: the row's in-flight fp chunk, causal over count ---
    s_c = jnp.einsum("wgd,wcd->wgc", qs2[rcl], kn2[rcl],
                     preferred_element_type=jnp.float32)
    qi = (jnp.arange(cg) // g)[None, :, None]
    kj = jnp.arange(c)[None, None, :]
    s_c = jnp.where((kj <= qi) & (kj < counts[:, None, None]), s_c, NEG_INF)
    m_c = jnp.max(s_c, axis=-1, keepdims=True)
    p_c = jnp.exp(s_c - m_c)
    l_c = jnp.sum(p_c, axis=-1, keepdims=True)
    acc_c = jnp.einsum("wgc,wcd->wgd", p_c, vn2[rcl],
                       preferred_element_type=jnp.float32)

    sel = (kinds != 0)[:, None, None]
    acc = jnp.where(sel, acc_c, acc_h)
    l = jnp.where(sel, l_c, l_h)
    m = jnp.where(sel, m_c, m_h)

    out = combine_work_partials(acc, l, m, desc[:, 0], nrows)
    out = out.reshape(b, hkv, c, g, d)
    out = jnp.moveaxis(out, 2, 1)                      # [B, C, Hkv, G, D]
    return out.reshape(b, c, hq, d)


def act_quant_ref(x: jax.Array, block_size: int = 128, bits: int = 4):
    """Oracle for the on-the-fly activation quantization kernel.

    x: [M, K] → (packed-or-int8 payload, scale [M, K/B]).
    bits=4 returns packed uint8 [M, K/2]; bits=8 returns int8 [M, K].
    """
    q, s = Q.quantize_act_groupwise(x, block_size=block_size, bits=bits)
    if bits == 4:
        return Q.pack_int4_interleaved(q, axis=1, block_size=block_size), s
    return q, s
