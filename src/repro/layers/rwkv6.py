"""RWKV-6 "Finch" block — data-dependent per-channel decay linear attention.

Time-mix recurrence per head (d = head_dim):
    y_t = r_t · S_{t-1}  +  (r_t ⊙ u · k_t) · v_t
    S_t = diag(w_t) · S_{t-1}  +  k_t ⊗ v_t
with w_t = exp(−exp(w0 + LoRA(x̃_t))) ∈ (0,1) per channel (data-dependent,
the Finch contribution), u a learned per-channel "bonus" for the current
token, and x̃ the token-shift interpolation.

Training/prefill uses a chunked parallel form (GLA-style): within chunks
the recurrence is a masked matmul against cumulative decay products;
across chunks a lax.scan carries S [B, H, dk, dv]. Decode is the O(1)
recurrent step. Channel-mix is the squared-ReLU RWKV FFN.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers import common as C
from repro.layers.common import Annotated

__all__ = [
    "init_rwkv6",
    "rwkv6_train",
    "rwkv6_decode",
    "init_rwkv6_state",
]


def _dims(cfg: ModelConfig):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    return d, d // hd, hd


def init_rwkv6(key, cfg: ModelConfig):
    d, h, hd = _dims(cfg)
    ks = jax.random.split(key, 12)
    lora = cfg.rwkv_decay_lora

    def mix(i):
        return Annotated(
            jax.random.uniform(ks[i], (d,), jnp.float32, 0.0, 1.0), ("embed",))

    return {
        # token-shift interpolation coefficients
        "mu_r": mix(0), "mu_k": mix(1), "mu_v": mix(2),
        "mu_g": mix(3), "mu_w": mix(4),
        "w_r": C.init_linear(ks[5], d, d, ("embed", "qdim")),
        "w_k": C.init_linear(ks[6], d, d, ("embed", "qdim")),
        "w_v": C.init_linear(ks[7], d, d, ("embed", "qdim")),
        "w_g": C.init_linear(ks[8], d, d, ("embed", "qdim")),
        "w_o": C.init_linear(ks[9], d, d, ("qdim", "embed")),
        # data-dependent decay: w0 + tanh(x̃ A) B
        "decay_w0": Annotated(
            jnp.full((d,), -6.0, jnp.float32) +
            0.5 * jax.random.normal(ks[10], (d,)), ("embed",)),
        "decay_A": C.dense_init(ks[10], (d, lora), ("embed", None)),
        "decay_B": C.dense_init(ks[11], (lora, d), (None, "embed"), scale=0.01),
        "bonus_u": Annotated(
            0.5 * jax.random.normal(ks[11], (h, hd)), (None, None)),
        "ln_x": C.init_norm("layernorm", d, ("embed",)),
    }


def _token_shift(x, x_prev):
    """x: [B, L, D]; x_prev: [B, 1, D] last token of previous segment."""
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _projections(params, x, xs):
    def mixed(mu):
        return x + (xs - x) * mu[None, None, :]

    r = C.linear(params["w_r"], mixed(params["mu_r"]))
    k = C.linear(params["w_k"], mixed(params["mu_k"]))
    v = C.linear(params["w_v"], mixed(params["mu_v"]))
    g = C.linear(params["w_g"], mixed(params["mu_g"]))
    xw = mixed(params["mu_w"]).astype(jnp.float32)
    lw = params["decay_w0"] + jnp.tanh(
        xw @ params["decay_A"]) @ params["decay_B"]
    logw = -jnp.exp(lw)                                 # log decay ≤ 0
    return r, k, v, g, logw


def _chunked_linear_attn(r, k, v, logw, u, chunk):
    """r/k/v: [B, L, H, D]; logw: [B, L, H, D] (log decay); u: [H, D]."""
    b, l, h, d = r.shape
    q = min(chunk, l)
    l_orig = l
    pad = (-l) % q
    if pad:
        # logw=0 (decay=1) + k=0 padding is exact: state unchanged, y=0
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc = l // q
    rr = r.reshape(b, nc, q, h, d).astype(jnp.float32)
    kk = k.reshape(b, nc, q, h, d).astype(jnp.float32)
    vv = v.reshape(b, nc, q, h, d).astype(jnp.float32)
    # Clamp per-step log-decay at −64/Q so the factored 1/P_s term stays
    # within f32 exp range for the whole chunk. A channel at the clamp
    # (w = e^{−64/Q} ≈ 0.6 for Q=128) forgets to ~1e−28 within one chunk,
    # so this is functionally lossless "instant forget".
    lw = jnp.maximum(logw.reshape(b, nc, q, h, d), -64.0 / q)

    cum = jnp.cumsum(lw, axis=2)                        # logP_t (inclusive)
    p_in = jnp.exp(cum - lw)                            # P_{t-1} (exclusive)
    p_out = jnp.exp(cum[:, :, -1:, :, :] - cum)         # P_Q / P_t
    p_end = jnp.exp(cum[:, :, -1, :, :])                # P_Q

    # intra-chunk: A[t,s] = ((r_t ⊙ P_{t-1}/P_s) · k_s)  for s < t
    #              A[t,t] = (r_t ⊙ u) · k_t
    rp = rr * p_in                                      # r_t ⊙ P_{t-1}
    kp = kk * jnp.exp(-cum)                             # k_s / P_s (inclusive)
    scores = jnp.einsum("bcthd,bcshd->bchts", rp, kp)   # s<t part
    tri = jnp.tril(jnp.ones((q, q), bool), -1)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    diag = jnp.einsum("bcthd,hd,bcthd->bcth", rr, u, kk)
    y_intra = jnp.einsum("bchts,bcshd->bcthd", scores, vv)
    y_intra += diag[..., None] * vv

    # chunk state contribution: S_c = diag(P_Q) S_{c-1} + Σ_s diag(P_Q/P_s) k_s ⊗ v_s
    s_chunk = jnp.einsum("bcshd,bcshe->bchde", kk * p_out, vv)

    def scan_fn(s_prev, inp):
        s_c, dec = inp
        return s_prev * dec[..., None] + s_c, s_prev

    _, s_before = jax.lax.scan(
        scan_fn,
        jnp.zeros((b, h, d, d), jnp.float32),
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(p_end, 1, 0)),
    )
    s_before = jnp.moveaxis(s_before, 0, 1)             # [B,NC,H,D,D]
    y_inter = jnp.einsum("bcthd,bchde->bcthe", rp, s_before)
    y = (y_intra + y_inter).reshape(b, l, h, d)
    # final state for the caller (prefill → decode handoff)
    last_dec = p_end[:, -1]
    s_final = s_before[:, -1] * last_dec[..., None] + s_chunk[:, -1]
    return y[:, :l_orig], s_final


def rwkv6_train(params, cfg: ModelConfig, x, state=None):
    """Time-mix + output. x: [B, L, D] → (y [B, L, D], final state)."""
    d, h, hd = _dims(cfg)
    b, l, _ = x.shape
    x_prev = (state["shift_tm"] if state is not None
              else jnp.zeros((b, 1, d), x.dtype))
    xs = _token_shift(x, x_prev)
    r, k, v, g, logw = _projections(params, x, xs)
    rr = r.reshape(b, l, h, hd)
    kk = k.reshape(b, l, h, hd)
    vv = v.reshape(b, l, h, hd)
    lw = logw.reshape(b, l, h, hd)
    y, s_final = _chunked_linear_attn(
        rr, kk, vv, lw, params["bonus_u"], cfg.ssm_chunk or 128)
    y = y.reshape(b, l, d)
    y = C.layernorm(y, params["ln_x"]["scale"], params["ln_x"]["bias"],
                    cfg.norm_eps)
    y = y * jax.nn.silu(g.astype(jnp.float32))
    out = C.linear(params["w_o"], y.astype(x.dtype))
    new_state = {"s": s_final, "shift_tm": x[:, -1:, :]}
    return out, new_state


def init_rwkv6_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    d, h, hd = _dims(cfg)
    return {
        "s": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((batch, 1, d), dtype),
        "shift_cm": jnp.zeros((batch, 1, d), dtype),
    }


def rwkv6_decode(params, cfg: ModelConfig, x, state):
    """One-token step. x: [B, 1, D]."""
    d, h, hd = _dims(cfg)
    b = x.shape[0]
    xs = state["shift_tm"]
    r, k, v, g, logw = _projections(params, x, xs)
    rr = r.reshape(b, h, hd).astype(jnp.float32)
    kk = k.reshape(b, h, hd).astype(jnp.float32)
    vv = v.reshape(b, h, hd).astype(jnp.float32)
    q = cfg.ssm_chunk or 128                            # match train clamp
    w = jnp.exp(jnp.maximum(logw.reshape(b, h, hd), -64.0 / q))
    u = params["bonus_u"]
    s = state["s"]
    kv = jnp.einsum("bhd,bhe->bhde", kk, vv)
    y = jnp.einsum("bhd,bhde->bhe", rr, s) + jnp.einsum(
        "bhd,hd,bhde->bhe", rr, u, kv)
    s_new = s * w[..., None] + kv
    y = y.reshape(b, 1, d)
    y = C.layernorm(y, params["ln_x"]["scale"], params["ln_x"]["bias"],
                    cfg.norm_eps)
    y = y * jax.nn.silu(g.astype(jnp.float32))
    out = C.linear(params["w_o"], y.astype(x.dtype))
    new_state = dict(state)
    new_state["s"] = s_new
    new_state["shift_tm"] = x
    return out, new_state


# ---------------------------------------------------------------------------
# Channel-mix (RWKV FFN)
# ---------------------------------------------------------------------------

def init_rwkv6_cmix(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mu_k": Annotated(
            jax.random.uniform(ks[0], (d,), jnp.float32, 0.0, 1.0), ("embed",)),
        "mu_r": Annotated(
            jax.random.uniform(ks[1], (d,), jnp.float32, 0.0, 1.0), ("embed",)),
        "w_k": C.init_linear(ks[1], d, cfg.d_ff, ("embed", "mlp")),
        "w_v": C.init_linear(ks[2], cfg.d_ff, d, ("mlp", "embed")),
        "w_r": C.init_linear(ks[2], d, d, ("embed", "qdim")),
    }


def rwkv6_cmix(params, cfg: ModelConfig, x, x_prev):
    """x: [B, L, D]; x_prev: [B, 1, D] → (y, new shift = x[:, -1:])."""
    xs = _token_shift(x, x_prev)
    xk = x + (xs - x) * params["mu_k"][None, None, :]
    xr = x + (xs - x) * params["mu_r"][None, None, :]
    k = jnp.square(jax.nn.relu(C.linear(params["w_k"], xk)))
    kv = C.linear(params["w_v"], k)
    return jax.nn.sigmoid(
        C.linear(params["w_r"], xr).astype(jnp.float32)
    ).astype(x.dtype) * kv, x[:, -1:, :]
