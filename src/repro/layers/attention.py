"""Attention: chunked-flash self-attention (train/prefill), cached decode
(fp16 and quantized-KV4 paths), GQA, RoPE, optional QK-norm, cross-attn.

The train/prefill path is a pure-jnp online-softmax flash attention
(lax.scan over KV chunks) so compiled intermediates stay O(S·chunk)
instead of O(S²) — mandatory for the 32k prefill dry-run cells.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quantizer as Q
from repro.kernels import ops
from repro.layers import common as C

NEG_INF = -1e30

__all__ = [
    "init_attention",
    "flash_attention",
    "attention_train",
    "attention_prefill",
    "attention_decode_fp",
    "attention_decode_q4",
    "init_fp_cache",
    "init_q4_cache",
]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 5)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": C.init_linear(ks[0], d, qd, ("embed", "qdim"), bias=cfg.qkv_bias),
        "wk": C.init_linear(ks[1], d, kvd, ("embed", "kvdim"), bias=cfg.qkv_bias),
        "wv": C.init_linear(ks[2], d, kvd, ("embed", "kvdim"), bias=cfg.qkv_bias),
        "wo": C.init_linear(ks[3], qd, d, ("qdim", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = C.init_norm("rmsnorm", cfg.head_dim, (None,))
        p["k_norm"] = C.init_norm("rmsnorm", cfg.head_dim, (None,))
    return p


def _project_qkv(params, cfg: ModelConfig, xq, xkv, positions_q, positions_kv,
                 use_rope: bool = True, num_heads: Optional[int] = None,
                 num_kv_heads: Optional[int] = None):
    """``num_heads``/``num_kv_heads`` override the config head counts for
    TP-sharded callers whose wq/wk/wv hold a per-shard head slice (RoPE
    and QK-norm are per-head, so the local slice needs no other care)."""
    b = xq.shape[0]
    nh = cfg.num_heads if num_heads is None else num_heads
    nkv = cfg.num_kv_heads if num_kv_heads is None else num_kv_heads
    q = C.linear(params["wq"], xq).reshape(b, -1, nh, cfg.head_dim)
    k = C.linear(params["wk"], xkv).reshape(b, -1, nkv, cfg.head_dim)
    v = C.linear(params["wv"], xkv).reshape(b, -1, nkv, cfg.head_dim)
    if cfg.qk_norm:
        q = C.rmsnorm(q, params["q_norm"]["scale"], cfg.norm_eps)
        k = C.rmsnorm(k, params["k_norm"]["scale"], cfg.norm_eps)
    if use_rope:
        q = C.apply_rope(q, positions_q, cfg.rope_theta)
        k = C.apply_rope(k, positions_kv, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Chunked flash attention (pure jnp, O(S·chunk) memory)
# ---------------------------------------------------------------------------

def flash_attention(
    q: jax.Array,          # [B, S, H, D]
    k: jax.Array,          # [B, T, Hkv, D]
    v: jax.Array,          # [B, T, Hkv, D]
    *,
    causal: bool = True,
    q_offset: int = 0,     # absolute position of q[0] (for causal masking)
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    b, s, h, d = q.shape
    t_orig, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t_orig)
    s_pad = (-s) % q_chunk
    t_pad = (-t_orig) % kv_chunk
    if s_pad:
        q = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    if t_pad:
        k = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    s_full, t = s + s_pad, t_orig + t_pad
    nq, nk = s_full // q_chunk, t // kv_chunk

    sm = 1.0 / jnp.sqrt(jnp.float32(d))
    qs = (q.astype(jnp.float32) * sm).reshape(b, nq, q_chunk, hkv, g, d)
    qs = jnp.moveaxis(qs, 1, 0)                       # [nq, B, qc, Hkv, G, D]
    ks = jnp.moveaxis(k.reshape(b, nk, kv_chunk, hkv, d), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nk, kv_chunk, hkv, d), 1, 0)

    def q_step(_, qi_qc):
        qi, qc = qi_qc                                # qc: [B, qcnk, Hkv, G, D]

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kc, vc = ki_kv
            sc = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qc, kc.astype(jnp.float32)
            )                                          # [B,Hkv,G,qc,kc]
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            if causal:
                qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
                mask = (qpos[:, None] >= kpos[None, :]) & (
                    kpos[None, :] < t_orig)
                sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            elif t_pad:
                sc = jnp.where((kpos < t_orig)[None, None, None, None],
                               sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new[..., None])
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
            acc = acc * alpha[..., None] + pv
            l = l * alpha + jnp.sum(p, axis=-1)
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs)
        )
        out = acc / jnp.maximum(l, 1e-20)[..., None]   # [B,Hkv,G,qc,D]
        return None, out

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    # outs: [nq, B, Hkv, G, qc, D] → [B, S, H, D]
    out = jnp.moveaxis(outs, 0, 3)                     # [B,Hkv,G,nq,qc,D]
    out = out.reshape(b, hkv, g, s_full, d)
    out = jnp.moveaxis(out, 3, 1).reshape(b, s_full, h, d)
    return out[:, :s]


# ---------------------------------------------------------------------------
# Modes
# ---------------------------------------------------------------------------

def attention_train(params, cfg: ModelConfig, x, positions=None,
                    kv_override=None):
    """Full self-attention (or cross-attention when kv_override given)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    xkv = x if kv_override is None else kv_override
    use_rope = kv_override is None
    pk = positions if kv_override is None else jnp.zeros(
        (b, xkv.shape[1]), jnp.int32)
    q, k, v = _project_qkv(params, cfg, x, xkv, positions, pk, use_rope)
    out = flash_attention(q, k, v, causal=cfg.causal and kv_override is None)
    out = out.astype(x.dtype).reshape(b, s, cfg.q_dim)
    return C.linear(params["wo"], out)


def attention_prefill(params, cfg: ModelConfig, x, cache, positions=None):
    """Causal self-attention + write the fp KV cache."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, cfg, x, x, positions, positions)
    out = flash_attention(q, k, v, causal=cfg.causal)
    out = out.astype(x.dtype).reshape(b, s, cfg.q_dim)
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
        "length": jnp.full((b,), s, jnp.int32),
    }
    return C.linear(params["wo"], out), cache


def init_fp_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def attention_decode_fp(params, cfg: ModelConfig, x, cache):
    """One-token decode against the fp cache. x: [B, 1, D]."""
    b = x.shape[0]
    t = cache["k"].shape[1]
    pos = cache["length"][:, None]                     # [B, 1]
    q, k, v = _project_qkv(params, cfg, x, x, pos, pos)

    def upd(c, new):
        return jax.vmap(
            lambda cb, nb, i: jax.lax.dynamic_update_slice(cb, nb, (i, 0, 0))
        )(c, new.astype(c.dtype), cache["length"])

    k_cache = upd(cache["k"], k)
    v_cache = upd(cache["v"], v)
    length = cache["length"] + 1

    qf = q[:, 0].astype(jnp.float32)                   # [B, H, D]
    g = cfg.num_heads // cfg.num_kv_heads
    qg = qf.reshape(b, cfg.num_kv_heads, g, cfg.head_dim)
    # k_cache layout is [B, T, Hkv, D]
    sc = jnp.einsum("bhgd,bThd->bhgT", qg, k_cache.astype(jnp.float32))
    sc = sc / jnp.sqrt(jnp.float32(cfg.head_dim))
    mask = jnp.arange(t)[None, None, None] < length[:, None, None, None]
    sc = jnp.where(mask, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgT,bThd->bhgd", p, v_cache.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.q_dim).astype(x.dtype)
    new_cache = {"k": k_cache, "v": v_cache, "length": length}
    return C.linear(params["wo"], out), new_cache


# ---------------------------------------------------------------------------
# Quantized KV4 cache (COMET serving path)
# ---------------------------------------------------------------------------

def init_q4_cache(cfg: ModelConfig, batch: int, max_len: int,
                  k_stats=None, v_stats=None):
    """Packed int4 cache with *static* per-channel scales/zeros.

    k_stats/v_stats: optional calibrated (scale, zero) [Hkv, 1, D]; defaults
    are generic ranges (|k| ≤ 8 post-norm works for RoPE'd keys).
    """
    hkv, d = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, hkv, max_len, d // 2)

    def default_stats(rng_range):
        scale = jnp.full((hkv, 1, d), rng_range / 15.0, jnp.float32)
        zero = jnp.full((hkv, 1, d), 7.5, jnp.float32)
        return scale, zero

    ks, kz = k_stats if k_stats is not None else default_stats(16.0)
    vs, vz = v_stats if v_stats is not None else default_stats(16.0)
    bcast = lambda a: jnp.broadcast_to(a[None], (batch, hkv, 1, d))
    return {
        "k_packed": jnp.zeros(shape, jnp.uint8),
        "v_packed": jnp.zeros(shape, jnp.uint8),
        "k_scale": bcast(ks), "k_zero": bcast(kz),
        "v_scale": bcast(vs), "v_zero": bcast(vz),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def _quant_kv_static(kv, scale, zero):
    """kv: [B, Hkv, S, D]; static per-channel affine → packed [B,Hkv,S,D/2]."""
    n = jnp.clip(jnp.round(kv / scale + zero), 0, 15).astype(jnp.uint8)
    half = n.shape[-1] // 2
    return (n[..., :half] | (n[..., half:] << 4)).astype(jnp.uint8)


def attention_prefill_q4(params, cfg: ModelConfig, x, cache, positions=None):
    """Prefill that writes the packed int4 cache."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, cfg, x, x, positions, positions)
    out = flash_attention(q, k, v, causal=cfg.causal)
    out = out.astype(x.dtype).reshape(b, s, cfg.q_dim)

    kt = k.swapaxes(1, 2).astype(jnp.float32)          # [B, Hkv, S, D]
    vt = v.swapaxes(1, 2).astype(jnp.float32)
    kp = _quant_kv_static(kt, cache["k_scale"], cache["k_zero"])
    vp = _quant_kv_static(vt, cache["v_scale"], cache["v_zero"])
    cache = dict(cache)
    cache["k_packed"] = jax.lax.dynamic_update_slice(
        cache["k_packed"], kp, (0, 0, 0, 0))
    cache["v_packed"] = jax.lax.dynamic_update_slice(
        cache["v_packed"], vp, (0, 0, 0, 0))
    cache["length"] = jnp.full((b,), s, jnp.int32)
    return C.linear(params["wo"], out), cache


def attention_decode_q4(params, cfg: ModelConfig, x, cache, *, impl="auto"):
    """One-token decode over the packed int4 KV cache (the COMET path)."""
    b = x.shape[0]
    pos = cache["length"][:, None]
    q, k, v = _project_qkv(params, cfg, x, x, pos, pos)

    kt = k.swapaxes(1, 2).astype(jnp.float32)          # [B, Hkv, 1, D]
    vt = v.swapaxes(1, 2).astype(jnp.float32)
    kp_new = _quant_kv_static(kt, cache["k_scale"], cache["k_zero"])
    vp_new = _quant_kv_static(vt, cache["v_scale"], cache["v_zero"])

    def upd(c, new):
        return jax.vmap(
            lambda cb, nb, i: jax.lax.dynamic_update_slice(cb, nb, (0, i, 0))
        )(c, new, cache["length"])

    cache = dict(cache)
    cache["k_packed"] = upd(cache["k_packed"], kp_new)
    cache["v_packed"] = upd(cache["v_packed"], vp_new)
    cache["length"] = cache["length"] + 1

    out = ops.kv4_decode_attention(
        q[:, 0], cache["k_packed"], cache["k_scale"], cache["k_zero"],
        cache["v_packed"], cache["v_scale"], cache["v_zero"],
        cache["length"], impl=impl,
    )                                                   # [B, H, D] f32
    out = out.reshape(b, 1, cfg.q_dim).astype(x.dtype)
    return C.linear(params["wo"], out), cache
