"""Feed-forward layers: SwiGLU / GELU MLP and capacity-based top-k MoE.

The MoE uses sort-based dispatch to per-expert capacity buffers
([E, C, D]) so that (a) compute is proportional to *active* experts
(capacity ≈ tokens·top_k/E · factor, not tokens·E), and (b) the expert
dimension shards cleanly over the "model" mesh axis (expert parallelism:
XLA SPMD turns the dispatch gather/scatter into all-to-alls).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers import common as C
from repro.layers.common import Annotated

__all__ = ["init_mlp", "mlp_apply", "init_moe", "moe_apply"]


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str = "swiglu"):
    ks = jax.random.split(key, 3)
    p = {
        "w_up": C.init_linear(ks[0], d_model, d_ff, ("embed", "mlp")),
        "w_down": C.init_linear(ks[1], d_ff, d_model, ("mlp", "embed")),
    }
    if act == "swiglu":
        p["w_gate"] = C.init_linear(ks[2], d_model, d_ff, ("embed", "mlp"))
    return p


def mlp_apply(params, x, act: str = "swiglu"):
    up = C.linear(params["w_up"], x)
    if act == "swiglu":
        gate = C.linear(params["w_gate"], x)
        h = jax.nn.silu(gate) * up
    elif act == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(act)
    return C.linear(params["w_down"], h)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    p = {
        "router": C.init_linear(ks[0], d, e, ("embed", "experts")),
        "w_gate": {"w": C.dense_init(ks[1], (e, d, f), ("experts", "embed", "mlp"))},
        "w_up": {"w": C.dense_init(ks[2], (e, d, f), ("experts", "embed", "mlp"))},
        "w_down": {"w": C.dense_init(ks[3], (e, f, d), ("experts", "mlp", "embed"))},
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(
            ks[4], d, cfg.moe_d_ff * cfg.num_shared_experts, "swiglu")
    return p


def _expert_linear(slot, xe):
    """Per-expert projection [E, C, K] → [E, C, N]; fp einsum or vmapped W4Ax."""
    if "w_packed" in slot:
        return jax.vmap(C.linear)(slot, xe)
    return jnp.einsum(
        "ecd,edf->ecf", xe.astype(jnp.bfloat16),
        slot["w"].astype(jnp.bfloat16))


def moe_apply(params, x, cfg: ModelConfig):
    """x: [B, S, D] → (out, aux_loss). Capacity-dropped top-k routing."""
    b, s, d = x.shape
    tkn = x.reshape(b * s, d)
    t = tkn.shape[0]
    e, k = cfg.num_experts, cfg.num_experts_per_tok

    logits = C.linear(params["router"], tkn).astype(jnp.float32)   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)          # renorm

    # ---- load-balancing aux loss (Switch-style) ----
    me = jnp.mean(probs, axis=0)                                   # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, e), axis=1), axis=0)      # [E]
    aux = jnp.sum(me * ce) * e * cfg.router_aux_loss

    # ---- sort-based dispatch to capacity buffers ----
    cap = int(cfg.capacity_factor * t * k / e)
    cap = max(cap, 4)
    flat_e = gate_idx.reshape(-1)                                  # [T·K]
    flat_w = gate_vals.reshape(-1)
    tok_of = jnp.arange(t * k) // k
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.sum(jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=0)
    starts = jnp.cumsum(counts) - counts                           # [E]
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)     # drop → OOB

    buf = jnp.zeros((e * cap + 1, d), tkn.dtype)
    buf = buf.at[slot].set(tkn[tok_of[order]], mode="drop")
    xe = buf[: e * cap].reshape(e, cap, d)                          # [E, C, D]
    # EP: experts over "model", capacity over "data" (all-to-all dispatch)
    from repro.parallel.sharding import maybe_shard
    xe = maybe_shard(xe, "model", "data", None)

    # ---- expert FFN (einsum over stacked expert weights; EP-shardable) ----
    ce_dt = xe.astype(jnp.bfloat16)
    gate = _expert_linear(params["w_gate"], ce_dt)
    up = _expert_linear(params["w_up"], ce_dt)
    h = (jax.nn.silu(gate.astype(jnp.float32)) *
         up.astype(jnp.float32)).astype(jnp.bfloat16)
    ye = _expert_linear(params["w_down"], h)

    # ---- combine back ----
    ye_flat = ye.reshape(e * cap, d)
    gathered = jnp.where(
        keep[:, None], ye_flat[jnp.clip(slot, 0, e * cap - 1)], 0.0)
    weighted = gathered.astype(jnp.float32) * flat_w[order][:, None]
    out = jax.ops.segment_sum(weighted, tok_of[order], num_segments=t)

    if "shared" in params:
        out = out + mlp_apply(params["shared"], tkn, "swiglu").astype(jnp.float32)
    return out.reshape(b, s, d).astype(x.dtype), aux
