"""Shared layer primitives: annotated params, norms, RoPE, linear layers.

Parameter convention
--------------------
``init_*`` functions return pytrees whose leaves are :class:`Annotated`
(array + logical axis names). :func:`split_annotations` separates the
tree into (params, axes) — the axes tree feeds ``parallel/sharding.py``
which maps logical names → mesh ``PartitionSpec``s.

Logical axis vocabulary:
  "embed"   d_model            "vocab"  vocabulary
  "heads"   q heads            "kv"     kv heads
  "qdim"    heads*head_dim     "kvdim"  kv_heads*head_dim
  "mlp"     FFN inner          "experts" MoE experts
  "layers"  stacked scan axis  None     replicated
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "Annotated",
    "split_annotations",
    "dense_init",
    "rmsnorm",
    "layernorm",
    "apply_norm",
    "init_norm",
    "rope_frequencies",
    "apply_rope",
    "init_linear",
    "linear",
    "init_embedding",
]


class Annotated(NamedTuple):
    value: jax.Array
    axes: tuple


def is_annotated(x) -> bool:
    return isinstance(x, Annotated)


def split_annotations(tree):
    """Annotated tree → (params tree, logical-axes tree)."""
    params = jax.tree.map(lambda a: a.value, tree, is_leaf=is_annotated)
    axes = jax.tree.map(lambda a: a.axes, tree, is_leaf=is_annotated)
    return params, axes


def dense_init(key, shape, axes, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init, annotated."""
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.maximum(1.0, fan_in))
    w = scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return Annotated(w.astype(dtype), axes)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(kind: str, d: int, axes=("embed",)):
    p = {"scale": Annotated(jnp.ones((d,), jnp.float32), axes)}
    if kind == "layernorm":
        p["bias"] = Annotated(jnp.zeros((d,), jnp.float32), axes)
    return p


def rmsnorm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(dt)


def layernorm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(dt)


def apply_norm(params, x, kind: str, eps: float = 1e-5):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"], eps)
    return layernorm(x, params["scale"], params["bias"], eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: broadcastable [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = rope_frequencies(d, theta)                       # [half]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]                      # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear (fp path; the quantized path lives in core/qlinear.py)
# ---------------------------------------------------------------------------

def init_linear(key, d_in, d_out, axes, bias: bool = False, scale=None):
    p = {"w": dense_init(key, (d_in, d_out), axes, scale=scale)}
    if bias:
        p["b"] = Annotated(jnp.zeros((d_out,), jnp.float32), (axes[1],))
    return p


# Quantized projections register a handler here (core/qlinear.py) so every
# layer's ``C.linear`` transparently dispatches fp vs W4Ax on the param
# structure ("w" vs "w_packed").
_QUANT_LINEAR_HANDLER = None


def register_quant_linear(fn):
    global _QUANT_LINEAR_HANDLER
    _QUANT_LINEAR_HANDLER = fn


def linear(params, x, compute_dtype=jnp.bfloat16):
    if "w_packed" in params:
        return _QUANT_LINEAR_HANDLER(params, x).astype(compute_dtype)
    w = params["w"].astype(compute_dtype)
    y = x.astype(compute_dtype) @ w
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int):
    return {
        "table": dense_init(key, (vocab, d), ("vocab", "embed"), scale=1.0)
    }
