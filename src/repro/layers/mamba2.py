"""Mamba2 / SSD block (zamba2 backbone) — chunked state-space duality.

Follows the SSD formulation (Mamba-2, arXiv:2405.21060): within chunks of
length Q the recurrence is materialized as a masked attention-like matrix
(all MXU-friendly einsums); across chunks a lax.scan carries the
[B, H, P, N] state. Decode is the O(1) recurrent step.

Layout: d_inner = expand·d_model, H = d_inner / head_dim (P), one B/C
group (G=1) of state size N = ssm_state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers import common as C
from repro.layers.common import Annotated

__all__ = [
    "init_mamba2",
    "mamba2_train",
    "mamba2_decode",
    "init_mamba2_state",
]


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_head_dim
    return d_in, heads, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in, h, p, n = _dims(cfg)
    conv_ch = d_in + 2 * n
    ks = jax.random.split(key, 4)
    return {
        # z (gate), x, B, C, dt  fused projection
        "in_proj": C.init_linear(
            ks[0], d, 2 * d_in + 2 * n + h, ("embed", "mlp")),
        "conv_w": Annotated(
            0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32),
            (None, "mlp")),
        "conv_b": Annotated(jnp.zeros((conv_ch,), jnp.float32), ("mlp",)),
        "dt_bias": Annotated(
            jnp.log(jnp.expm1(
                jnp.exp(jax.random.uniform(
                    ks[2], (h,), minval=jnp.log(0.001), maxval=jnp.log(0.1))))),
            (None,)),
        "A_log": Annotated(
            jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)), (None,)),
        "D": Annotated(jnp.ones((h,), jnp.float32), (None,)),
        "norm": C.init_norm("rmsnorm", d_in, ("mlp",)),
        "out_proj": C.init_linear(ks[3], d_in, d, ("mlp", "embed")),
    }


def _split_proj(params, cfg, u):
    d_in, h, p, n = _dims(cfg)
    zxbcdt = C.linear(params["in_proj"], u)            # [B, L, 2di+2n+h]
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d. xbc: [B, L, Ch], w: [K, Ch].

    Runs in the input dtype (bf16 on the train path — §Perf cell C,
    iteration 3: the f32 conv/gating chain dominated HBM traffic)."""
    k = w.shape[0]
    dt = xbc.dtype
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :].astype(dt)
        for i in range(k)
    )
    return jax.nn.silu(out + b[None, None, :].astype(dt))


def _ssd_chunked(x, dt, a_log, b_mat, c_mat, chunk):
    """Chunked SSD scan.

    x:   [B, L, H, P]   inputs (already dt-weighted NOT applied; we apply)
    dt:  [B, L, H]      softplus'd step sizes
    b_mat/c_mat: [B, L, N]
    Returns y [B, L, H, P].
    """
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, l)
    l_orig = l
    pad = (-l) % q
    if pad:
        # dt=0 padding is exact: decay=1 and zero state/output contribution
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        l = l + pad
    nc = l // q

    a = -jnp.exp(a_log)                                 # [H] (negative)
    la = dt * a[None, None, :]                          # [B, L, H] log-decay
    xr = x.reshape(bsz, nc, q, h, p)
    dtr = dt.reshape(bsz, nc, q, h)
    lar = la.reshape(bsz, nc, q, h)
    br = b_mat.reshape(bsz, nc, q, n)
    cr = c_mat.reshape(bsz, nc, q, n)

    cums = jnp.cumsum(lar, axis=2)                      # [B,NC,Q,H]
    # intra-chunk: M[i,j] = exp(cums_i − cums_j)·dt_j · (C_i·B_j), j ≤ i
    # The O(B·NC·Q²·H) decay/score tensors are the memory-dominant
    # intermediates of the whole train step; they are bounded (≤1 decay,
    # O(1) scores) so bf16 storage with f32 MXU accumulation halves the
    # dominant HBM term (§Perf cell C, iteration 2).
    seg = cums[:, :, :, None, :] - cums[:, :, None, :, :]   # [B,NC,Q,Q,H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(
        tri[None, None, :, :, None], jnp.exp(seg), 0.0).astype(jnp.bfloat16)
    g = jnp.einsum("bcin,bcjn->bcij", cr.astype(jnp.bfloat16),
                   br.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32).astype(jnp.bfloat16)
    m = g[..., None] * decay * dtr[:, :, None, :, :].astype(jnp.bfloat16)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m,
                         xr.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)

    # chunk-final states: S_c = Σ_j exp(cums_Q − cums_j)·dt_j · B_j ⊗ x_j
    dec_end = jnp.exp(cums[:, :, -1:, :] - cums)        # [B,NC,Q,H]
    sc = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                    (dec_end * dtr).astype(jnp.bfloat16),
                    br.astype(jnp.bfloat16), xr.astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32)  # [B,NC,H,N,P] f32
    chunk_decay = jnp.exp(cums[:, :, -1, :])            # [B,NC,H] total decay

    def scan_fn(s_prev, inp):
        s_c, dec = inp                                  # [B,H,N,P], [B,H]
        s_new = s_prev * dec[:, :, None, None] + s_c
        return s_new, s_prev

    s0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, s_before = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(sc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )                                                   # [NC,B,H,N,P]
    s_before = jnp.moveaxis(s_before, 0, 1)             # [B,NC,H,N,P]

    # inter-chunk: y_i += exp(cums_i)·(C_i · S_prev)
    y_inter = jnp.einsum(
        "bcin,bchnp,bcih->bcihp", cr.astype(jnp.bfloat16),
        s_before.astype(jnp.bfloat16),
        jnp.exp(cums).astype(jnp.bfloat16),
        preferred_element_type=jnp.float32)
    s_final = (s_before[:, -1] * chunk_decay[:, -1][:, :, None, None]
               + sc[:, -1])
    y = (y_intra + y_inter).reshape(bsz, l, h, p)
    return y[:, :l_orig], s_final


def mamba2_train(params, cfg: ModelConfig, u, return_state: bool = False):
    """u: [B, L, d_model] → [B, L, d_model] (also used for prefill fwd).

    Compute policy (§Perf cell C, iteration 3): the bulk tensors (conv,
    gating, SSD operands) stay in the activation dtype (bf16); only the
    numerically-sensitive small tensors — dt softplus, log-decay cumsum,
    inter-chunk state — run f32, with f32 MXU accumulation everywhere.
    """
    d_in, h, p, n = _dims(cfg)
    bsz, l, _ = u.shape
    z, xbc, dt = _split_proj(params, cfg, u)
    xbc_raw = xbc
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    x = xbc[..., :d_in].reshape(bsz, l, h, p)
    b_mat = xbc[..., d_in : d_in + n]
    c_mat = xbc[..., d_in + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    # §Perf cell C iteration 1: the z/x/B/C/dt slices of the fused in_proj
    # do not align with the model-axis shards, so without explicit
    # constraints the SSD intermediates (decay tensors ∝ B·NC·Q²·H)
    # replicate. Pin the head axis to "model" and batch to "data".
    from repro.parallel.sharding import maybe_shard
    x = maybe_shard(x, "data", None, "model", None)
    dt = maybe_shard(dt, "data", None, "model")
    b_mat = maybe_shard(b_mat, "data", None, None)
    c_mat = maybe_shard(c_mat, "data", None, None)
    y, s_final = _ssd_chunked(x, dt, params["A_log"],
                              b_mat, c_mat, cfg.ssm_chunk)
    y = y.astype(u.dtype) + (
        params["D"].astype(u.dtype)[None, None, :, None] * x)
    y = y.reshape(bsz, l, d_in)
    y = C.rmsnorm(y, params["norm"]["scale"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = C.linear(params["out_proj"], y.astype(u.dtype))
    if return_state:
        state = {
            "ssm": s_final,
            "conv": xbc_raw[:, -(cfg.ssm_conv - 1):, :],
        }
        return out, state
    return out


def init_mamba2_state(cfg: ModelConfig, batch: int):
    d_in, h, p, n = _dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "ssm": jnp.zeros((batch, h, n, p), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), jnp.bfloat16),
    }


def mamba2_decode(params, cfg: ModelConfig, u, state):
    """One-token recurrent step. u: [B, 1, d_model]."""
    d_in, h, p, n = _dims(cfg)
    bsz = u.shape[0]
    z, xbc, dt = _split_proj(params, cfg, u)

    # conv state update (state kept in activation dtype)
    conv_in = jnp.concatenate(
        [state["conv"].astype(xbc.dtype), xbc], axis=1)  # [B, K, Ch]
    w = params["conv_w"]
    out = jnp.einsum("bkc,kc->bc", conv_in.astype(jnp.float32), w) \
        + params["conv_b"]
    xbc_t = jax.nn.silu(out)[:, None, :]                     # [B, 1, Ch]
    new_conv = conv_in[:, 1:, :]

    x = xbc_t[..., :d_in].reshape(bsz, h, p)
    b_mat = xbc_t[:, 0, d_in : d_in + n]
    c_mat = xbc_t[:, 0, d_in + n :]
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    dec = jnp.exp(dt_t * a[None, :])                          # [B, H]
    s = state["ssm"] * dec[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt_t, b_mat, x)
    y = jnp.einsum("bn,bhnp->bhp", c_mat, s)
    y = y + params["D"][None, :, None] * x
    y = y.reshape(bsz, 1, d_in)
    y = C.rmsnorm(y, params["norm"]["scale"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = C.linear(params["out_proj"], y.astype(u.dtype))
    return out, {"ssm": s, "conv": new_conv}
