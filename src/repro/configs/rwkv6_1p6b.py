"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay.

[arXiv:2404.05892] 24L d_model=2048 d_ff=7168 vocab=65536; 32 heads of 64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65536,
    rwkv_head_dim=64, rwkv_decay_lora=64, rwkv_mix_lora=32,
)

SMOKE_CONFIG = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=512,
    rwkv_head_dim=32, rwkv_decay_lora=16, rwkv_mix_lora=8,
)
