"""llama-3-8b — paper's primary evaluation model (Tables 1-2, Figs 9-12)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256,
    rope_theta=500_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="llama3-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512,
    rope_theta=500_000.0,
)
