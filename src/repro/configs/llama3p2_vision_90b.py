"""llama-3.2-vision-90b — VLM: decoder with cross-attn image layers.

[hf:meta-llama/Llama-3.2-11B-Vision scaled] 100L (80 self + 20 cross,
every 5th is cross-attn) d_model=8192 64H kv=8 head_dim=128 d_ff=28672
vocab=128256. Vision tower is a STUB: ``input_specs()`` provides
precomputed patch embeddings [B, num_image_tokens, d_model].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    cross_attn_period=5, num_image_tokens=1601, rope_theta=500_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="llama-vision-smoke", family="vlm",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512,
    cross_attn_period=2, num_image_tokens=16, rope_theta=500_000.0,
)
