"""qwen3-moe-235b-a22b — 128-expert top-8 MoE with GQA(kv=4) + QK-norm.

[hf:Qwen/Qwen3-30B-A3B scaled per assignment] 94L d_model=4096 64H kv=4
head_dim=128, expert d_ff=1536, vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936,
    num_experts=128, num_experts_per_tok=8, moe_d_ff=1536,
    qk_norm=True, rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=512,
    num_experts=8, num_experts_per_tok=2, moe_d_ff=128,
    qk_norm=True, rope_theta=1_000_000.0,
)
