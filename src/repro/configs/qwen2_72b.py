"""qwen2-72b — dense GQA decoder with QKV bias. [arXiv:2407.10671; hf]

80L d_model=8192 64H kv=8 head_dim=128 d_ff=29568 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512,
    qkv_bias=True, rope_theta=1_000_000.0,
)
