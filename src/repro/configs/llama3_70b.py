"""llama-3-70b — paper's large evaluation model."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3-70b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    rope_theta=500_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="llama3-70b-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512,
    rope_theta=500_000.0,
)
