"""Model/config dataclasses, the architecture registry, and input shapes.

Every assigned architecture is a :class:`ModelConfig` in its own module
(``src/repro/configs/<id>.py``) registered here; ``--arch <id>`` on any
launcher resolves through :func:`get_config`.

Shapes (assigned): each arch is exercised on
  train_4k     seq 4096  × global_batch 256   → lowers ``train_step``
  prefill_32k  seq 32768 × global_batch 32    → lowers ``prefill_step``
  decode_32k   seq 32768 × global_batch 128   → lowers ``serve_step``
  long_500k    seq 524288 × global_batch 1    → lowers ``serve_step``

Skip rules (DESIGN.md §4): ``long_500k`` only for sub-quadratic archs
(ssm / hybrid); decode shapes skipped for encoder-only archs.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCH_IDS",
    "get_config",
    "get_smoke_config",
    "applicable_shapes",
    "all_cells",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    rope_theta: float = 1_000_000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    causal: bool = True
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    mlp_act: str = "swiglu"      # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001

    # --- SSM (Mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_period: int = 0         # hybrid: shared attn block every N ssm layers

    # --- RWKV6 ---
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # --- VLM ---
    cross_attn_period: int = 0   # every Nth layer is a cross-attn layer
    num_image_tokens: int = 0    # stub frontend: precomputed patch embeds

    # --- encoder-only (audio) ---
    encoder_only: bool = False
    conv_pos_width: int = 0      # HuBERT conv positional embedding kernel

    dtype: str = "bfloat16"

    @property
    def num_self_layers(self) -> int:
        if self.cross_attn_period:
            return self.num_layers - self.num_layers // self.cross_attn_period
        return self.num_layers

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    def param_count(self) -> int:
        """Analytic parameter count N (for the 6·N·D roofline term)."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.family == "moe":
                n_e = self.num_experts + self.num_shared_experts
                ffn = n_e * 3 * d * self.moe_d_ff + d * self.num_experts
            elif self.mlp_act == "swiglu":
                ffn = 3 * d * self.d_ff
            else:
                ffn = 2 * d * self.d_ff
            per_layer = attn + ffn
            total = self.num_layers * per_layer
            if self.cross_attn_period:
                # cross-attn layers already counted as attn+ffn; close enough
                pass
            return emb + total
        if self.family == "ssm":  # rwkv6
            tm = 5 * d * d + d * d  # r,k,v,g,w(+lora approx) + out
            cm = 2 * d * self.d_ff
            return emb + self.num_layers * (tm + cm)
        if self.family == "hybrid":  # zamba2
            d_in = self.ssm_expand * d
            m = d * (2 * d_in + 2 * self.ssm_state) + d_in * d
            attn = d * self.q_dim * 2 + 2 * d * self.kv_dim + 3 * d * self.d_ff
            n_attn_blocks = 1  # shared
            return emb + self.num_layers * m + n_attn_blocks * attn
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        n_active = self.num_experts_per_tok + self.num_shared_experts
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        ffn = n_active * 3 * d * self.moe_d_ff + d * self.num_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + self.num_layers * (attn + ffn)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "zamba2_2p7b",
    "qwen3_moe_235b_a22b",
    "moonshot_v1_16b_a3b",
    "qwen2_72b",
    "qwen2p5_32b",
    "starcoder2_15b",
    "mistral_nemo_12b",
    "rwkv6_1p6b",
    "hubert_xlarge",
    "llama3p2_vision_90b",
    # paper's own evaluation models
    "llama3_8b",
    "llama3_70b",
]


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "p")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    arch = arch.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE_CONFIG


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    names = ["train_4k", "prefill_32k"]
    if cfg.has_decode:
        names.append("decode_32k")
        if cfg.sub_quadratic:
            names.append("long_500k")
    return names


def all_cells(include_paper_models: bool = False):
    """Every (arch, shape) dry-run cell, honouring the skip rules."""
    cells = []
    for arch in ARCH_IDS:
        if not include_paper_models and arch.startswith("llama3_"):
            continue
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            cells.append((arch, shape))
    return cells
