"""qwen2.5-32b — dense GQA decoder with QKV bias. [hf:Qwen/Qwen2.5 family]

64L d_model=5120 40H kv=8 head_dim=128 d_ff=27648 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=27648, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2.5-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512,
    qkv_bias=True, rope_theta=1_000_000.0,
)
