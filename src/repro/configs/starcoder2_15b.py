"""starcoder2-15b — dense GQA, LayerNorm + non-gated GELU FFN, RoPE.

[arXiv:2402.19173] 40L d_model=6144 48H kv=4 head_dim=128 d_ff=24576
vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4, head_dim=128,
    d_ff=24576, vocab_size=49152,
    norm="layernorm", mlp_act="gelu", qkv_bias=True, rope_theta=100_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="starcoder2-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512,
    norm="layernorm", mlp_act="gelu", qkv_bias=True, rope_theta=100_000.0,
)
