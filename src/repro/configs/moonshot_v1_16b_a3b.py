"""moonshot-v1-16b-a3b (Moonlight) — 64-expert top-6 MoE + shared experts.

[hf:moonshotai/Moonlight-16B-A3B] 48L d_model=2048 16H kv=16 head_dim=128,
expert d_ff=1408, vocab=163840, MoE 64e top-6, 2 shared experts.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=163840,
    num_experts=64, num_experts_per_tok=6, num_shared_experts=2, moe_d_ff=1408,
    rope_theta=50_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="moonshot-smoke", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=128, vocab_size=512,
    num_experts=8, num_experts_per_tok=3, num_shared_experts=1, moe_d_ff=128,
    rope_theta=50_000.0,
)
