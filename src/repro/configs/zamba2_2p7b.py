"""zamba2-2.7b — hybrid Mamba2 backbone + shared attention block.

[arXiv:2411.15242; hf] 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64. The shared transformer block (full attn + SwiGLU MLP) is one
parameter set invoked every ``attn_period`` Mamba2 layers (Zamba2 design).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_conv=4, ssm_head_dim=64, ssm_chunk=128,
    attn_period=6, rope_theta=10000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_conv=4, ssm_head_dim=32, ssm_chunk=32,
    attn_period=2, rope_theta=10000.0,
)
