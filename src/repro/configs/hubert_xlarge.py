"""hubert-xlarge — encoder-only audio transformer (w2v2 arch).

[arXiv:2106.07447] 48L d_model=1280 16H kv=16 head_dim=80 d_ff=5120
vocab=504 (cluster targets). The conv waveform frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings [B, T, d_model].
A conv positional embedding (k=128) is real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    encoder_only=True, causal=False, norm="layernorm", mlp_act="gelu",
    conv_pos_width=128,
)

SMOKE_CONFIG = ModelConfig(
    name="hubert-smoke", family="audio",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=64,
    encoder_only=True, causal=False, norm="layernorm", mlp_act="gelu",
    conv_pos_width=16,
)
