"""mistral-nemo-12b — dense GQA, 128k context. [hf:mistralai/Mistral-Nemo-Base-2407]

40L d_model=5120 32H kv=8 head_dim=128 d_ff=14336 vocab=131072.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="mistral-nemo-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512,
    rope_theta=1_000_000.0,
)
