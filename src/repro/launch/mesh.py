"""Production mesh construction.

``make_production_mesh`` is a function (never module-level state) so that
importing this module never touches JAX device initialization — the
dry-run sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import and then calls this.
"""

from __future__ import annotations

import math

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_local_mesh", "parse_mesh_arg"]


def parse_mesh_arg(spec: str) -> tuple[int, int]:
    """CLI ``--mesh DxM`` → ``(data, model)``, e.g. ``"1x4"`` → (1, 4).

    Pure string parsing (no device touch) so launchers can validate the
    flag before importing/initializing a backend. Raises ValueError on
    anything that is not two positive ints joined by 'x'."""
    parts = spec.lower().split("x")
    if len(parts) != 2:
        raise ValueError(
            f"--mesh expects DATAxMODEL (e.g. 1x4), got {spec!r}")
    try:
        data, model = int(parts[0]), int(parts[1])
    except ValueError:
        raise ValueError(
            f"--mesh expects DATAxMODEL (e.g. 1x4), got {spec!r}") from None
    if data < 1 or model < 1:
        raise ValueError(f"--mesh axes must be >= 1, got {spec!r}")
    return data, model


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devices)} "
            "(the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count"
            "=512 before importing jax)")
    return jax.sharding.Mesh(np.array(devices[:n]).reshape(shape), axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    devices = jax.devices()
    n = len(devices)
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.sharding.Mesh(
        np.array(devices[: data * model]).reshape(data, model),
        ("data", "model"))
