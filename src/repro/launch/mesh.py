"""Production mesh construction.

``make_production_mesh`` is a function (never module-level state) so that
importing this module never touches JAX device initialization — the
dry-run sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import and then calls this.
"""

from __future__ import annotations

import math
import warnings

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_local_mesh",
           "make_replica_meshes", "parse_mesh_arg"]


def parse_mesh_arg(spec: str) -> tuple[int, int]:
    """CLI ``--mesh DxM`` → ``(data, model)``, e.g. ``"1x4"`` → (1, 4).

    Pure string parsing (no device touch) so launchers can validate the
    flag before importing/initializing a backend. Raises ValueError on
    anything that is not two positive ints joined by 'x'."""
    parts = spec.lower().split("x")
    if len(parts) != 2:
        raise ValueError(
            f"--mesh expects DATAxMODEL (e.g. 1x4), got {spec!r}")
    try:
        data, model = int(parts[0]), int(parts[1])
    except ValueError:
        raise ValueError(
            f"--mesh expects DATAxMODEL (e.g. 1x4), got {spec!r}") from None
    if data < 1 or model < 1:
        raise ValueError(f"--mesh axes must be >= 1, got {spec!r}")
    return data, model


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devices)} "
            "(the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count"
            "=512 before importing jax)")
    return jax.sharding.Mesh(np.array(devices[:n]).reshape(shape), axes)


def make_local_mesh(data: int = 1, model: int = 1, *,
                    allow_shrink: bool = False):
    """Small ``(data, model)`` mesh over the local devices (tests / CPU
    smoke).

    The requested shape is honored exactly: asking for more devices than
    exist raises, because a silently clamped mesh serves a DIFFERENT
    topology than the one requested (``--mesh 2x4`` on 4 devices would
    quietly run 1x4 — wrong replica count, wrong shard math, and every
    downstream counter lies). ``allow_shrink=True`` restores the old
    best-effort behavior for exploratory runs, but loudly: a
    ``UserWarning`` reports the effective mesh whenever it differs from
    the request."""
    devices = jax.devices()
    n = len(devices)
    if data * model > n:
        if not allow_shrink:
            raise ValueError(
                f"mesh ({data}, {model}) needs {data * model} devices "
                f"but only {n} exist — set XLA_FLAGS=--xla_force_host_"
                f"platform_device_count={data * model} (CPU) or pass "
                "allow_shrink=True to best-effort clamp")
        data = min(data, n)
        model = min(model, max(1, n // data))
        warnings.warn(
            f"make_local_mesh clamped to effective mesh "
            f"(data={data}, model={model}) over {n} device(s)",
            UserWarning, stacklevel=2)
    return jax.sharding.Mesh(
        np.array(devices[: data * model]).reshape(data, model),
        ("data", "model"))


def make_replica_meshes(replicas: int, model: int = 1) -> list:
    """Carve the local devices into ``replicas`` disjoint per-replica
    meshes, each ``(data=1, model)`` — the data axis realized as N
    independent engines (``serving/replication.py``) rather than one
    mesh axis, since each replica owns a private page pool and
    scheduler. Raises when ``replicas * model`` devices don't exist
    (same strictness as :func:`make_local_mesh`)."""
    devices = jax.devices()
    need = replicas * model
    if need > len(devices):
        raise ValueError(
            f"{replicas} replica(s) x model={model} needs {need} devices "
            f"but only {len(devices)} exist — set XLA_FLAGS=--xla_force_"
            f"host_platform_device_count={need} (CPU)")
    return [
        jax.sharding.Mesh(
            np.array(devices[i * model:(i + 1) * model]).reshape(1, model),
            ("data", "model"))
        for i in range(replicas)]
