"""ShapeDtypeStruct stand-ins and sharding assembly for every dry-run cell.

``build_cell(arch, shape_name, mesh)`` returns everything dryrun.py needs:
the step function, argument ShapeDtypeStructs, and matching in_shardings —
with **zero** device allocation (params/caches come from jax.eval_shape;
the logical-axes metadata is captured through a trace-time side channel).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, SHAPES, get_config
from repro.models.lm import LM, QuantConfig
from repro.parallel import sharding as SH
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step

__all__ = ["build_cell", "input_specs", "shapes_of_init",
           "cost_analysis_dict"]

SDS = jax.ShapeDtypeStruct


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Older releases return a one-dict-per-device list; newer ones return
    the dict directly. Always hand back a plain dict (empty if absent).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}


def shapes_of_init(lm: LM, quantized: bool = False):
    """(param ShapeDtypeStructs, axes tree) without materializing params."""
    side = {}

    def init_only(key):
        params, axes = lm.init(key)
        if quantized:
            params, axes = lm.quantize(params, axes)
        side["axes"] = axes
        return params

    shapes = jax.eval_shape(init_only, jax.random.PRNGKey(0))
    return shapes, side["axes"]


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStructs for the model *inputs* of one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {
            "tokens": SDS((b, s), jnp.int32),
            "labels": SDS((b, s), jnp.int32),
            "mask": SDS((b, s), jnp.float32),
        }
        if cfg.family == "audio":
            out["frames"] = SDS((b, s, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            out["image_embeds"] = SDS(
                (b, cfg.num_image_tokens, cfg.d_model), jnp.float32)
        return out
    if shape.kind == "prefill":
        out = {"tokens": SDS((b, s), jnp.int32)}
        if cfg.family == "audio":
            out["frames"] = SDS((b, s, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            out["image_embeds"] = SDS(
                (b, cfg.num_image_tokens, cfg.d_model), jnp.float32)
        return out
    # decode: one new token against a seq_len-deep cache
    return {"tokens": SDS((b, 1), jnp.int32)}


def _batch_shardings(specs: dict, mesh: Mesh) -> dict:
    bspec = SH.batch_spec(mesh)
    baxes = bspec[0]
    bsize = SH._axes_size(mesh, baxes)

    def one(sds):
        dims = [None] * sds.ndim
        if sds.shape[0] % bsize == 0 and sds.shape[0] > 0:
            dims[0] = baxes
        return NamedSharding(mesh, P(*dims))

    return {k: one(v) for k, v in specs.items()}


@dataclasses.dataclass
class Cell:
    arch: str
    shape_name: str
    kind: str
    step_fn: Callable
    args: tuple                 # ShapeDtypeStructs
    in_shardings: tuple
    cfg: ModelConfig


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               *, quant: Optional[QuantConfig] = None,
               fsdp: bool = True) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    ins = input_specs(arch, shape_name)

    if shape.kind == "train":
        lm = LM(cfg)
        params, axes = shapes_of_init(lm)
        opt_state = jax.eval_shape(OPT.adamw_init, params)
        step = make_train_step(lm, OPT.AdamWConfig())
        rules = dict(SH.TRAIN_RULES)
        if not fsdp:
            rules["embed"] = None
        psh = SH.tree_shardings(axes, params, mesh, rules)
        osh = {
            "m": psh, "v": psh,
            "step": NamedSharding(mesh, P()),
        }
        bsh = _batch_shardings(ins, mesh)
        batch = ins

        def train_step(params, opt_state, batch):
            return step(params, opt_state, batch)

        return Cell(arch, shape_name, "train", train_step,
                    (params, opt_state, batch), (psh, osh, bsh), cfg)

    # serving cells run the quantized W4AxKV4 model (the paper's system).
    q = quant or QuantConfig(impl="ref")
    lmq = LM(cfg, quant=q)
    qparams, qaxes = shapes_of_init(lmq, quantized=True)
    psh = SH.tree_shardings(qaxes, qparams, mesh, SH.SERVE_RULES)

    if shape.kind == "prefill":
        if cfg.encoder_only:
            # encoder "prefill" = one quantized forward over the sequence
            def encode_step(params, tokens, frames):
                logits, _ = lmq.train_logits(params, tokens,
                                             {"frames": frames})
                return logits

            bsh = _batch_shardings(ins, mesh)
            return Cell(arch, shape_name, "prefill", encode_step,
                        (qparams, ins["tokens"], ins["frames"]),
                        (psh, bsh["tokens"], bsh["frames"]), cfg)

        cache = jax.eval_shape(lambda: lmq.init_cache(b, s))
        csh = jax.tree.map(
            lambda p: NamedSharding(mesh, p),
            SH.cache_pspecs(cache, mesh, seq_parallel=(b == 1)))
        bsh = _batch_shardings(ins, mesh)
        if cfg.family == "vlm":
            def prefill_step(params, tokens, cache, image_embeds):
                return lmq.prefill(params, tokens, cache,
                                   {"image_embeds": image_embeds})
            return Cell(arch, shape_name, "prefill", prefill_step,
                        (qparams, ins["tokens"], cache, ins["image_embeds"]),
                        (psh, bsh["tokens"], csh, bsh["image_embeds"]), cfg)

        def prefill_step(params, tokens, cache):
            return lmq.prefill(params, tokens, cache)

        return Cell(arch, shape_name, "prefill", prefill_step,
                    (qparams, ins["tokens"], cache),
                    (psh, bsh["tokens"], csh), cfg)

    # decode
    cache = jax.eval_shape(lambda: lmq.init_cache(b, s))
    csh = jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        SH.cache_pspecs(cache, mesh, seq_parallel=(b == 1)))
    bsh = _batch_shardings(ins, mesh)

    def serve_step(params, tokens, cache):
        return lmq.decode(params, tokens, cache)

    return Cell(arch, shape_name, "decode", serve_step,
                (qparams, ins["tokens"], cache),
                (psh, bsh["tokens"], csh), cfg)
