"""Distributed training launcher (works end-to-end on CPU for smoke-scale
models; the same code lowers for the production mesh via --dryrun-mesh).

Fault tolerance wiring:
* auto-resume from the newest complete checkpoint in --ckpt-dir;
* async checkpoint every --ckpt-every steps (+ keep-last-K GC);
* the data pipeline is a pure function of step, so a restart replays
  exactly the remaining stream;
* a per-step wall-clock watchdog logs straggling steps (>x̄ + 4σ) — the
  single-process analogue of fleet straggler detection.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --smoke \
      --steps 100 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.launch.mesh import make_local_mesh
from repro.models.lm import LM
from repro.parallel import sharding as SH
from repro.serving.jit_args import argnums_of
from repro.training import checkpoint as CKPT
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", type=str, default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    lm = LM(cfg)
    # best-effort for the smoke trainer: shrinking warns and reports the
    # effective mesh instead of aborting the run
    mesh = make_local_mesh(args.data, args.model, allow_shrink=True)

    params, axes = lm.init(jax.random.PRNGKey(args.seed))
    opt_cfg = OPT.AdamWConfig(
        lr=args.lr,
        schedule=OPT.cosine_schedule(args.warmup, args.steps))
    opt_state = OPT.adamw_init(params)
    step_fn = make_train_step(lm, opt_cfg)

    psh = SH.tree_shardings(axes, params, mesh, SH.TRAIN_RULES)
    params = jax.device_put(params, psh)
    opt_state = jax.device_put(opt_state, {
        "m": psh, "v": psh,
        "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
        if hasattr(jax, "NamedSharding") else None,
    }) if False else opt_state  # opt state follows params via jit

    start_step = 0
    if args.ckpt_dir and CKPT.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), extra, start_step = CKPT.restore(
            args.ckpt_dir, (params, opt_state))
        print(f"[resume] restored step {start_step}", flush=True)

    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed))

    jit_step = jax.jit(step_fn, donate_argnums=argnums_of(
        step_fn, "params", "opt_state"))
    durations = []
    with mesh:
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = data.batch_for_step(step)
            if cfg.family == "audio":
                rng = np.random.default_rng(step)
                batch["frames"] = jnp.asarray(rng.normal(
                    size=(args.batch, args.seq, cfg.d_model)), jnp.float32)
            if cfg.family == "vlm":
                rng = np.random.default_rng(step)
                batch["image_embeds"] = jnp.asarray(rng.normal(
                    size=(args.batch, cfg.num_image_tokens, cfg.d_model)),
                    jnp.float32)
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            dt = time.time() - t0
            durations.append(dt)
            if len(durations) > 10:
                mu = float(np.mean(durations[:-1]))
                sd = float(np.std(durations[:-1])) + 1e-6
                if dt > mu + 4 * sd:
                    print(f"[straggler] step {step} took {dt:.2f}s "
                          f"(mean {mu:.2f}s)", flush=True)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = jax.device_get(metrics)
                print(f"step {step}: loss={float(m['loss']):.4f} "
                      f"ce={float(m['ce']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f} "
                      f"({dt:.2f}s)", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                CKPT.save_async(args.ckpt_dir, step + 1, (params, opt_state))
                CKPT.cleanup(args.ckpt_dir, keep_last=3)
    if args.ckpt_dir:
        CKPT.wait_async()
        CKPT.save(args.ckpt_dir, args.steps, (params, opt_state))
    print("done", flush=True)


if __name__ == "__main__":
    main()
