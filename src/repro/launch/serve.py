"""Serving launcher: quantize a model offline (FMPQ W4AxKV4) and run the
continuous-batching engine over a synthetic request trace.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --smoke \
      --requests 16 --max-new 32
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--pages", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=32)
    ap.add_argument("--int4-fraction", type=float, default=0.875)
    ap.add_argument("--schedule", default="split", choices=["split", "mixed"])
    ap.add_argument("--impl", default="ref", choices=["auto", "pallas", "ref"])
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prefill-mode", default="chunked",
                    choices=["chunked", "whole"])
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="ragged-prefill token budget per step")
    ap.add_argument("--step-mode", default="unified",
                    choices=["unified", "split"],
                    help="unified: ONE forward/step over decode rows + "
                         "prompt chunks (bucketed shapes); split: "
                         "separate prefill + decode forwards (baseline)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    quant = QuantConfig(int4_fraction=args.int4_fraction,
                        schedule=args.schedule, impl=args.impl)
    lm_fp = LM(cfg)
    lm_q = LM(cfg, quant=quant)

    print(f"[init+quantize] {cfg.name} "
          f"(~{cfg.param_count()/1e6:.1f}M params)", flush=True)
    params, axes = lm_fp.init(jax.random.PRNGKey(args.seed))
    qparams, _ = lm_q.quantize(params, axes)
    del params

    eng = Engine(cfg, qparams, quant, EngineConfig(
        max_batch=args.max_batch, num_pages=args.pages,
        page_size=args.page_size, temperature=args.temperature,
        prefill_mode=args.prefill_mode,
        prefill_chunk_tokens=args.prefill_chunk,
        unified_step=(args.step_mode == "unified")))

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).tolist()
        eng.add_request(i, prompt, args.max_new)

    t0 = time.time()
    finished = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in finished)
    print(f"[done] {len(finished)} requests, {total_tokens} tokens in "
          f"{dt:.1f}s → {total_tokens/dt:.1f} tok/s "
          f"(steps={eng.steps}, forwards={eng.forward_calls}, "
          f"traces={eng.trace_count}, preemptions={eng.sched.preemptions})",
          flush=True)
    for r in finished[:4]:
        print(f"  req {r.request_id}: {r.generated[:12]}…", flush=True)


if __name__ == "__main__":
    main()
