"""Serving launcher: quantize a model offline (FMPQ W4AxKV4) and run the
request-lifecycle engine over a synthetic request trace.

Requests go through ``Engine.submit`` with per-request
:class:`SamplingParams`; ``--stream`` prints tokens as ``step()`` emits
them (the ``engine.events()`` queue); ``--prefix-cache`` toggles
refcounted shared-prompt page reuse (``--shared-prefix`` controls how
many prompt tokens the synthetic trace shares, ``--prefix-cache-max-
bytes`` caps the reclaimable LRU); ``--attention-schedule`` picks the
paged-attention grid schedule (Stream-K work queue vs dense baseline);
``--abort-every N`` cancels every Nth request mid-flight to exercise
the abort path; ``--mesh DxM`` (model > 1) turns on tensor-parallel
sharded serving — heads and int4 KV pools shard over the model axis
with the scheduler and page allocator staying host-global.

Robustness knobs (the fault-tolerant serving core): ``--deadline-ms`` /
``--ttft-ms`` set per-request deadlines (expired requests end
``TIMED_OUT``), ``--max-waiting`` bounds the waiting queue (submits past
it are rejected ``FAILED("queue_full")`` and preemption victims are shed
instead of re-queued), ``--inject-faults SPEC`` arms a deterministic
fault schedule (``serving/faults.py`` grammar, e.g.
``"forward:step=3,action=nan;alloc_page:nth=20"``) to chaos-test the
step-level isolation, and ``--snapshot-every N`` rides a journaled
:class:`~repro.serving.recovery.RecoveryLog` along with the run (full
engine snapshot every N steps + per-token event journal).

``--speculation K`` turns on speculative multi-token decode on the
unified path: every request carries ``SamplingParams.speculation=K``,
the engine drafts K tokens per decode row from the prompt-lookup
source and verifies them in one forward (greedy output stays bitwise
identical to K=0). The summary's ``[sched] speculation:`` line reports
drafted/accepted (acceptance rate), rollbacks, and the no-op/error
counters; the ``[slo]`` line reports TTFT and TPOT mean + p95 over the
finished requests.

Replicated serving (``serving/replication.py``): ``--replicas N`` runs
N engine replicas behind a :class:`ReplicaGroup` — least-loaded
routing, per-step health checks, RecoveryLog artifact shipping —
with ``--failover standby|migrate`` picking the death policy and
``--kill-replica-at STEP`` (``--kill-replica IDX``) arming the
deterministic ``crash`` fault for failover smokes; the ``[group]``
summary line reports failovers/migrations/health. The
end-of-run summary reports throughput, prefix-cache hit rate + eviction
counters, schedule work/grid counters (per shard under TP), lifecycle
counts (aborted/failed/timed-out/shed/rejected), and the fired faults.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --smoke \
      --requests 16 --max-new 32 --stream --prefix-cache on
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --smoke \
      --requests 12 --deadline-ms 2000 --max-waiting 4 \
      --inject-faults "forward:step=5,action=nan;sample:nth=3"
  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
      PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b \
      --smoke --mesh 1x2 --head-dim 64 --int4-fraction 1.0
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.launch.mesh import make_local_mesh, parse_mesh_arg
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig, SamplingParams


def _ms_stats(xs: list) -> str:
    """mean + p95 of a latency sample, formatted in ms (or 'n/a')."""
    if not xs:
        return "n/a"
    arr = np.asarray(xs) * 1000.0
    return f"mean {arr.mean():.1f}ms p95 {np.percentile(arr, 95):.1f}ms"


def _group_ecfg(args) -> EngineConfig:
    """Per-replica engine config for the ReplicaGroup path. Fault specs
    are armed through explicit per-replica injectors (so
    ``--kill-replica-at`` targets one replica), never via
    ``inject_faults`` — the group hands each engine its injector."""
    return EngineConfig(
        max_batch=args.max_batch, num_pages=args.pages,
        page_size=args.page_size, temperature=args.temperature,
        prefill_mode=args.prefill_mode,
        prefill_chunk_tokens=args.prefill_chunk,
        kv_range=args.kv_range,
        unified_step=(args.step_mode == "unified"),
        prefix_cache=(args.prefix_cache == "on"),
        attention_schedule=args.attention_schedule,
        prefix_cache_max_bytes=(args.prefix_cache_max_bytes or None),
        max_waiting=(args.max_waiting or None),
        sanitize=args.sanitize)


def _run_group(args, cfg, qparams, qaxes, quant, model: int):
    """Drive a ReplicaGroup over the synthetic trace (--replicas N)."""
    from repro.launch.mesh import make_replica_meshes
    from repro.serving.faults import Fault, FaultInjector
    from repro.serving.replication import ReplicaGroup

    meshes = None
    if model > 1:
        meshes = make_replica_meshes(args.replicas, model)
        print(f"[mesh] {args.replicas} replica(s) x (data=1, "
              f"model={model}) over {jax.device_count()} "
              f"{jax.default_backend()} device(s)", flush=True)
    faults = []
    for i in range(args.replicas):
        inj = (FaultInjector.from_spec(args.inject_faults)
               if args.inject_faults else FaultInjector())
        if args.kill_replica_at and i == args.kill_replica:
            inj.faults.append(Fault("crash", step=args.kill_replica_at))
        faults.append(inj)
    group = ReplicaGroup(
        cfg, qparams, quant, _group_ecfg(args),
        replicas=args.replicas, failover=args.failover,
        snapshot_every=(args.snapshot_every or 4), faults=faults,
        meshes=meshes, param_axes=qaxes)

    rng = np.random.default_rng(args.seed)
    shared = rng.integers(0, cfg.vocab_size,
                          size=args.shared_prefix).tolist()
    sp = SamplingParams(max_new_tokens=args.max_new,
                        temperature=args.temperature, top_k=args.top_k,
                        speculation=args.speculation,
                        deadline_ms=(args.deadline_ms or None),
                        ttft_ms=(args.ttft_ms or None))
    prompts = []
    for _ in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        prompts.append(shared
                       + rng.integers(0, cfg.vocab_size, size=plen).tolist())
    pending = [(i * args.arrival_every, p) for i, p in enumerate(prompts)]

    def stream_cb(ev):
        # lifetime ordinal from the GROUP record (a migrated request's
        # engine-local num_generated restarts after the fold; the group
        # count is the client-visible stream position)
        if ev.token is not None:
            n = len(group.delivered.get(ev.request_id, []))
            print(f"  [stream] req {ev.request_id} +tok {ev.token} "
                  f"(#{n})", flush=True)
        elif ev.finished:
            print(f"  [stream] req {ev.request_id} {ev.state.value}"
                  + (f" ({ev.stop_reason})" if ev.stop_reason else ""),
                  flush=True)

    t0 = time.time()
    gsteps = 0
    while (pending or group.has_work) and gsteps < 10_000:
        while pending and pending[0][0] <= gsteps:
            _, prompt = pending.pop(0)
            group.submit(prompt, sp,
                         on_event=stream_cb if args.stream else None)
        group.step()
        gsteps += 1
    dt = time.time() - t0

    total_tokens = sum(len(v) for v in group.delivered.values())
    print(f"[done] {len(group.terminals)} requests, {total_tokens} "
          f"tokens in {dt:.1f}s → {total_tokens/max(dt, 1e-9):.1f} tok/s "
          f"(group_steps={gsteps}, replica_steps={group.replica_steps})",
          flush=True)
    c = group.counters()
    health = " ".join(f"r{i}={h}" for i, h in sorted(c["health"].items()))
    print(f"[group] replicas={args.replicas} failover={args.failover} "
          f"failovers={c['failovers']} "
          f"migrated={c['migrated_requests']} "
          f"replica_steps={c['replica_steps']} "
          f"dup_suppressed={c['duplicates_suppressed']} "
          f"internal_errors={c['internal_errors']} {health}", flush=True)
    live = [r for r in group.replicas if r.alive]
    print(f"[robust] failed="
          f"{sum(r.engine.failed_count for r in live)} timed_out="
          f"{sum(r.engine.timeout_count for r in live)} shed="
          f"{sum(r.engine.shed_count for r in live)} rejected="
          f"{sum(r.engine.rejected_count for r in live)} "
          f"internal_errors={c['internal_errors']} sanitize_checks="
          f"{sum(r.engine.sanitize_checks for r in live)}", flush=True)
    for rep in group.replicas:
        if rep.engine.faults.fired:
            fired = [f"{p}:{a}@step{s}"
                     for p, a, s in rep.engine.faults.fired]
            print(f"[faults] replica {rep.idx}: fired {', '.join(fired)}",
                  flush=True)
    for idx, why, step in group.deaths:
        print(f"[death] replica {idx} at engine step {step} ({why})",
              flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--pages", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=32)
    ap.add_argument("--int4-fraction", type=float, default=0.875)
    ap.add_argument("--schedule", default="split", choices=["split", "mixed"])
    ap.add_argument("--impl", default="ref", choices=["auto", "pallas", "ref"])
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request SamplingParams.temperature (0=greedy)")
    ap.add_argument("--top-k", type=int, default=40,
                    help="per-request SamplingParams.top_k")
    ap.add_argument("--speculation", type=int, default=0,
                    help="per-request SamplingParams.speculation: draft "
                         "K tokens per decode row from the prompt-lookup "
                         "source and verify them in one forward (0 = "
                         "off; greedy output is bitwise identical either "
                         "way — K only changes how many forwards it "
                         "takes)")
    ap.add_argument("--prefill-mode", default="chunked",
                    choices=["chunked", "whole"])
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="ragged-prefill token budget per step")
    ap.add_argument("--kv-range", type=float, default=16.0,
                    help="calibrated |k|,|v| range for the int4 KV "
                         "scales; tighter ranges reduce quantization "
                         "error (decode reads quantized KV, prefill "
                         "attends to same-chunk KV in full precision, "
                         "so fold/migration parity tightens with it)")
    ap.add_argument("--step-mode", default="unified",
                    choices=["unified", "split"],
                    help="unified: ONE forward/step over decode rows + "
                         "prompt chunks (bucketed shapes); split: "
                         "separate prefill + decode forwards (baseline)")
    ap.add_argument("--attention-schedule", default="work_queue",
                    choices=["work_queue", "dense"],
                    help="paged-attention grid schedule: flat Stream-K "
                         "work queue with split-KV combine (default) or "
                         "the dense (B·Hkv, max_npages) baseline")
    ap.add_argument("--prefix-cache", default="on", choices=["on", "off"],
                    help="refcounted shared-prompt page reuse")
    ap.add_argument("--prefix-cache-max-bytes", type=int, default=0,
                    help="byte cap on the reclaimable prefix-page LRU "
                         "(0 = unlimited); evictions show in the summary")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prompt tokens shared by every request (a "
                         "synthetic system prompt — the prefix-cache "
                         "workload)")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are emitted")
    ap.add_argument("--abort-every", type=int, default=0,
                    help="abort every Nth request after its first token "
                         "(0 = never) — exercises the abort path")
    ap.add_argument("--deadline-ms", type=float, default=0,
                    help="per-request wall-clock deadline (0 = none): "
                         "expired requests end TIMED_OUT with partial "
                         "output retained")
    ap.add_argument("--ttft-ms", type=float, default=0,
                    help="per-request first-token budget (0 = none)")
    ap.add_argument("--max-waiting", type=int, default=0,
                    help="bound on the waiting queue (0 = unbounded): "
                         "submits past it are rejected (queue_full) and "
                         "preemption victims are shed, not re-queued")
    ap.add_argument("--inject-faults", default="",
                    help="deterministic fault schedule (serving/faults.py "
                         "grammar), e.g. 'forward:step=3,action=nan;"
                         "alloc_page:nth=20' — chaos-tests step isolation")
    ap.add_argument("--sanitize", action="store_true",
                    help="run the step-boundary runtime sanitizers "
                         "(serving/sanitize.py): page-refcount "
                         "conservation + event-contract checks after "
                         "every step; SanitizerError aborts the run "
                         "the moment an invariant breaks")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="journaled crash recovery: full engine snapshot "
                         "every N steps + per-token event journal "
                         "(0 = off)")
    ap.add_argument("--arrival-every", type=int, default=0,
                    help="submit one request every N engine steps "
                         "(0 = all up front). Staggered arrivals let "
                         "later requests hit the prefix published by "
                         "earlier ones")
    ap.add_argument("--mesh", default="1x1", metavar="DxM",
                    help="(data, model) mesh for tensor-parallel sharded "
                         "serving, e.g. 1x4 shards heads + KV pools over "
                         "4 devices (CPU smoke: set XLA_FLAGS=--xla_force_"
                         "host_platform_device_count=N first). 1x1 = "
                         "single-device (default). Asking for more "
                         "devices than exist is an error (no silent "
                         "clamping)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="run N engine replicas behind a ReplicaGroup "
                         "(params replicated, page pools + scheduler "
                         "per-replica; least-loaded routing, per-step "
                         "health checks, failover). With --mesh 1xM each "
                         "replica shards over its own M-device slice")
    ap.add_argument("--failover", default="migrate",
                    choices=["standby", "migrate"],
                    help="replica-death policy: promote a standby engine "
                         "resumed from the shipped RecoveryLog artifacts "
                         "into the dead slot, or migrate the dead "
                         "replica's in-flight requests to the survivors")
    ap.add_argument("--kill-replica-at", type=int, default=0,
                    help="deterministically kill one replica before its "
                         "Nth engine step (the 'crash' fault point; "
                         "0 = never) — the failover smoke")
    ap.add_argument("--kill-replica", type=int, default=0,
                    help="which replica index --kill-replica-at kills")
    ap.add_argument("--head-dim", type=int, default=0,
                    help="override cfg.head_dim (0 = keep). The smoke "
                         "configs use head_dim=32 → q_dim=128, too small "
                         "for row-parallel TP (shards must hold whole "
                         "128-channel quant blocks) — pass 64 with "
                         "--smoke --mesh 1x2")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.head_dim:
        cfg = dataclasses.replace(cfg, head_dim=args.head_dim)
    quant = QuantConfig(int4_fraction=args.int4_fraction,
                        schedule=args.schedule, impl=args.impl)
    lm_fp = LM(cfg)
    lm_q = LM(cfg, quant=quant)

    print(f"[init+quantize] {cfg.name} "
          f"(~{cfg.param_count()/1e6:.1f}M params)", flush=True)
    params, axes = lm_fp.init(jax.random.PRNGKey(args.seed))
    qparams, qaxes = lm_q.quantize(params, axes)
    del params

    data, model = parse_mesh_arg(args.mesh)
    if args.replicas > 1:
        _run_group(args, cfg, qparams, qaxes, quant, model)
        return
    mesh = None
    if model > 1:
        # strict: make_local_mesh raises when the requested topology
        # doesn't fit the devices — no silently different mesh
        mesh = make_local_mesh(data, model)
        print(f"[mesh] (data={mesh.shape['data']}, "
              f"model={int(mesh.shape['model'])}) over "
              f"{jax.device_count()} {jax.default_backend()} device(s)",
              flush=True)

    eng = Engine(cfg, qparams, quant, EngineConfig(
        max_batch=args.max_batch, num_pages=args.pages,
        page_size=args.page_size, temperature=args.temperature,
        prefill_mode=args.prefill_mode,
        prefill_chunk_tokens=args.prefill_chunk,
        kv_range=args.kv_range,
        unified_step=(args.step_mode == "unified"),
        prefix_cache=(args.prefix_cache == "on"),
        attention_schedule=args.attention_schedule,
        prefix_cache_max_bytes=(args.prefix_cache_max_bytes or None),
        max_waiting=(args.max_waiting or None),
        inject_faults=(args.inject_faults or None),
        sanitize=args.sanitize),
        mesh=mesh, param_axes=qaxes)
    log = None
    if args.snapshot_every:
        from repro.serving.recovery import RecoveryLog
        log = RecoveryLog(eng, snapshot_every=args.snapshot_every)

    rng = np.random.default_rng(args.seed)
    shared = rng.integers(0, cfg.vocab_size,
                          size=args.shared_prefix).tolist()
    if 0 < args.shared_prefix < args.page_size:
        print(f"[warn] --shared-prefix {args.shared_prefix} < --page-size "
              f"{args.page_size}: prefix matching is full-page-granular, "
              "so the shared prefix can never hit — shrink --page-size or "
              "grow the prefix", flush=True)
    sp = SamplingParams(max_new_tokens=args.max_new,
                        temperature=args.temperature, top_k=args.top_k,
                        speculation=args.speculation,
                        deadline_ms=(args.deadline_ms or None),
                        ttft_ms=(args.ttft_ms or None))
    prompts = []
    for _ in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        prompts.append(shared
                       + rng.integers(0, cfg.vocab_size, size=plen).tolist())
    # arrival trace: request i is submitted at step i*arrival_every
    pending = [(i * args.arrival_every, p) for i, p in enumerate(prompts)]
    abort_ids: set = set()
    submitted = 0

    t0 = time.time()
    while (pending or eng.sched.has_work) and eng.steps < 10_000:
        while pending and pending[0][0] <= eng.steps:
            _, prompt = pending.pop(0)
            h = eng.submit(prompt, sp)
            submitted += 1
            if args.abort_every and submitted % args.abort_every == 0:
                abort_ids.add(h.request_id)
        if log is not None:
            evs = log.step()
        else:
            eng.step()
            evs = eng.events()
        for ev in evs:
            if ev.token is not None and ev.request_id in abort_ids:
                eng.abort(ev.request_id)       # cancel after first token
                abort_ids.discard(ev.request_id)
            if args.stream:
                if ev.token is not None:
                    print(f"  [stream] req {ev.request_id} "
                          f"+tok {ev.token} (#{ev.num_generated})",
                          flush=True)
                elif ev.finished:
                    print(f"  [stream] req {ev.request_id} "
                          f"{ev.state.value}"
                          + (f" ({ev.stop_reason})" if ev.stop_reason
                             else ""), flush=True)
    dt = time.time() - t0

    finished = eng.sched.finished
    total_tokens = sum(len(r.generated) for r in finished)
    prompt_tokens = eng.prefill_tokens + eng.prefix_hit_tokens
    hit_rate = eng.prefix_hit_tokens / prompt_tokens if prompt_tokens else 0.0
    print(f"[done] {len(finished)} requests, {total_tokens} tokens in "
          f"{dt:.1f}s → {total_tokens/dt:.1f} tok/s "
          f"(steps={eng.steps}, forwards={eng.forward_calls}, "
          f"traces={eng.trace_count}, preemptions={eng.sched.preemptions})",
          flush=True)
    print(f"[cache] prefix hit rate {hit_rate:.0%} "
          f"({eng.prefix_hit_tokens}/{prompt_tokens} prompt tokens served "
          f"from published pages); evicted={eng.cache.prefix_evicted_pages} "
          f"pages; reclaimable={eng.cache.prefix_reclaimable_bytes}B; "
          f"aborted={eng.aborted_count}", flush=True)
    print(f"[robust] failed={eng.failed_count} timed_out={eng.timeout_count} "
          f"shed={eng.shed_count} rejected={eng.rejected_count} "
          f"callback_errors={eng.callback_errors} "
          f"internal_errors={eng.internal_errors} "
          f"sanitize_checks={eng.sanitize_checks} "
          f"released={eng.sched.released_count}", flush=True)
    # latency SLOs measured from the lifecycle stamps: TTFT from
    # arrival to first token, TPOT over the decode window
    ttft = [r.first_token_at - r.arrived_at
            for r in finished if r.first_token_at]
    tpot = [(r.finished_at - r.first_token_at) / (len(r.generated) - 1)
            for r in finished
            if r.finished_at and r.first_token_at and len(r.generated) > 1]
    print(f"[slo] ttft {_ms_stats(ttft)} | tpot {_ms_stats(tpot)} "
          f"(over {len(ttft)} first tokens / {len(tpot)} decode windows)",
          flush=True)
    if eng.faults.faults:
        fired = [f"{p}:{a}@step{s}" for p, a, s in eng.faults.fired]
        print(f"[faults] armed: {eng.faults.describe()}; "
              f"fired: {', '.join(fired) or '(none)'}; "
              f"pending: {len(eng.faults.pending)}", flush=True)
    if log is not None:
        print(f"[recovery] journal={len(log.journal)} events, "
              f"snapshot@step{log._snapshot_step} "
              f"(every {log.snapshot_every}), replayed={log.replayed}",
              flush=True)
    if eng.attn_forwards:
        waste = eng.attn_grid_items - eng.attn_work_items
        dense_waste = eng.attn_dense_grid_items - eng.attn_work_items
        print(f"[sched] {args.attention_schedule}: "
              f"{eng.attn_work_items} attention work items over "
              f"{eng.attn_forwards} forwards; grid={eng.attn_grid_items} "
              f"(waste {waste}; dense rectangle would waste "
              f"{dense_waste})", flush=True)
        if eng.tp_size > 1:
            print(f"[sched] per-shard work items "
                  f"{eng.attn_work_items_per_shard} (balanced split of "
                  f"{eng.attn_work_items} over model={eng.tp_size})",
                  flush=True)
    if args.speculation or eng.spec_draft_tokens:
        acc = eng.spec_accepted_tokens / max(1, eng.spec_draft_tokens)
        print(f"[sched] speculation: drafted={eng.spec_draft_tokens} "
              f"accepted={eng.spec_accepted_tokens} (acceptance {acc:.0%}) "
              f"rollback={eng.spec_rollback_tokens} "
              f"noop={eng.spec_noop_count} "
              f"draft_errors={eng.draft_errors} "
              f"[{eng.draft_source.describe()}]", flush=True)
    for r in finished[:4]:
        print(f"  req {r.request_id}: {r.state.value:9s} "
              f"{r.generated[:12]}…", flush=True)


if __name__ == "__main__":
    main()
