import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and record memory/cost/collective analysis.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes need 512 host placeholder
devices (16×16 single-pod, 2×16×16 multi-pod).

Usage:
  python -m repro.launch.dryrun --arch qwen2_72b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs.base import SHAPES, all_cells, applicable_shapes, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell, cost_analysis_dict

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the optimized HLO."""
    out = {op: 0 for op in COLLECTIVE_OPS}
    counts = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # "%x = f32[...] all-reduce(...)" or tuple-shaped results
        m = re.match(r"%?[\w.\-]+ = (.+?) ([a-z\-]+)\(", ls)
        if not m:
            continue
        shape_part, opname = m.groups()
        matched = None
        for op in COLLECTIVE_OPS:
            if opname == op or opname.startswith(op + "-"):
                matched = op
                break
        if matched is None:
            continue
        # shape_part may be a tuple "(f32[...], u8[...])"
        total = 0
        for sm in _SHAPE_RE.finditer(shape_part):
            total += _shape_bytes(sm.group(0))
        out[matched] += total
        counts[matched] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, schedule: str = "split",
             fsdp: bool = True, save_hlo: bool = False) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.models.lm import QuantConfig
    quant = QuantConfig(impl="ref", schedule=schedule)
    cell = build_cell(arch, shape_name, mesh, quant=quant, fsdp=fsdp)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": cell.kind, "schedule": schedule, "status": "fail",
    }
    try:
        with mesh:
            jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower - t0, 1),
            "compile_s": round(t_compile - t_lower, 1),
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "memory": {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "generated_code_bytes": int(
                    getattr(mem, "generated_code_size_in_bytes", 0)),
            },
            "collectives": coll,
        })
        if save_hlo:
            with open(os.path.join(
                    out_dir, f"{arch}_{shape_name}_{mesh_name}.hlo.txt"),
                    "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    fname = f"{arch}_{shape_name}_{mesh_name}_{schedule}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--schedule", type=str, default="split",
                    choices=["split", "mixed"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        cells = all_cells()
    elif args.arch and args.shape:
        cells = [(args.arch, args.shape)]
    elif args.arch:
        cells = [(args.arch, s)
                 for s in applicable_shapes(get_config(args.arch))]
    else:
        ap.error("need --all or --arch [--shape]")

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, args.out,
                           schedule=args.schedule, fsdp=not args.no_fsdp,
                           save_hlo=args.save_hlo)
            status = rec["status"]
            extra = ("" if status == "ok" else
                     " :: " + rec.get("error", "")[:200])
            print(f"[{status}] {arch} {shape} "
                  f"{'2x16x16' if mp else '16x16'} "
                  f"lower={rec.get('lower_s', '-')}s "
                  f"compile={rec.get('compile_s', '-')}s"
                  f"{extra}", flush=True)
            if status != "ok":
                failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
