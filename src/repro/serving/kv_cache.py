"""Paged KV4 cache — vLLM-style block tables over packed int4 storage.

The physical pool is ``[num_pages, page_size, Hkv, D/2]`` uint8 per layer
stack (one K pool + one V pool, layers stacked on the leading axis).
Sequences own pages through a block table ``[max_seqs, max_pages]`` int32
(-1 = unmapped) plus an O(1) per-sequence page count maintained by the
allocator (no row scans on the hot path). Appending a token touches
exactly one page; eviction frees whole pages. Per-channel scales/zeros
are static (calibrated), so pages never need rescaling — the property
that makes int4 paging cheap.

The decode hot path is gather-free: `block_tables_device`/
`lengths_device` hand the physical indirection straight to the
block-table-aware paged attention kernel, which resolves
``(seq, logical page) → physical page`` in its index maps — decode is
O(pages touched). Page destinations for a step's appends are computed
once on the host (`token_dests`) and reused by every layer's
`scatter_tokens` call — one block-table lookup + validation per step,
not per layer.

Prefill is chunk-granular: `grow_to` acquires pages for the next chunk
only (admission never reserves a whole prompt), and `scatter_tokens`
writes a ragged chunk's quantized KV into precomputed (page, offset)
destinations — prompts stream through the pools incrementally, so a
prompt's KV is never resident in fp beyond the in-flight chunk.

**Page ownership is refcounted** (not per-seq): every mapped page
carries a reference count, and full prompt pages can be *published*
into a chained-hash prefix index (`publish_prefix`) once their
sequence's prefill completes. A later request whose prompt shares that
prefix adopts the published pages at admission (`match_prefix` →
`allocate_seq(prefix_pages=...)`): its block table starts with the
shared pages (ref+1 each) and only the un-cached suffix is ever
forwarded or written. Shared pages are written by nobody — a sequence
only writes positions >= its matched prefix, which land in its private
pages; static per-channel scales make the int4 bytes position- and
request-independent, so published pages are bit-exact for every reader.

Freeing is refcount-exact: `free_seq` decrements every mapped page;
pages reaching ref==0 go back to the free list unless they are
published, in which case they move to a *reclaimable* LRU — still
cached (a future `match_prefix` revives them) but counted in
`pages_free` and evicted LRU-first the moment an allocation would
otherwise fail, BEFORE any scheduler preemption fires.

**Work-queue schedule (host side).** `build_work_queue` /
`work_queue_np` flatten a ragged batch's real pages into the Stream-K
descriptor array the work-queue attention kernels walk: one item per
``(seq, kv_head, page)`` covering only mapped history (plus one
in-flight-chunk item per row), padded to a power-of-two count so the
kernel grid is a uniform pool of work instead of the dense
``(B·Hkv, max_npages)`` rectangle that serializes long rows and pads
short ones (see `kernels/paged_attention.py` for the device side).

The reclaimable prefix LRU can be capped by bytes
(``reclaimable_max_bytes``): publishing beyond the cap evicts
oldest-first, `prefix_evicted_pages` counts every eviction (cap or
allocation pressure), and `prefix_reclaimable_bytes` reports the
resident overhang — the observability a long-running server needs to
size the cache.

The legacy gather path (`gather_kv`) that materializes a sequence's
packed KV contiguously (a per-token O(context) copy) is retained only as
the benchmark baseline and for tests.
"""

from __future__ import annotations

import base64
import dataclasses
import hashlib
import json
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.faults import InjectedFault

__all__ = ["PagedKV4Config", "PagedKV4Cache", "build_work_queue",
           "quantize_kv_with", "qdq_kv_with"]


@dataclasses.dataclass(frozen=True)
class PagedKV4Config:
    num_pages: int
    page_size: int = 64
    max_seqs: int = 64
    max_pages_per_seq: int = 128
    reclaimable_max_bytes: Optional[int] = None  # byte cap on the prefix LRU


def build_work_queue(block_tables, ctx_lens, page_size: int,
                     num_kv_heads: int, q_lens=None,
                     min_items: int = 8,
                     pad_row: Optional[int] = None,
                     seq_ids=None) -> np.ndarray:
    """Flatten a ragged batch into Stream-K work descriptors.

    → ``[W, 4]`` int32 rows ``(row, phys_page, count, kind)``:

    * ``row``  — output-row segment id ``seq_idx · Hkv + kv_head`` the
      item's partial belongs to (the split-KV combine's segment key);
    * ``phys_page`` — physical pool page (kind 0) — the kernel's
      BlockSpec index map reads it directly, no block-table walk;
    * ``count`` — valid tokens in the page (kind 0: ``min(ps, ctx −
      page_start)``) or the row's causal chunk length (kind 1);
    * ``kind`` — 0: int4 history page, 1: in-flight fp chunk.

    One item per ``(seq, kv_head, real page)`` — pages past ``ctx_lens``
    simply don't exist, so Σ items ≈ Σ real pages, not ``B × max_pages``
    — plus, when ``q_lens`` is given, one chunk item per row with
    ``q_lens > 0``. Items are ordered row-major so consecutive grid
    steps reuse the row's query block (one-to-many tile binding).

    ``W`` is padded to a power of two (≥ ``min_items``); padding rows
    carry the sentinel ``pad_row`` (default ``B·Hkv`` — pass the
    *bucketed* row count when the consumer pads the batch beyond B, so
    the sentinel stays out of every live segment) and ``count = 0`` —
    the combine's segment scatter drops them.

    ``seq_ids`` (optional, [B]) names the caller's sequences — engine
    cache slots — purely for diagnostics: the unmapped-page error
    reports these instead of positional batch indices, which are
    misleading whenever the batch is a non-contiguous slot subset.
    """
    tables = np.atleast_2d(np.asarray(block_tables))
    ctx = np.atleast_1d(np.asarray(ctx_lens)).astype(np.int64)
    b, ps, hkv = ctx.shape[0], page_size, num_kv_heads
    # fully vectorized (this runs every engine step): flatten each
    # sequence's real pages in seq order, scatter the optional chunk
    # item behind them, then tile the per-seq stream across kv heads
    npg = -(-ctx // ps)                              # real pages per seq
    has_chunk = (np.zeros(b, np.int64) if q_lens is None else
                 (np.atleast_1d(np.asarray(q_lens)) > 0).astype(np.int64))
    qls = (None if q_lens is None
           else np.atleast_1d(np.asarray(q_lens)).astype(np.int64))
    seq_of_pg = np.repeat(np.arange(b), npg)
    pg_off = np.concatenate([[0], np.cumsum(npg)])
    pg_idx = np.arange(pg_off[-1]) - pg_off[seq_of_pg]
    pages_flat = tables[seq_of_pg, pg_idx]
    if (pages_flat < 0).any():
        bad_idx = np.unique(seq_of_pg[pages_flat < 0])
        if seq_ids is not None:
            bad = np.atleast_1d(np.asarray(seq_ids))[bad_idx].tolist()
            what = "seq slot(s)"
        else:
            bad = bad_idx.tolist()
            what = "batch row(s)"
        raise IndexError(
            f"work queue over unmapped page(s) for {what} {bad} — "
            "grow capacity first")
    counts_flat = np.minimum(ps, ctx[seq_of_pg] - ps * pg_idx)
    # per-seq item streams: pages first, then the chunk item (if any)
    n_per_seq = npg + has_chunk
    off = np.concatenate([[0], np.cumsum(n_per_seq)])
    tot = int(off[-1])
    pages_c = np.zeros(tot, np.int64)
    counts_c = np.zeros(tot, np.int64)
    kinds_c = np.zeros(tot, np.int64)
    pg_pos = off[seq_of_pg] + pg_idx
    pages_c[pg_pos] = pages_flat
    counts_c[pg_pos] = counts_flat
    if qls is not None:
        ch = np.nonzero(has_chunk)[0]
        ch_pos = off[ch] + npg[ch]
        counts_c[ch_pos] = qls[ch]
        kinds_c[ch_pos] = 1
    # tile across heads, row-major: block k = i·hkv + h replays seq i's
    # stream, so consecutive grid steps share the row's query block
    reps = np.repeat(n_per_seq, hkv)                 # items per (seq, head)
    bs = np.cumsum(reps) - reps                      # flat block starts
    within = np.arange(int(reps.sum())) - np.repeat(bs, reps)
    src = np.repeat(off[np.repeat(np.arange(b), hkv)], reps) + within
    w = max(len(src), 1)
    wb = max(min_items, 1 << (w - 1).bit_length())
    desc = np.zeros((wb, 4), np.int32)
    desc[:, 0] = b * hkv if pad_row is None else pad_row
    desc[:len(src), 0] = np.repeat(np.arange(b * hkv), reps)
    desc[:len(src), 1] = pages_c[src]
    desc[:len(src), 2] = counts_c[src]
    desc[:len(src), 3] = kinds_c[src]
    return desc


def quantize_kv_with(k, v, k_scale, k_zero, v_scale, v_zero):
    """k/v: [B, T, Hkv, D] float → packed [B, Hkv, T, D/2] uint8.

    Module-level (explicit scales) so the TP-sharded forward can pass
    per-shard scale slices through ``shard_map`` — ``Hkv`` here is
    whatever the scale arrays say (local heads under TP)."""
    def pack(x, scale, zero):
        xt = x.swapaxes(1, 2).astype(jnp.float32)          # [B, Hkv, T, D]
        n = jnp.clip(jnp.round(xt / scale + zero), 0, 15).astype(jnp.uint8)
        half = n.shape[-1] // 2
        return (n[..., :half] | (n[..., half:] << 4)).astype(jnp.uint8)
    return pack(k, k_scale, k_zero), pack(v, v_scale, v_zero)


def qdq_kv_with(k, v, k_scale, k_zero, v_scale, v_zero):
    """Fake-quantize k/v ([B, T, Hkv, D] float) through the int4
    codebook → the exact f32 values a reader dequantizes from the
    pools. Explicit-scale sibling of :meth:`PagedKV4Cache.qdq_kv`."""
    def roundtrip(x, scale, zero):
        xt = x.swapaxes(1, 2).astype(jnp.float32)          # [B, Hkv, T, D]
        n = jnp.clip(jnp.round(xt / scale + zero), 0, 15)
        return ((n - zero) * scale).swapaxes(1, 2)
    return (roundtrip(k, k_scale, k_zero), roundtrip(v, v_scale, v_zero))


class PagedKV4Cache:
    """Host-managed page allocator + device-resident pools.

    Allocation/free run in Python (the engine's scheduler thread);
    device ops (append, gather) are jittable pure functions over the
    pool arrays.
    """

    # rule R1 (snapshot-completeness) allowlist: constructor-derived
    # config/calibration state the restore path rebuilds from the same
    # ctor args (scales/zeros are pure functions of cfg + kv_range), and
    # the engine-injected fault harness, which never crosses a snapshot.
    _SNAPSHOT_EXEMPT = frozenset({
        "cfg", "pcfg", "k_scale", "k_zero", "v_scale", "v_zero",
        "page_bytes", "faults",
    })

    def __init__(self, cfg: ModelConfig, pcfg: PagedKV4Config,
                 num_layer_slots: int,
                 k_stats=None, v_stats=None, kv_range: float = 16.0):
        self.cfg = cfg
        self.pcfg = pcfg
        hkv, d = cfg.num_kv_heads, cfg.head_dim
        shape = (num_layer_slots, pcfg.num_pages, pcfg.page_size, hkv, d // 2)
        self.k_pool = jnp.zeros(shape, jnp.uint8)
        self.v_pool = jnp.zeros(shape, jnp.uint8)

        def default_stats(rng):
            # symmetric range ±rng mapped onto [0, 15] (asym affine)
            scale = jnp.full((hkv, 1, d), rng / 15.0, jnp.float32)
            zero = jnp.full((hkv, 1, d), 7.5, jnp.float32)
            return scale, zero

        self.k_scale, self.k_zero = k_stats or default_stats(kv_range)
        self.v_scale, self.v_zero = v_stats or default_stats(kv_range)

        self.block_table = np.full(
            (pcfg.max_seqs, pcfg.max_pages_per_seq), -1, np.int32)
        self.seq_len = np.zeros((pcfg.max_seqs,), np.int32)
        self.page_count = np.zeros((pcfg.max_seqs,), np.int32)
        self.free_pages = list(range(pcfg.num_pages - 1, -1, -1))
        self.active = set()
        # refcounted ownership + prefix cache: ref[p] = sequences mapping
        # page p; prefix_index: chain-hash key → published physical page;
        # page_key: inverse map for published pages; _reclaimable: LRU of
        # published pages with ref==0 (cached but immediately evictable)
        self.ref = np.zeros((pcfg.num_pages,), np.int32)
        self.prefix_index: dict = {}
        self.page_key: dict = {}
        self._reclaimable: OrderedDict = OrderedDict()
        # prefix-LRU observability: bytes one page pins in the pools
        # (K + V across the layer stack) and lifetime eviction count
        self.page_bytes = 2 * num_layer_slots * pcfg.page_size * hkv * (d // 2)
        self.prefix_evicted_pages = 0
        # optional FaultInjector (serving/faults.py) the engine shares
        # with the cache; consulted at alloc_page / append_kv
        self.faults = None

    # ------------------------------------------------------------- allocator

    @property
    def pages_free(self) -> int:
        """Pages allocatable right now: the free list plus published
        ref==0 pages (evicted LRU-first on demand)."""
        return len(self.free_pages) + len(self._reclaimable)

    @property
    def prefix_reclaimable_bytes(self) -> int:
        """Pool bytes pinned by published ref==0 pages (the LRU)."""
        return len(self._reclaimable) * self.page_bytes

    def _evict_reclaimable(self) -> Optional[int]:
        """Drop the LRU reclaimable page's index entry; → page id."""
        if not self._reclaimable:
            return None
        p, key = self._reclaimable.popitem(last=False)
        del self.prefix_index[key]
        del self.page_key[p]
        self.prefix_evicted_pages += 1
        return p

    def _acquire_page(self) -> Optional[int]:
        """Pop a free page, evicting the LRU reclaimable prefix page
        (and its index entry) if the free list is empty. Eviction runs
        BEFORE any scheduler preemption can fire: allocation only fails
        once both pools are dry."""
        if self.faults is not None and self.faults.check("alloc_page"):
            return None         # injected exhaustion — same shape as dry
        if self.free_pages:
            p = self.free_pages.pop()
        else:
            p = self._evict_reclaimable()
            if p is None:
                return None
        self.ref[p] = 1
        return p

    def _adopt_page(self, p: int):
        """Take a reference on a published page (a prefix-cache hit)."""
        if int(self.ref[p]) == 0:
            self._reclaimable.pop(p, None)
        self.ref[p] += 1

    def _release_page(self, p: int):
        self.ref[p] -= 1
        if self.ref[p] > 0:
            return                      # still shared
        key = self.page_key.get(p)
        if key is not None and self.prefix_index.get(key) == p:
            # published: keep the content cached, evictable LRU-first —
            # unless the byte cap says the LRU is already full, in
            # which case evict oldest-first down to the cap
            self._reclaimable[p] = key
            self._reclaimable.move_to_end(p)
            cap = self.pcfg.reclaimable_max_bytes
            while (cap is not None
                   and self.prefix_reclaimable_bytes > cap):
                self.free_pages.append(self._evict_reclaimable())
        else:
            self.free_pages.append(p)

    @property
    def max_tokens_per_seq(self) -> int:
        return self.pcfg.max_pages_per_seq * self.pcfg.page_size

    def pages_needed(self, tokens: int) -> int:
        ps = self.pcfg.page_size
        return (tokens + ps - 1) // ps

    def pages_available_for(self, prefix_pages) -> int:
        """Acquirable pages for an allocation that will adopt
        ``prefix_pages``: matched pages sitting on the reclaimable LRU
        (ref==0) count in ``pages_free`` but are about to be adopted —
        they cannot double as headroom for the new acquisitions."""
        reserved = sum(1 for p in prefix_pages if int(self.ref[int(p)]) == 0)
        return self.pages_free - reserved

    def allocate_seq(self, seq_id: int, reserve_tokens: int,
                     prefix_pages: tuple = (),
                     prefix_tokens: int = 0) -> bool:
        """Reserve pages for ``reserve_tokens`` (a whole prompt, or just
        its first prefill chunk); False if pool exhausted or the request
        exceeds the per-sequence page cap.

        ``prefix_pages``/``prefix_tokens`` (from :meth:`match_prefix`):
        published pages covering the request's shared prompt prefix —
        adopted (ref+1) instead of allocated, so only the un-cached
        suffix is charged to the pool; ``seq_len`` starts at the end of
        the shared prefix (its KV is already resident)."""
        need = max(self.pages_needed(reserve_tokens), len(prefix_pages))
        if (need - len(prefix_pages) > self.pages_available_for(prefix_pages)
                or seq_id in self.active
                or need > self.pcfg.max_pages_per_seq):
            return False
        for i, p in enumerate(prefix_pages):
            self._adopt_page(int(p))
            self.block_table[seq_id, i] = int(p)
        for i in range(len(prefix_pages), need):
            p = self._acquire_page()
            if p is None:
                # mid-loop exhaustion (the availability check races with
                # nothing here, but prefix adoption above can consume
                # reclaimable pages the estimate counted as free): roll
                # back every reference this call took — adopted prefix
                # refs AND already-acquired pages — so the block table
                # never holds a poisoned slot and the caller sees a
                # clean False, exactly like the up-front failure path
                for j in range(i):
                    self._release_page(int(self.block_table[seq_id, j]))
                self.block_table[seq_id, :i] = -1
                return False
            self.block_table[seq_id, i] = p
        self.seq_len[seq_id] = prefix_tokens
        self.page_count[seq_id] = need
        self.active.add(seq_id)
        return True

    def extend_seq(self, seq_id: int) -> bool:
        """Ensure capacity for one more token; may grab a new page.
        O(1): uses the maintained per-sequence page count, no row scan."""
        ln = int(self.seq_len[seq_id])
        need = self.pages_needed(ln + 1)
        have = int(self.page_count[seq_id])
        if need <= have:
            return True
        if need > self.pcfg.max_pages_per_seq:
            return False
        p = self._acquire_page()
        if p is None:
            return False
        self.block_table[seq_id, have] = p
        self.page_count[seq_id] = have + 1
        return True

    def at_capacity(self, seq_id: int) -> bool:
        """True when the sequence can NEVER grow another token — it has
        hit ``max_pages_per_seq``, or it would need more pages than the
        whole pool owns — so preemption cannot help it. (The pool bound
        also guarantees preempted sequences are always re-admissible:
        their folded prompt is at most the pages they already held.)"""
        return (self.pages_needed(int(self.seq_len[seq_id]) + 1)
                > min(self.pcfg.max_pages_per_seq, self.pcfg.num_pages))

    def grow_to(self, seq_id: int, target_tokens: int) -> int:
        """Acquire pages toward ``target_tokens`` capacity (chunked
        prefill's page-granular admission). Grabs as many pages as the
        pool allows, capped at ``max_pages_per_seq``; returns the token
        capacity actually backed by pages."""
        cap = min(self.pages_needed(target_tokens),
                  self.pcfg.max_pages_per_seq)
        have = int(self.page_count[seq_id])
        while have < cap:
            p = self._acquire_page()
            if p is None:
                break
            self.block_table[seq_id, have] = p
            have += 1
        self.page_count[seq_id] = have
        return have * self.pcfg.page_size

    def truncate_seq(self, seq_id: int, new_len: int) -> int:
        """Set the sequence's resident length to ``new_len`` tokens,
        releasing every page past ``pages_needed(new_len)`` — the
        speculative-decode rollback: a verify chunk scatters int4 KV
        for the whole k+1-token draft, and the unaccepted tail is
        retracted here, pages returning to their pre-draft baseline.

        Refcount/prefix-safe by construction: pages drop through the
        same :meth:`_release_page` path ``free_seq`` uses, so a shared
        (adopted) page survives for its other owners and a *published*
        page reaching ref==0 parks on the reclaimable LRU — still
        matchable — instead of the free list. ``new_len`` may also sit
        PAST ``seq_len`` (up to the page-backed capacity): the spec
        path writes KV beyond ``seq_len`` during verification and then
        lands the accepted length here in one move. Stale int4 bytes
        past ``new_len`` stay in the kept pages — attention masks by
        ``seq_len`` and the next append overwrites them. Returns the
        number of page references dropped."""
        if seq_id not in self.active:
            raise ValueError(f"truncate_seq: seq {seq_id} not active")
        have = int(self.page_count[seq_id])
        if not 0 <= new_len <= have * self.pcfg.page_size:
            raise ValueError(
                f"truncate_seq: new_len={new_len} outside the page-backed "
                f"range [0, {have * self.pcfg.page_size}] of seq {seq_id}")
        keep = self.pages_needed(new_len)
        for i in range(keep, have):
            self._release_page(int(self.block_table[seq_id, i]))
            self.block_table[seq_id, i] = -1
        self.page_count[seq_id] = min(have, keep)
        self.seq_len[seq_id] = new_len
        return max(0, have - keep)

    def free_seq(self, seq_id: int):
        """Drop the sequence's references. Private pages return to the
        free list; shared pages survive for their other owners; published
        pages reaching ref==0 stay cached on the reclaimable LRU."""
        pages = self.block_table[seq_id]
        for p in pages[pages >= 0]:
            self._release_page(int(p))
        self.block_table[seq_id, :] = -1
        self.seq_len[seq_id] = 0
        self.page_count[seq_id] = 0
        self.active.discard(seq_id)

    # ---------------------------------------------------------- prefix cache

    def _page_keys(self, tokens, nfull: int) -> list:
        """Chained page digests: key_i commits to ALL tokens through
        page i, so a single dict hit proves the whole prefix matches.
        SHA-256 (not builtin ``hash``): a page key maps straight to
        another request's KV pages, so keys must be collision-resistant
        even against adversarial prompts — builtin tuple hashing is
        predictable and forgeable."""
        ps = self.pcfg.page_size
        keys, key = [], b""
        for i in range(nfull):
            chunk = np.asarray(tokens[i * ps:(i + 1) * ps], np.int64)
            key = hashlib.sha256(key + chunk.tobytes()).digest()
            keys.append(key)
        return keys

    def match_prefix(self, tokens) -> tuple[list, int]:
        """Longest published prefix of ``tokens`` → (pages, matched).

        Walks full pages through the prefix index and stops at the first
        miss. Matching is capped one token short of the full prompt so
        at least one token always flows through prefill — the forward
        over that suffix is what produces the request's first logits.
        Pure lookup: takes no references (adoption happens inside
        :meth:`allocate_seq`, with no eviction possible in between)."""
        nfull = max(0, (len(tokens) - 1)) // self.pcfg.page_size
        pages = []
        for key in self._page_keys(tokens, nfull):
            p = self.prefix_index.get(key)
            if p is None:
                break
            pages.append(p)
        return pages, len(pages) * self.pcfg.page_size

    def publish_prefix(self, seq_id: int, tokens):
        """Publish the sequence's full prompt pages into the prefix
        index (called once its prefill completes — the pages' int4
        content is final; everything the sequence writes from here on
        lands in later, private pages). First publisher wins: a page
        whose chain key is already indexed is skipped, keeping its
        owner's copy private."""
        nfull = len(tokens) // self.pcfg.page_size
        for i, key in enumerate(self._page_keys(tokens, nfull)):
            if key in self.prefix_index:
                continue
            page = int(self.block_table[seq_id, i])
            if self.page_key.get(page) is not None:
                continue            # already published under another key
            self.prefix_index[key] = page
            self.page_key[page] = key

    # ------------------------------------------------------------- device ops

    def quantize_kv(self, k, v):
        """k/v: [B, T, Hkv, D] float → packed [B, Hkv, T, D/2]."""
        return quantize_kv_with(k, v, self.k_scale, self.k_zero,
                                self.v_scale, self.v_zero)

    def qdq_kv(self, k, v):
        """Fake-quantize K/V ([B, T, Hkv, D] float) through the pool's
        int4 codebook → the exact f32 values a reader dequantizes from
        the pools. The unified forward routes decode rows' in-flight
        chunk through this so their self-attention sees the same
        numerics as the split decode path (which reads the just-written
        int4 page) — greedy argmax then cannot flip on the fp-vs-int4
        difference of one token."""
        return qdq_kv_with(k, v, self.k_scale, self.k_zero,
                           self.v_scale, self.v_zero)

    def write_prompt(self, layer_slot: int, seq_id: int, k, v):
        """Write a prompt's packed KV ([1, T, Hkv, D] float) into pages."""
        kp, vp = self.quantize_kv(k, v)                    # [1, Hkv, T, D/2]
        t = kp.shape[2]
        ps = self.pcfg.page_size
        need = self.pages_needed(t)
        pad = need * ps - t
        kp = jnp.pad(kp, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(vp, ((0, 0), (0, 0), (0, pad), (0, 0)))
        # [Hkv, need, ps, D/2] → per page [ps, Hkv, D/2]
        kp = kp[0].reshape(kp.shape[1], need, ps, -1).swapaxes(0, 1)
        vp = vp[0].reshape(vp.shape[1], need, ps, -1).swapaxes(0, 1)
        kp = kp.swapaxes(1, 2)                              # [need, ps, Hkv, D/2]
        vp = vp.swapaxes(1, 2)
        pages = self.block_table[seq_id, :need]
        self.k_pool = self.k_pool.at[layer_slot, pages].set(kp)
        self.v_pool = self.v_pool.at[layer_slot, pages].set(vp)
        if layer_slot == 0:
            self.seq_len[seq_id] = t

    def append_token(self, layer_slot: int, seq_id: int, k, v,
                     pos: Optional[int] = None):
        """Write one token's KV ([1, 1, Hkv, D] float) at position ``pos``
        (default: current seq_len). Does NOT advance seq_len — call
        :meth:`advance` once after all layers have written."""
        kp, vp = self.quantize_kv(k, v)                     # [1, Hkv, 1, D/2]
        ln = int(self.seq_len[seq_id]) if pos is None else int(pos)
        ps = self.pcfg.page_size
        page = int(self.block_table[seq_id, ln // ps])
        off = ln % ps
        self.k_pool = self.k_pool.at[layer_slot, page, off].set(
            kp[0, :, 0, :])
        self.v_pool = self.v_pool.at[layer_slot, page, off].set(
            vp[0, :, 0, :])

    def token_dests_np(self, seq_ids, positions):
        """Host-side :meth:`token_dests`: validated numpy (pages, offs).

        The unified engine pads these up to its shape bucket (padding
        tokens get an out-of-range page id whose scatter update is
        dropped) before shipping them to the device once per step."""
        if self.faults is not None and self.faults.check("append_kv"):
            raise InjectedFault("append_kv: injected destination failure")
        seq_ids = np.atleast_1d(np.asarray(seq_ids))
        pos = np.atleast_1d(np.asarray(positions))
        ps = self.pcfg.page_size
        pages_np = self.block_table[seq_ids, pos // ps]
        if (pages_np < 0).any():
            raise IndexError(
                f"write into unmapped page(s) for seqs "
                f"{seq_ids[pages_np < 0].tolist()} — grow capacity first")
        return pages_np.astype(np.int32), (pos % ps).astype(np.int32)

    def token_dests(self, seq_ids, positions):
        """Resolve per-token (physical page, in-page offset) destinations
        on the host — ONCE per step — so every layer's scatter reuses the
        same validated device arrays instead of re-reading the block
        table ``num_layers`` times. → (pages [N] jnp, offs [N] jnp)."""
        pages_np, offs_np = self.token_dests_np(seq_ids, positions)
        return jnp.asarray(pages_np), jnp.asarray(offs_np)

    def scatter_tokens(self, layer_slot: int, pages, offs, k, v):
        """Quantize + scatter N tokens' KV into precomputed destinations.
        k/v is float ``[B, T, Hkv, D]`` with B·T == N tokens in
        (seq-major) order matching ``pages``/``offs`` — covers both the
        decode batch ([B, 1, ...]) and a ragged prefill chunk
        ([1, T, ...]); the chunk is the only fp KV ever materialized for
        a prompt."""
        kq, vq = self.quantize_kv(k, v)               # [B, Hkv, T, D/2]
        hkv, half = kq.shape[1], kq.shape[-1]
        kq = jnp.moveaxis(kq, 1, 2).reshape(-1, hkv, half)   # [N, Hkv, D/2]
        vq = jnp.moveaxis(vq, 1, 2).reshape(-1, hkv, half)
        self.k_pool = self.k_pool.at[layer_slot, pages, offs].set(kq)
        self.v_pool = self.v_pool.at[layer_slot, pages, offs].set(vq)

    def append_tokens(self, layer_slot: int, seq_ids, k, v, positions=None):
        """Batched one-token append: k/v ``[B, 1, Hkv, D]`` float, one
        scatter into the pools for the whole decode batch. Positions
        default to each sequence's current length; does NOT advance.
        (Hot path: compute :meth:`token_dests` once per step and call
        :meth:`scatter_tokens` per layer instead.)"""
        seq_ids = np.atleast_1d(np.asarray(seq_ids))
        pos = (self.seq_len[seq_ids] if positions is None
               else np.atleast_1d(np.asarray(positions)))
        pages, offs = self.token_dests(seq_ids, pos)
        self.scatter_tokens(layer_slot, pages, offs, k, v)

    def advance(self, seq_ids):
        for s in np.atleast_1d(seq_ids):
            self.seq_len[s] += 1

    # ---------------------------------------------- full-state snapshot

    def snapshot_state(self) -> str:
        """Serialize the ENTIRE cache — device pools included — for
        journaled crash recovery (``serving/recovery.py``).

        The legacy engine restore path re-prefills demoted requests, and
        a re-prefill runs the in-flight chunk in fp — numerics that can
        differ from the int4-history decode path by enough to flip a
        greedy argmax. Bitwise-identical continuation therefore needs
        the pools' int4 bytes verbatim, plus every piece of host
        allocator state *in iteration order* (free-list order and
        reclaimable-LRU order both steer future page assignment)."""
        pools = {
            "k": base64.b64encode(np.asarray(self.k_pool).tobytes()).decode(),
            "v": base64.b64encode(np.asarray(self.v_pool).tobytes()).decode(),
        }
        return json.dumps({
            "pool_shape": list(self.k_pool.shape),
            "pools": pools,
            "block_table": self.block_table.tolist(),
            "seq_len": self.seq_len.tolist(),
            "page_count": self.page_count.tolist(),
            "free_pages": list(self.free_pages),
            "ref": self.ref.tolist(),
            "active": sorted(self.active),
            "prefix_index": {k.hex(): int(v)
                             for k, v in self.prefix_index.items()},
            "page_key": {int(p): k.hex() for p, k in self.page_key.items()},
            "reclaimable": [[int(p), k.hex()]
                            for p, k in self._reclaimable.items()],
            "prefix_evicted_pages": self.prefix_evicted_pages,
        })

    def restore_state(self, blob: str):
        """Load a :meth:`snapshot_state` blob into THIS cache (built with
        the same configs — pool shape is validated). After this, decode
        resumes with the exact pool bytes and allocator order the
        snapshotted engine had."""
        state = json.loads(blob)
        shape = tuple(state["pool_shape"])
        if shape != tuple(self.k_pool.shape):
            raise ValueError(
                f"snapshot pool shape {shape} != cache pool shape "
                f"{tuple(self.k_pool.shape)} — restore needs an "
                "identically-configured cache")
        k = np.frombuffer(base64.b64decode(state["pools"]["k"]),
                          np.uint8).reshape(shape)
        v = np.frombuffer(base64.b64decode(state["pools"]["v"]),
                          np.uint8).reshape(shape)
        self.k_pool = jnp.asarray(k)
        self.v_pool = jnp.asarray(v)
        self.block_table = np.asarray(state["block_table"], np.int32)
        self.seq_len = np.asarray(state["seq_len"], np.int32)
        self.page_count = np.asarray(state["page_count"], np.int32)
        self.free_pages = list(state["free_pages"])
        self.ref = np.asarray(state["ref"], np.int32)
        self.active = set(state["active"])
        self.prefix_index = {bytes.fromhex(k): int(v)
                             for k, v in state["prefix_index"].items()}
        self.page_key = {int(p): bytes.fromhex(k)
                         for p, k in state["page_key"].items()}
        self._reclaimable = OrderedDict(
            (int(p), bytes.fromhex(k)) for p, k in state["reclaimable"])
        self.prefix_evicted_pages = state.get("prefix_evicted_pages", 0)

    # -------------------------------------------------- block-table views

    def work_queue_np(self, seq_ids, ctx_lens, q_lens=None,
                      min_items: int = 8,
                      pad_row: Optional[int] = None,
                      num_kv_heads: Optional[int] = None) -> np.ndarray:
        """Stream-K work descriptors for these sequences' *real* pages
        (see :func:`build_work_queue`): ``[W, 4]`` int32, W padded to a
        power of two. ``ctx_lens`` is the paged history per row;
        ``q_lens`` (optional) adds one in-flight-chunk item per row;
        ``pad_row`` overrides the padding sentinel for bucketed
        batches; ``num_kv_heads`` overrides the head count the stream
        is tiled over (the TP-sharded engine builds ONE descriptor with
        the per-shard local head count — each sequence's page stream is
        identical for every head, so the same local-head descriptor is
        valid on every model shard). Unmapped-page errors report the
        caller's ``seq_ids``, not positional batch indices."""
        return build_work_queue(
            self.block_table[np.asarray(seq_ids)], ctx_lens,
            self.pcfg.page_size,
            self.cfg.num_kv_heads if num_kv_heads is None else num_kv_heads,
            q_lens, min_items, pad_row, seq_ids=seq_ids)

    def block_tables_np(self, seq_ids, npages: int) -> np.ndarray:
        """[B, npages] int32 host table with unmapped slots (-1) clamped
        to 0 (masked by length in-kernel, never read semantically)."""
        tables = self.block_table[np.asarray(seq_ids), :npages]
        return np.maximum(tables, 0).astype(np.int32)

    def block_tables_device(self, seq_ids, max_len: int) -> jax.Array:
        """[B, NP] int32 physical-page table for the paged-attention
        kernel, sliced to the pages covering ``max_len``."""
        return jnp.asarray(
            self.block_tables_np(seq_ids, self.pages_needed(max_len)))

    def lengths_device(self, seq_ids) -> jax.Array:
        return jnp.asarray(self.seq_len[np.asarray(seq_ids)], jnp.int32)

    def gather_kv(self, layer_slot: int, seq_ids, max_len: int):
        """[Benchmark baseline] Materialize packed KV for a decode batch.

        → (k_packed, v_packed) [B, Hkv, max_len, D/2] plus lengths [B].
        Unmapped pages read page 0 but are masked by length in attention.
        """
        ps = self.pcfg.page_size
        npages = (max_len + ps - 1) // ps
        tables = jnp.asarray(
            np.where(self.block_table[seq_ids, :npages] < 0, 0,
                     self.block_table[seq_ids, :npages]))
        kp = self.k_pool[layer_slot][tables]    # [B, npages, ps, Hkv, D/2]
        vp = self.v_pool[layer_slot][tables]
        b = kp.shape[0]
        kp = kp.reshape(b, npages * ps, *kp.shape[3:]).swapaxes(1, 2)
        vp = vp.reshape(b, npages * ps, *vp.shape[3:]).swapaxes(1, 2)
        lengths = jnp.asarray(self.seq_len[seq_ids])
        return kp[:, :, :max_len], vp[:, :, :max_len], lengths
