"""Request-lifecycle serving API: the engine's stable public surface.

The engine used to be batch-offline: ``add_request(...)`` then
``run() -> list[Request]``. Production serving needs a *request
lifecycle* — submit a prompt with its own sampling parameters, stream
tokens as they are sampled, cancel mid-flight, observe completion —
which is what vLLM-style continuous-batching engines (and QServe's
serving stack, COMET's measured baseline) expose. This module holds the
value types of that surface; the verbs live on ``Engine``:

* ``Engine.submit(prompt, params) -> RequestHandle`` — enqueue a request
  with per-request :class:`SamplingParams`.
* ``Engine.stream(handle)`` — generator of :class:`RequestOutput`
  events for one request, driving ``step()`` as needed; or pass
  ``on_event=`` to ``submit`` for push-style per-token callbacks.
* ``Engine.events()`` — drain the engine-wide event queue fed by
  ``step()`` (one event per sampled token, plus a terminal event per
  request).
* ``Engine.abort(handle)`` — cancel at ANY lifecycle state; pages are
  released refcount-exactly (``pages_free`` returns to baseline).
* ``Engine.run()`` — thin batch compatibility wrapper over the above.

Lifecycle (``RequestState``)::

    QUEUED → PREFILLING → DECODING → FINISHED(stop_reason)
       │         │            │
       │         ├────────────┴────→ FAILED(error)   (step-level fault)
       ├─────────┴────────────┴────→ TIMED_OUT       (deadline/TTFT)
       └─────────┴────────────┴────→ ABORTED         (abort() anywhere)

Preemption moves a running request back to QUEUED (its pages are
dropped; re-admission re-prefills — with the prefix cache warm, its own
already-published prompt pages are a hit and only the tail re-forwards).

Failure is a per-request outcome, never an engine crash: an exception
in the forward or sampler, or a non-finite logits row, quarantines the
affected request(s) to ``FAILED`` (the error in ``stop_reason``) with
refcount-exact page release while the rest of the batch keeps decoding
— ``Engine.step()`` never propagates a per-request failure. Two more
paths land in ``FAILED`` with a policy reason instead of an error:
``"queue_full"`` (submit against a full bounded waiting queue — the
handle comes back already terminal) and ``"shed"`` (a preemption victim
dropped under load instead of re-queued, after the reclaimable prefix
LRU has already been drained). Requests carrying a
:class:`SamplingParams` deadline expire to ``TIMED_OUT``
(``stop_reason`` ``"deadline"`` or ``"ttft_budget"``) with partial
output retained and pages freed exactly. Deterministic fault schedules
for all of these live in ``serving/faults.py``; journaled crash
recovery (periodic full snapshots + a per-token event journal with
exactly-once redelivery) lives in ``serving/recovery.py``.

Event contract: every sampled token is emitted exactly once, in
generation order, so the concatenation of a request's token events
always equals its final output (``tests/serving/test_api.py`` pins
this, including across preemptions, where earlier tokens are folded
into the re-queued prompt). Every submitted request emits exactly ONE
terminal event, and no token event ever follows it — the chaos suite
(``tests/serving/test_faults.py``) pins both under seeded fault
schedules.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Optional

__all__ = ["SamplingParams", "RequestState", "RequestOutput",
           "RequestHandle"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    max_new_tokens: generation budget (the request FINISHES with
        ``stop_reason=None`` when it is spent).
    temperature: 0 → greedy argmax; > 0 → top-k categorical sampling at
        this temperature. Sampling is keyed by (request_id, position),
        so a request's stochastic text is reproducible across runs and
        across engine restarts.
    top_k: candidate pool for temperature sampling (ignored when
        greedy). Per-row: one batched sampler call serves a batch that
        mixes greedy and stochastic requests with different k.
    deadline_ms: wall-clock budget for the WHOLE request, measured from
        submit. A request past its deadline — waiting or running — is
        expired to ``TIMED_OUT`` (``stop_reason="deadline"``) at the
        next step boundary, partial output retained, pages freed
        exactly. ``None`` (default) = no deadline.
    ttft_ms: budget for the FIRST token only, also from submit: a
        request still tokenless past it times out with
        ``stop_reason="ttft_budget"`` (an SLO guard — a request that
        cannot start in time should release the queue slot it is
        holding). ``None`` = no TTFT budget.
    speculation: speculative-decode draft length k (0 = off, the
        default). Each decode step the engine drafts up to k tokens
        from its host-side draft source (n-gram prompt lookup by
        default) and verifies them in ONE forward as a qlen-(k+1)
        chunk; greedy verification is exact-match, so the emitted text
        is identical to speculation-off, just in fewer forwards.
        Stochastic requests verify by rejection sampling (the output
        *distribution* is exact; the sampled text may differ from the
        non-speculative sampler). Must fit the engine's per-step token
        budget: ``Engine.submit`` rejects k + 1 >
        ``prefill_chunk_tokens``. With ``max_new_tokens == 1`` (or one
        token remaining) drafting silently no-ops — there is nothing
        left to speculate (counted in ``Engine.spec_noop_count``).
    """

    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 40
    deadline_ms: Optional[float] = None
    ttft_ms: Optional[float] = None
    speculation: int = 0

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.speculation < 0:
            raise ValueError("speculation must be >= 0 (0 = off)")
        if self.temperature < 0.0:
            raise ValueError("temperature must be >= 0")
        if self.top_k < 1:
            raise ValueError("top_k must be >= 1")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError("deadline_ms must be > 0 (None = no deadline)")
        if self.ttft_ms is not None and self.ttft_ms <= 0:
            raise ValueError("ttft_ms must be > 0 (None = no budget)")


class RequestState(str, enum.Enum):
    """Request lifecycle. String-valued so snapshots/logs stay readable."""

    QUEUED = "queued"            # submitted, waiting for admission
    PREFILLING = "prefilling"    # admitted, prompt streaming through chunks
    DECODING = "decoding"        # prompt resident, generating tokens
    FINISHED = "finished"        # completed (stop_reason says why)
    ABORTED = "aborted"          # cancelled via Engine.abort()
    FAILED = "failed"            # quarantined by a step-level failure
    #                              (stop_reason carries the error), or
    #                              rejected ("queue_full") / load-shed
    #                              ("shed") under pressure
    TIMED_OUT = "timed_out"      # deadline_ms / ttft_ms expired

    @property
    def terminal(self) -> bool:
        return self in (RequestState.FINISHED, RequestState.ABORTED,
                        RequestState.FAILED, RequestState.TIMED_OUT)


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    """One streamed event. ``token is not None`` → a newly sampled token
    (exactly one event per token, in order); ``finished`` → the terminal
    event (state FINISHED / ABORTED / FAILED / TIMED_OUT; ``stop_reason``
    set for caps, aborts, failures, and timeouts — ``None`` for a clean
    max_new_tokens completion). Exactly one terminal event per request,
    always last."""

    request_id: int
    state: RequestState
    token: Optional[int] = None
    num_generated: int = 0       # tokens generated this incarnation
    stop_reason: Optional[str] = None
    finished: bool = False


@dataclasses.dataclass(frozen=True)
class RequestHandle:
    """Opaque ticket returned by ``Engine.submit``; pass it to
    ``Engine.stream`` / ``Engine.abort`` / ``Engine.result``."""

    request_id: int
    prompt_len: int = 0


# Per-token callback signature for Engine.submit(on_event=...).
EventCallback = Callable[[RequestOutput], None]
