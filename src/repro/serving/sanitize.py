"""Step-boundary runtime sanitizers for the serving engine.

The static half of the repo contracts lives in ``repro.analysis``
(cometlint rules R1–R6); this is the RUNTIME half: with
``EngineConfig(sanitize=True)`` the engine re-derives its core
invariants from first principles after every ``Engine.step()`` and
raises :class:`SanitizerError` naming the violated invariant the moment
one breaks — instead of letting a corrupted refcount or a duplicated
terminal surface requests later as a wrong answer. Checks are pure-host
(numpy over the cache's host-side tables; no device sync beyond what the
step already did), so the chaos and replication suites run every seeded
fault schedule under them.

Invariants checked (see ``docs/invariants.md``):

- **page-refcount conservation** — per-page refs recomputed from the
  active sequences' block tables must equal ``cache.ref`` exactly, the
  free list and the reclaimable LRU must be duplicate-free, disjoint,
  and unmapped, reclaimable pages must be published (key'd both ways in
  the prefix index), and ``free + reclaimable + mapped`` must tile the
  pool: Σ refs>0 pages + len(free) + len(reclaimable) == num_pages.
- **exactly-one-terminal** — at most one ``finished`` event per request,
  and ``terminal_emitted`` agrees with the event log.
- **no-token-after-terminal** — a terminal event is the LAST event; no
  token event may carry ``finished=True``; a request's token-event count
  never exceeds its lifetime ``emitted`` cursor.
- **emitted-position-monotonic** — a request's token events advance
  ``num_generated`` by exactly one per event (restarting at 1 only
  after a preemption fold): multi-token speculative commits must emit
  in order, never duplicating or skipping a position.
- **kv-length-consistency** — after every step, each running request's
  resident KV length equals its committed tokens: mid-prefill,
  ``seq_len == prefill_pos``; decoding, ``seq_len == total_len - 1``
  (every committed token except the newest has resident KV — the
  newest is written by its next forward). Speculative rollback
  (``truncate_seq``) must land sequences exactly here; a leaked or
  over-retracted draft token trips this immediately.
"""

from __future__ import annotations

import numpy as np

__all__ = ["SanitizerError", "check_engine", "check_cache",
           "check_events", "check_positions"]


class SanitizerError(AssertionError):
    """A serving-core invariant failed a step-boundary sanitizer check.

    Deliberately NOT swallowed by the engine's step backstop: the check
    runs outside the isolation boundary, because a broken invariant
    means state is already corrupt and continuing would serve wrong
    answers."""


def check_cache(cache) -> list:
    """Page-refcount conservation over the paged KV4 cache."""
    problems = []
    num_pages = cache.pcfg.num_pages
    expected = np.zeros(num_pages, np.int64)
    for sid in cache.active:
        npg = int(cache.page_count[sid])
        for p in cache.block_table[sid, :npg]:
            p = int(p)
            if p < 0 or p >= num_pages:
                problems.append(
                    f"page-refcount conservation: active seq {sid} maps "
                    f"out-of-pool page {p} (pool has {num_pages})")
            else:
                expected[p] += 1
    ref = np.asarray(cache.ref, np.int64)
    if not np.array_equal(expected, ref):
        bad = np.nonzero(expected != ref)[0][:8]
        detail = ", ".join(
            f"page {int(p)}: ref={int(ref[p])} but {int(expected[p])} "
            f"active mapping(s)" for p in bad)
        problems.append(f"page-refcount conservation: ref table diverges "
                        f"from block tables ({detail})")
    free = [int(p) for p in cache.free_pages]
    if len(free) != len(set(free)):
        problems.append("page-refcount conservation: duplicate page in "
                        "free list")
    reclaimable = {int(p) for p in cache._reclaimable}
    overlap = set(free) & reclaimable
    if overlap:
        problems.append(f"page-refcount conservation: page(s) "
                        f"{sorted(overlap)[:8]} on both the free list "
                        f"and the reclaimable LRU")
    for p in free:
        if 0 <= p < num_pages and ref[p] != 0:
            problems.append(f"page-refcount conservation: free page {p} "
                            f"has ref={int(ref[p])}")
            break
    for p, key in cache._reclaimable.items():
        p = int(p)
        if ref[p] != 0:
            problems.append(f"page-refcount conservation: reclaimable "
                            f"page {p} has ref={int(ref[p])}")
        if cache.prefix_index.get(key) != p or \
                cache.page_key.get(p) != key:
            problems.append(f"page-refcount conservation: reclaimable "
                            f"page {p} lost its prefix-index pairing")
    mapped = int(np.count_nonzero(ref > 0))
    if mapped + len(free) + len(reclaimable) != num_pages:
        problems.append(
            f"page-refcount conservation: mapped({mapped}) + "
            f"free({len(free)}) + reclaimable({len(reclaimable)}) != "
            f"pool({num_pages})")
    return problems


def check_events(engine) -> list:
    """Exactly-one-terminal + no-token-after-terminal per request.

    Tolerates restored requests whose event log was not carried across
    the snapshot (empty ``events`` with ``terminal_emitted=True``)."""
    problems = []
    for req in engine._by_id.values():
        rid = req.request_id
        terminals = [i for i, ev in enumerate(req.events) if ev.finished]
        if len(terminals) > 1:
            problems.append(f"exactly-one-terminal: request {rid} has "
                            f"{len(terminals)} terminal events")
        if terminals and terminals[0] != len(req.events) - 1:
            extra = len(req.events) - 1 - terminals[0]
            problems.append(f"no-token-after-terminal: request {rid} has "
                            f"{extra} event(s) after its terminal")
        if terminals and not req.terminal_emitted:
            problems.append(f"exactly-one-terminal: request {rid} logged "
                            f"a terminal event but terminal_emitted is "
                            f"False (a second terminal could slip "
                            f"through _emit)")
        tokens = sum(1 for ev in req.events if ev.token is not None)
        if any(ev.token is not None and ev.finished for ev in req.events):
            problems.append(f"no-token-after-terminal: request {rid} has "
                            f"a token event marked finished")
        if tokens > req.emitted:
            problems.append(f"no-token-after-terminal: request {rid} "
                            f"logged {tokens} token events but its "
                            f"lifetime emitted cursor is {req.emitted}")
        nums = [ev.num_generated for ev in req.events
                if ev.token is not None]
        for a, b in zip(nums, nums[1:]):
            if b != a + 1 and b != 1:
                problems.append(
                    f"emitted-position-monotonic: request {rid} token "
                    f"events jump num_generated {a} -> {b} (must advance "
                    f"by exactly one, or restart at 1 after a preemption "
                    f"fold)")
                break
    return problems


def check_positions(engine) -> list:
    """KV-length ↔ committed-token agreement for every running request.

    The invariant speculative rollback must restore: a decoding
    request's newest committed token has NO resident KV yet (its next
    forward writes it), every older one does — so ``seq_len`` is
    exactly ``total_len - 1``. Mid-prefill, ``seq_len`` tracks the
    chunk cursor ``prefill_pos``. Checked over ``sched.running`` only:
    waiting/preempted requests hold no slot, terminal ones no pages."""
    problems = []
    cache = engine.cache
    for req in engine.sched.running:
        rid, slot = req.request_id, req.seq_slot
        if slot < 0:
            problems.append(f"kv-length-consistency: running request "
                            f"{rid} holds no seq slot")
            continue
        ln = int(cache.seq_len[slot])
        if not req.prefilled:
            if ln != req.prefill_pos:
                problems.append(
                    f"kv-length-consistency: request {rid} mid-prefill "
                    f"has kv len {ln} but prefill_pos {req.prefill_pos}")
            continue
        want = req.total_len - 1 if req.generated else len(req.prompt)
        if ln != want:
            problems.append(
                f"kv-length-consistency: request {rid} has kv len {ln} "
                f"but {req.total_len} committed tokens (expected {want}: "
                f"every committed token except the newest has resident "
                f"KV)")
    return problems


def check_engine(engine) -> None:
    """Assert every step-boundary invariant; raise on the first batch of
    violations. Called by ``Engine.step()`` when ``ecfg.sanitize``."""
    problems = (check_cache(engine.cache) + check_events(engine)
                + check_positions(engine))
    if problems:
        raise SanitizerError(
            f"step {engine.steps}: {len(problems)} sanitizer "
            f"violation(s):\n  - " + "\n  - ".join(problems))
