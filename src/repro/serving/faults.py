"""Deterministic fault injection for the serving stack.

A production engine's failure modes — allocator exhaustion mid-loop, an
exception inside the jitted forward, NaN logits, a sampler blow-up, a
client callback that throws — are rare enough in normal operation that
the isolation code handling them would otherwise ship untested. This
module makes those faults *schedulable*: a :class:`FaultInjector` armed
with :class:`Fault` entries rides along with the engine, and the
instrumented choke points in ``engine.py`` / ``kv_cache.py`` consult it
on every hit. Schedules are fully deterministic ("fail the Nth
allocation", "NaN the logits at step K"), so a chaos test that trips an
invariant replays bit-for-bit from its seed.

Fault points (the names the engine/cache fire):

* ``alloc_page``  — every ``PagedKV4Cache._acquire_page`` call. The only
  legal action is ``exhaust`` (the call returns ``None``, exactly what a
  dry pool returns): allocator exhaustion is a *condition*, not an
  exception — the engine's admission / preemption / load-shed machinery
  is the handler under test, and a raise inside the allocator's
  multi-page loop would corrupt block-table state no real exhaustion
  can produce.
* ``forward``     — one hit per model forward. ``raise`` aborts the
  forward before launch (the engine quarantines every request in the
  batch); ``nan`` lets the forward run and then corrupts one logits row
  (``row``), tripping the engine's per-row non-finite guard.
* ``sample``      — one hit per batched sampler call; ``raise`` fails
  every row being sampled (rows mid-prefill are untouched).
* ``append_kv``   — every KV write-destination resolution
  (``PagedKV4Cache.token_dests_np``); ``raise`` aborts the step's
  forward before any pool write.
* ``emit_event``  — every delivery to a request's ``on_event`` callback;
  ``raise`` simulates a throwing client callback (the engine detaches
  the callback and keeps the request alive — the event log is intact).

Two points cover the speculative-decode path (grouped in
``SPEC_FAULT_POINTS``, kept OUT of ``ENGINE_FAULT_POINTS`` so seeded
schedules built before they existed replay unchanged):

* ``draft``       — every draft-source invocation in
  ``Engine._plan_speculation``. ``raise`` simulates a blowing-up draft
  oracle (the engine counts ``draft_errors`` and degrades to plain
  one-token decode — drafting is best-effort, never fatal); ``empty``
  makes the source politely propose nothing (pure degradation, no
  error).
* ``verify``      — once per speculating row's verification in
  ``Engine._verify_row``; ``raise`` quarantines exactly that request
  (pages released to baseline, drafted KV retracted with them) while
  the rest of the batch keeps decoding.

Two points model *process-level* failures (consulted by the layers
wrapping the engine, never by ``Engine.step`` itself):

* ``crash``       — consulted by ``serving/replication.py``'s
  :class:`ReplicaGroup` at the top of each replica step; action
  ``kill`` marks the WHOLE replica dead before the step runs (its
  in-memory engine state is considered lost with the process — the
  controller recovers only from the shipped RecoveryLog artifacts).
* ``snapshot_write`` — consulted by ``RecoveryLog._write_snapshot``;
  action ``torn`` writes a partial temp file and then raises (a kill
  mid-write), proving the atomic-rename contract: the last good
  ``snapshot.json`` must survive untouched.

Schedules come from three constructors: explicit :class:`Fault` lists,
the CLI spec grammar (:meth:`FaultInjector.from_spec`, e.g.
``"forward:step=3,action=nan;alloc_page:nth=20"``), and seeded random
mixes for chaos sweeps (:meth:`FaultInjector.random_schedule` — drawn
from the five in-engine points only, so pre-existing seeded schedules
are stable; pass ``points=`` to include the speculative-decode and/or
process-level ones, e.g. ``ENGINE_FAULT_POINTS + SPEC_FAULT_POINTS``
for the chaos sweeps covering speculation).

Each armed fault fires exactly once. ``hits`` counts every consultation
per point and ``fired`` records what actually tripped (point, action,
engine step) — chaos tests assert against these to prove a schedule
actually exercised the path it meant to.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["Fault", "FaultInjector", "InjectedFault", "FAULT_POINTS",
           "ENGINE_FAULT_POINTS", "SPEC_FAULT_POINTS"]

# the five points Engine.step/PagedKV4Cache consult directly
ENGINE_FAULT_POINTS = ("alloc_page", "forward", "sample", "append_kv",
                       "emit_event")
# the speculative-decode points (Engine._plan_speculation /
# Engine._verify_row) — a separate group, NOT folded into
# ENGINE_FAULT_POINTS, so seeded random_schedule draws from before
# speculation existed still replay bit-for-bit
SPEC_FAULT_POINTS = ("draft", "verify")
# plus the process-level points consulted by the wrapping layers
# (ReplicaGroup / RecoveryLog)
FAULT_POINTS = ENGINE_FAULT_POINTS + SPEC_FAULT_POINTS + (
    "crash", "snapshot_write")

# legal actions per point (first entry = the default)
_ACTIONS = {
    "alloc_page": ("exhaust",),
    "forward": ("raise", "nan"),
    "sample": ("raise",),
    "append_kv": ("raise",),
    "emit_event": ("raise",),
    "draft": ("raise", "empty"),
    "verify": ("raise",),
    "crash": ("kill",),
    "snapshot_write": ("torn",),
}


class InjectedFault(RuntimeError):
    """Raised by an armed ``raise``-action fault at its point."""


@dataclasses.dataclass
class Fault:
    """One scheduled fault. Exactly one trigger must be set:

    ``nth``  — fire on the Nth consultation of ``point`` (1-based,
    counted over the engine's lifetime);
    ``step`` — fire on the first consultation of ``point`` during that
    engine step.

    ``action`` defaults to the point's canonical failure mode (see
    module docstring); ``row`` picks the logits row a ``nan`` fault
    corrupts (clamped to the batch by the engine).
    """

    point: str
    nth: Optional[int] = None
    step: Optional[int] = None
    action: Optional[str] = None
    row: int = 0
    fired: bool = dataclasses.field(default=False, compare=False)

    def __post_init__(self):
        if self.point not in FAULT_POINTS:
            raise ValueError(f"unknown fault point {self.point!r}; "
                             f"expected one of {FAULT_POINTS}")
        if (self.nth is None) == (self.step is None):
            raise ValueError(
                f"fault {self.point!r} needs exactly one trigger: "
                f"nth= or step= (got nth={self.nth}, step={self.step})")
        if self.action is None:
            self.action = _ACTIONS[self.point][0]
        if self.action not in _ACTIONS[self.point]:
            raise ValueError(
                f"action {self.action!r} not valid for point "
                f"{self.point!r}; legal: {_ACTIONS[self.point]}")

    def describe(self) -> str:
        trig = (f"nth={self.nth}" if self.nth is not None
                else f"step={self.step}")
        return f"{self.point}[{trig},action={self.action}]"


class FaultInjector:
    """Armed fault schedule + hit accounting shared by engine and cache.

    The engine calls :meth:`begin_step` once per ``Engine.step``; the
    instrumented points call :meth:`check(point)` on every hit. ``check``
    returns the :class:`Fault` that just tripped (or ``None``) — raising
    is the *caller's* job, so each point keeps its own failure semantics
    (the allocator returns ``None``, the forward raises, the NaN fault
    mutates logits after the forward ran).
    """

    def __init__(self, faults: Optional[list] = None):
        self.faults: list[Fault] = list(faults or [])
        self.hits = {p: 0 for p in FAULT_POINTS}
        self.fired: list[tuple] = []    # (point, action, engine_step)
        self.step = 0

    def begin_step(self, step: int):
        self.step = step

    def check(self, point: str) -> Optional[Fault]:
        """Count a hit at ``point``; return the fault that trips, if any.

        At most one fault fires per hit (schedules listing two faults on
        the same trigger fire them on consecutive hits)."""
        self.hits[point] += 1
        for f in self.faults:
            if f.fired or f.point != point:
                continue
            if f.nth is not None:
                if self.hits[point] != f.nth:
                    continue
            elif self.step != f.step:
                continue
            f.fired = True
            self.fired.append((point, f.action, self.step))
            return f
        return None

    @property
    def pending(self) -> list[Fault]:
        return [f for f in self.faults if not f.fired]

    # ------------------------------------------------------------ builders

    @classmethod
    def from_spec(cls, spec: str) -> "FaultInjector":
        """Parse the CLI grammar: ``;``-separated faults, each
        ``point:key=val,key=val`` — e.g.
        ``"forward:step=3,action=nan;alloc_page:nth=20;sample:nth=2"``.
        """
        faults = []
        for part in spec.split(";"):
            part = part.strip()
            if not part:
                continue
            point, _, argstr = part.partition(":")
            kw: dict = {}
            for kv in filter(None, (a.strip() for a in argstr.split(","))):
                key, _, val = kv.partition("=")
                if key in ("nth", "step", "row"):
                    kw[key] = int(val)
                elif key == "action":
                    kw[key] = val
                else:
                    raise ValueError(f"unknown fault key {key!r} in "
                                     f"{part!r}")
            faults.append(Fault(point.strip(), **kw))
        return cls(faults)

    @classmethod
    def random_schedule(cls, seed: int, n_faults: int = 3,
                        max_step: int = 30,
                        points=ENGINE_FAULT_POINTS) -> "FaultInjector":
        """A seeded random mix of faults for chaos sweeps — the same
        seed always builds the same schedule, so a failing sweep replays
        exactly from its seed."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            point = str(rng.choice(list(points)))
            if point == "alloc_page":
                faults.append(Fault(point, nth=int(rng.integers(1, 60))))
            elif point == "forward":
                action = str(rng.choice(["raise", "nan"]))
                faults.append(Fault(point, step=int(rng.integers(2, max_step)),
                                    action=action,
                                    row=int(rng.integers(0, 4))))
            else:
                faults.append(Fault(point, nth=int(rng.integers(1, 20))))
        return cls(faults)

    def describe(self) -> str:
        return "; ".join(f.describe() for f in self.faults) or "(none)"
