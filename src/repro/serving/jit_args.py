"""Name-derived ``static_argnums``/``donate_argnums`` for ``jax.jit``.

Integer argnum literals are positional landmines: adding a parameter to
the jitted callable silently shifts which argument gets staticized (a
retrace storm) or donated (a use-after-donate on the wrong buffer) — the
engine's forward has already been bitten once by exactly this. Rule R2
(``repro.analysis.cometlint``) bans the literals; this helper is the
sanctioned replacement: callers declare INTENT as parameter names and
the indices are derived from the live signature, so a rename or
reorder either resolves correctly or fails loudly at construction.
"""

from __future__ import annotations

import inspect

__all__ = ["argnums_of"]


def argnums_of(fn, *names: str) -> tuple:
    """Positional indices of ``names`` in ``fn``'s signature.

    ``fn`` may be a plain function or a bound method (``self`` is then
    already excluded by ``inspect.signature``). Raises ``ValueError``
    naming the missing parameter(s) if the signature no longer carries
    one of the declared names, and rejects keyword-only parameters —
    they have no positional index for jit to consume.
    """
    params = list(inspect.signature(fn).parameters.values())
    by_name = {p.name: i for i, p in enumerate(params)}
    missing = [n for n in names if n not in by_name]
    if missing:
        raise ValueError(
            f"argnums_of: {getattr(fn, '__qualname__', fn)!r} has no "
            f"parameter(s) {missing}; signature is "
            f"({', '.join(p.name for p in params)}) — update the "
            f"declared intent list to match the renamed/removed "
            f"parameter")
    kw_only = [n for n in names
               if params[by_name[n]].kind == inspect.Parameter.KEYWORD_ONLY]
    if kw_only:
        raise ValueError(
            f"argnums_of: parameter(s) {kw_only} of "
            f"{getattr(fn, '__qualname__', fn)!r} are keyword-only and "
            f"have no positional argnum")
    return tuple(by_name[n] for n in names)
