"""Replicated serving: data-axis replica groups with health-checked
failover and exactly-once request migration.

One engine (PR 7) survives its own step-level faults, but the replica
IS the failure domain: a process death still kills every in-flight
stream it owned. This module adds the availability layer above the
engine — the :class:`ReplicaGroup` controller runs N engine replicas
and turns a replica death into a throughput degradation instead of a
correctness event.

**What is per-replica vs. group-global.** Each replica is a full
engine: its own page pool, scheduler, prefix index, fault injector, and
a private :class:`~repro.serving.recovery.RecoveryLog` driving its
steps. Params are replicated (the data axis of the ``(data, model)``
mesh — every replica holds the same weights; ``make_replica_meshes``
carves per-replica device slices whose model axis shards within the
replica). Group-global: the request-id namespace (the group assigns
ids, so sampling — keyed ``(request_id, position)`` — reproduces
bit-identically on whichever replica serves the request), the routing
table (``rid → replica``), and the delivered-event record (the group is
the exactly-once choke point clients observe).

**Routing.** ``submit`` places each request on the least-loaded live
replica (in-flight = waiting + running), skipping replicas whose
bounded waiting queue is full — per-replica admission backpressure.
When every live replica is full the submit lands on the least-loaded
one anyway and the engine's existing bounded-queue path rejects it
(``FAILED("queue_full")``); when failover halves capacity, the same
machinery sheds preemption victims (``FAILED("shed")``) on the
survivors — overload degrades into explicit, counted outcomes.

**Health.** A replica is health-checked every group step, two ways:
the ``crash`` fault point (``serving/faults.py``) is consulted at the
top of each replica step — action ``kill`` marks the whole replica dead
BEFORE the step runs, deterministically (``--kill-replica-at``) — and
step completion is timed against ``heartbeat_s``: a step that finishes
over the deadline marks the replica dead and its events are DISCARDED
(never shipped, never delivered — a zombie's output must not race the
failover). Either way the dead engine's live memory is never trusted
again.

**Shipping and failover.** After every healthy step a replica ships
``(snapshot_blob, journal, steps)`` — the RecoveryLog artifacts — to
the group's standby store, and only THEN are the step's events
delivered, so the shipped view always covers every delivered event.
On death the controller recovers exclusively from that shipped view via
``RecoveryLog.resume``: the engine restores at the last shipped
checkpoint and re-runs the gap up to the shipped step count while the
journal verifies every regenerated event bitwise and suppresses its
redelivery (exactly-once across the failover). Then, by policy:

* ``failover="standby"`` — the resumed engine is promoted whole into
  the dead slot (health ``promoted``); streams continue where the
  shipped view left off, same replica index, same routing.
* ``failover="migrate"`` — the resumed engine is a STAGING area only:
  the gap replay verifies the journal bitwise without redelivering,
  then every in-flight request is folded from the group's own record
  (prompt + delivered tokens, ``max_new_tokens`` reduced by what was
  delivered — the engine's preemption fold) and resubmitted to the
  survivors under its ORIGINAL request id, so the continued sampling
  stream is the one the client was already reading. Tokens generated
  after the last ship were never delivered (ship-then-deliver), so
  survivors regenerate exactly the undelivered suffix. With no
  survivors the group synthesizes ``FAILED("replica_lost")`` terminals
  — still exactly one terminal per request.

The snapshot alone is not enough: a request routed to a replica AFTER
its last shipped checkpoint exists in neither the shipped snapshot nor
(as a request) the journal. The group therefore keeps its own durable
submission record (``rid → (prompt, params)``) and, on failover,
re-submits any such lost request from that record plus the delivered
token stream — both policies share this path.

The group-level delivered record deduplicates by request id (tokens
after a delivered terminal, or a second terminal, are suppressed and
counted), making the exactly-once contract hold at the layer clients
actually read, independent of which engine produced an event.

Counters: ``failovers``, ``migrated_requests``, ``replica_steps``,
``duplicates_suppressed``, per-replica ``health`` — surfaced by
``launch/serve.py --replicas N`` as the ``[group]`` summary line.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.serving.api import RequestOutput, RequestState, SamplingParams
from repro.serving.engine import Engine
from repro.serving.faults import FaultInjector
from repro.serving.recovery import RecoveryLog

__all__ = ["Replica", "ReplicaGroup"]


@dataclasses.dataclass
class Replica:
    """One slot in the group: a live engine + its RecoveryLog, the
    health state, and the last shipped artifact tuple
    ``(snapshot_blob, journal, steps)``."""
    idx: int
    engine: Engine
    log: RecoveryLog
    health: str = "live"        # live | promoted | dead:crash |
    #                             dead:heartbeat
    shipped: Optional[tuple] = None
    last_step_s: float = 0.0

    @property
    def alive(self) -> bool:
        return not self.health.startswith("dead")

    @property
    def load(self) -> int:
        s = self.engine.sched
        return len(s.waiting) + len(s.running)


class ReplicaGroup:
    """N engine replicas behind one submit/step surface (see module
    docstring for the full contract).

    ``faults``: optional per-replica list of
    :class:`~repro.serving.faults.FaultInjector` (``None`` entries get
    a fresh empty injector) — the seam chaos tests and
    ``--kill-replica-at`` arm ``crash`` faults through.
    ``heartbeat_s``: per-step completion deadline (``None`` disables
    the heartbeat check). ``meshes``: optional per-replica meshes for
    TP within each replica (requires ``param_axes``).
    """

    def __init__(self, cfg, qparams, quant, ecfg, *, replicas: int = 2,
                 failover: str = "migrate", snapshot_every: int = 4,
                 heartbeat_s: Optional[float] = None, faults=None,
                 meshes=None, param_axes=None, clock=time.time):
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        if failover not in ("standby", "migrate"):
            raise ValueError(
                f"failover must be 'standby' or 'migrate', got "
                f"{failover!r}")
        if faults is not None and len(faults) != replicas:
            raise ValueError(
                f"faults must list one injector per replica "
                f"({replicas}), got {len(faults)}")
        if meshes is not None and len(meshes) != replicas:
            raise ValueError(
                f"meshes must list one mesh per replica ({replicas}), "
                f"got {len(meshes)}")
        self.cfg, self.qparams, self.quant, self.ecfg = (cfg, qparams,
                                                         quant, ecfg)
        self.failover = failover
        self.snapshot_every = snapshot_every
        self.heartbeat_s = heartbeat_s
        self.clock = clock
        self._meshes = meshes
        self._param_axes = param_axes
        self.replicas: list[Replica] = []
        for i in range(replicas):
            inj = faults[i] if faults is not None and faults[i] is not None \
                else FaultInjector()
            eng = Engine(cfg, qparams, quant, ecfg,
                         mesh=meshes[i] if meshes else None,
                         param_axes=param_axes if meshes else None,
                         faults=inj, clock=clock)
            rep = Replica(idx=i, engine=eng,
                          log=RecoveryLog(eng, snapshot_every=snapshot_every))
            self._ship(rep)
            self.replicas.append(rep)
        self._next_rid = 0
        self.owner: dict[int, int] = {}         # rid → replica idx
        # durable submission record: a request routed to a replica AFTER
        # its last shipped checkpoint is in neither the shipped snapshot
        # nor (necessarily) the journal — the group itself is the
        # client-facing durable record, so failover re-submits such
        # "lost" requests from here, continuing from delivered tokens
        self._requests: dict[int, tuple] = {}   # rid → (prompt, params)
        self.delivered: dict[int, list[int]] = {}   # rid → token stream
        self.terminals: dict[int, RequestOutput] = {}
        self._callbacks: dict[int, object] = {}
        self.failovers = 0
        self.migrated_requests = 0
        self.replica_steps = 0
        self.duplicates_suppressed = 0
        self.callback_errors = 0
        self.deaths: list[tuple] = []           # (idx, why, engine_step)

    # ------------------------------------------------------------- routing

    def _route(self) -> Replica:
        """Least-loaded live replica with waiting-queue headroom; when
        all are full, the least-loaded one outright (its bounded queue
        rejects at submit — the existing backpressure path)."""
        live = [r for r in self.replicas if r.alive]
        if not live:
            raise RuntimeError("no live replicas")
        open_ = [r for r in live if not r.engine.sched.waiting_full]
        return min(open_ or live, key=lambda r: (r.load, r.idx))

    def submit(self, prompt, params: Optional[SamplingParams] = None,
               on_event=None) -> int:
        """Enqueue on the least-loaded live replica; returns the
        group-global request id. Events are delivered through the
        group's record (``tokens_for``/``terminal_for``) and the
        optional ``on_event`` callback as the group steps."""
        rid = self._next_rid
        self._next_rid += 1
        rep = self._route()
        self._requests[rid] = (list(prompt), params)
        rep.engine.submit(list(prompt), params, request_id=rid)
        self.owner[rid] = rep.idx
        if on_event is not None:
            self._callbacks[rid] = on_event
        return rid

    # ------------------------------------------------------------ stepping

    def step(self):
        """One group step: every live replica advances one engine step
        (crash-fault check → step → heartbeat check → ship → deliver).
        A death detected here fails over immediately, within the same
        group step."""
        for rep in list(self.replicas):
            self._step_replica(rep)

    def run(self, max_steps: int = 10_000):
        while self.has_work and max_steps > 0:
            self.step()
            max_steps -= 1

    @property
    def has_work(self) -> bool:
        return any(r.alive and r.engine.sched.has_work
                   for r in self.replicas)

    def _step_replica(self, rep: Replica):
        if not rep.alive:
            return
        eng = rep.engine
        # process-level crash check BEFORE the step: the injector's step
        # counter is advanced to the step about to run, so crash:step=K
        # kills the replica with its journal consistent to step K-1 —
        # exactly the shipped view
        eng.faults.begin_step(eng.steps + 1)
        if eng.faults.check("crash") is not None:
            self._on_death(rep, "crash")
            return
        t0 = self.clock()
        fresh = rep.log.step()
        rep.last_step_s = self.clock() - t0
        self.replica_steps += 1
        if self.heartbeat_s is not None and rep.last_step_s > self.heartbeat_s:
            # missed heartbeat: the step's events are DISCARDED — never
            # shipped, never delivered — so the failover regenerates
            # them on a survivor and the client still sees each exactly
            # once
            self._on_death(rep, "heartbeat")
            return
        self._ship(rep)
        for ev in fresh:
            self._deliver(ev)

    def _ship(self, rep: Replica):
        """Publish the replica's RecoveryLog artifacts to the standby
        store. Runs BEFORE the step's events are delivered, so the
        shipped view always covers every delivered event."""
        rep.shipped = (rep.log.snapshot_blob,
                       [dict(e) for e in rep.log.journal],
                       rep.engine.steps)

    # ------------------------------------------------------------ delivery

    def _deliver(self, ev: RequestOutput):
        """Group-level exactly-once choke point: record the event under
        its request id, suppressing anything after a delivered terminal
        (and second terminals outright)."""
        rid = ev.request_id
        if rid in self.terminals:
            self.duplicates_suppressed += 1
            return
        if ev.token is not None:
            self.delivered.setdefault(rid, []).append(int(ev.token))
        else:
            self.terminals[rid] = ev
        cb = self._callbacks.get(rid)
        if cb is not None:
            try:
                cb(ev)
            except Exception:  # noqa: BLE001 — client-callback boundary:
                # group-level mirror of Engine._emit's guard — client
                # code may raise anything; detach + count, never fatal
                self.callback_errors += 1
                self._callbacks.pop(rid, None)

    def tokens_for(self, rid: int) -> list[int]:
        """The full delivered token stream for a request — the group
        keeps lifetime history (the per-replica journals compact)."""
        return list(self.delivered.get(rid, []))

    def terminal_for(self, rid: int) -> Optional[RequestOutput]:
        return self.terminals.get(rid)

    # ------------------------------------------------------------ failover

    def _on_death(self, rep: Replica, why: str):
        rep.health = f"dead:{why}"
        self.deaths.append((rep.idx, why, rep.engine.steps))
        self.failovers += 1
        if self.failover == "standby":
            self._promote(rep)
        else:
            self._migrate(rep)

    def _owned_inflight(self, idx: int) -> list[int]:
        """The dead replica's requests the group still owes a terminal
        for, in submission order (rids are monotonic)."""
        return sorted(rid for rid, owner in self.owner.items()
                      if owner == idx and rid not in self.terminals)

    def _recover_log(self, shipped: tuple, idx: int,
                     deliver: bool) -> RecoveryLog:
        """Resume an engine from a shipped artifact tuple and replay the
        gap up to the shipped step count. Every regenerated event in the
        gap is in the shipped journal (ship-then-deliver), so the
        RecoveryLog verifies it bitwise (``ReplayMismatch`` otherwise)
        and suppresses its redelivery. ``deliver=False`` for a staging
        replay (migrate): any fresh event would be regenerated by the
        survivor fold, so delivering it here would duplicate."""
        blob, journal, steps = shipped
        log = RecoveryLog.resume(
            blob, [dict(e) for e in journal], self.cfg, self.qparams,
            self.quant, self.ecfg, snapshot_every=self.snapshot_every,
            mesh=self._meshes[idx] if self._meshes else None,
            param_axes=self._param_axes if self._meshes else None,
            clock=self.clock)
        while log.engine.steps < steps:
            for ev in log.step():
                if deliver:
                    self._deliver(ev)
        return log

    def _resubmit(self, rid: int, target: Replica):
        """Continue a request on ``target`` from the stream the client
        already saw: the group's durable record folds the delivered
        tokens into the prompt (the engine's preemption fold) and the
        budget shrinks to the undelivered remainder — under the ORIGINAL
        request id, so the sampling stream is unchanged."""
        prompt, params = self._requests[rid]
        done = self.delivered.get(rid, [])
        base = params if params is not None else SamplingParams(
            temperature=self.ecfg.temperature, top_k=self.ecfg.top_k)
        params = dataclasses.replace(
            base, max_new_tokens=max(base.max_new_tokens - len(done), 0))
        target.engine.submit(list(prompt) + list(done), params,
                             request_id=rid)
        self.owner[rid] = target.idx

    def _promote(self, rep: Replica):
        """Standby failover: install the resumed engine in the dead slot
        — same replica index, same routing, streams continue bitwise
        from the shipped view. Requests routed here after the shipped
        checkpoint are in neither the snapshot nor the journal — the
        group re-submits them from its own record."""
        log = self._recover_log(rep.shipped, rep.idx, deliver=True)
        new = Replica(idx=rep.idx, engine=log.engine, log=log,
                      health="promoted")
        self.replicas[rep.idx] = new
        for rid in self._owned_inflight(rep.idx):
            if rid not in new.engine._by_id:
                self._resubmit(rid, new)
        self._ship(new)

    def _migrate(self, rep: Replica):
        """Migrate failover: resume a STAGING engine from the shipped
        artifacts purely to verify the replayed gap bitwise against the
        journal, then fold every in-flight request from the group's
        delivered record and resubmit to the survivors (least-loaded,
        original ids). The staging engine is discarded — the group
        record and the staging state agree by construction (everything
        in the staging engine's ``generated`` was delivered)."""
        survivors = [r for r in self.replicas if r.alive]
        if not survivors:
            # total loss: exactly one synthesized terminal per request
            # the group still owes one
            for rid in self._owned_inflight(rep.idx):
                self._deliver(RequestOutput(
                    request_id=rid, state=RequestState.FAILED,
                    token=None,
                    num_generated=len(self.delivered.get(rid, [])),
                    stop_reason="replica_lost", finished=True))
            return
        self._recover_log(rep.shipped, rep.idx, deliver=False)
        for rid in self._owned_inflight(rep.idx):
            self._resubmit(rid, self._route())
            self.migrated_requests += 1

    # --------------------------------------------------------- observability

    @property
    def health(self) -> dict[int, str]:
        return {r.idx: r.health for r in self.replicas}

    @property
    def internal_errors(self) -> int:
        return sum(r.engine.internal_errors for r in self.replicas
                   if r.alive)

    def counters(self) -> dict:
        return {
            "failovers": self.failovers,
            "migrated_requests": self.migrated_requests,
            "replica_steps": self.replica_steps,
            "duplicates_suppressed": self.duplicates_suppressed,
            "callback_errors": self.callback_errors,
            "internal_errors": self.internal_errors,
            "health": self.health,
        }
