"""Host-side draft sources for speculative multi-token decode.

The unified engine verifies a k-token draft by riding the speculating
decode row through the SAME ragged forward as a qlen-(k+1) chunk (see
``Engine._forward_step``), so the only new machinery speculation needs
is something that *proposes* the k tokens. This module holds that seam:

* :class:`DraftSource` — the pluggable interface. A draft source is a
  pure host-side oracle: given the request's prompt + generated history
  it returns up to ``k`` proposed next tokens (possibly fewer, possibly
  none). It must be deterministic for a given context — greedy
  speculation-on/-off parity and the recovery journal's bitwise replay
  both depend on the draft plan being a pure function of engine state.
* :class:`PromptLookupDraft` — the default implementation: n-gram
  prompt lookup (PLD). The last ``max_ngram``..``min_ngram`` tokens of
  the context are searched for an earlier occurrence, and the tokens
  that followed that occurrence become the draft. Repetitive contexts
  (code, extractive QA, self-repeating generations) accept most of the
  draft; divergent contexts just fall back to ordinary one-token decode.
  Zero model cost, zero device state — the draft never touches the KV
  pools, only the *verification* chunk does.

A small draft MODEL sharing the engine's page pools would implement the
same interface (propose from its own forward pass); that is the
remaining roadmap gap, and it plugs in here without touching the
engine's verify/rollback path.

This module is deliberately host-only (cometlint rule R6): draft
planning runs in the scheduler phase of every step and must never
trigger device work or retracing.
"""

from __future__ import annotations

__all__ = ["DraftSource", "PromptLookupDraft"]


class DraftSource:
    """Interface for speculative-draft proposers.

    ``draft(prompt, generated, k)`` returns up to ``k`` proposed token
    ids continuing ``prompt + generated``. Returning fewer tokens (or
    an empty list) is always legal — the engine simply verifies a
    shorter chunk, or falls back to plain one-token decode. The engine
    treats the result as untrusted: ids outside the vocab are dropped
    (counted in ``draft_errors``), and a raising source degrades to
    no-draft instead of failing the request.
    """

    def draft(self, prompt: list, generated: list, k: int) -> list:
        raise NotImplementedError

    def describe(self) -> str:
        return type(self).__name__


class PromptLookupDraft(DraftSource):
    """Deterministic n-gram prompt-lookup drafting.

    Searches the request's full context (prompt + generated history)
    for the most recent earlier occurrence of its trailing n-gram,
    longest ``max_ngram`` first, and proposes the tokens that followed
    it. Among occurrences of the same n-gram, the most recent one with
    a full k-token continuation wins (a match near the context tail
    has its continuation clipped by the context end — in a repeating
    run that match would propose a single token, wasting the verify
    chunk); if no occurrence can fill ``k``, the longest available
    continuation is used.

    O(len(context) · max_ngram) per call on plain python lists — the
    context is one request's tokens, and the scan runs once per decode
    step for speculating rows only.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram, got "
                f"min_ngram={min_ngram}, max_ngram={max_ngram}")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def draft(self, prompt: list, generated: list, k: int) -> list:
        if k <= 0:
            return []
        ctx = list(prompt) + list(generated)
        length = len(ctx)
        for n in range(min(self.max_ngram, length - 1),
                       self.min_ngram - 1, -1):
            pattern = ctx[-n:]
            best: list = []
            for i in range(length - n - 1, -1, -1):
                if ctx[i:i + n] == pattern:
                    cont = ctx[i + n:i + n + k]
                    if len(cont) >= k:
                        return list(cont)
                    if len(cont) > len(best):
                        best = list(cont)
            if best:
                return best
        return []

    def describe(self) -> str:
        return (f"PromptLookupDraft(max_ngram={self.max_ngram}, "
                f"min_ngram={self.min_ngram})")
