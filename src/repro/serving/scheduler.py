"""Continuous-batching request scheduler with preemption + fault recovery.

COMET's end-to-end win (paper Fig. 11/12) comes from KV4 admitting larger
decode batches under a fixed memory budget; this scheduler is where that
batch is formed. Policy (vLLM-style):

* FCFS admission: a waiting request is admitted when the paged pool can
  hold its *first prefill chunk* — plus one decode token of headroom
  once the whole prompt is resident (chunked prefill — pass
  ``first_chunk_tokens``; whole-prompt admission reserves the full
  prompt). Prompts that can never fit ``max_pages_per_seq``, or whose
  prompt + one decode token exceeds the whole pool, are failed
  immediately with ``stop_reason="prompt_too_long"``.
* with ``prefix_cache=True`` admission first consults the cache's
  prefix index (``cache.match_prefix``): a request whose prompt prefix
  is already published adopts the shared pages, is charged only its
  un-cached pages against the pool, and starts with ``prefill_pos`` at
  the end of the shared prefix — only the suffix streams through
  ``plan_prefill``. A *preempted* request re-admits through the same
  path, so its own previously-published prompt pages are a warm hit;
* requests track ``prefill_pos`` (prompt tokens already through the
  model) so prefill proceeds chunk-by-chunk and preemption can fire
  mid-prefill — a preempted request simply restarts at ``prefill_pos=0``;
* decode batch = all running sequences (up to ``max_batch``);
* on pool exhaustion the *youngest* running sequence is preempted back to
  the waiting queue (its pages freed — recomputed on re-admission).
  Preemption is the LAST resort: the allocator drains the reclaimable
  prefix LRU first (``PagedKV4Cache._acquire_page``), so cached-but-idle
  prefix pages are always shed before any in-flight work is;
* graceful degradation under pressure (``max_waiting``): the waiting
  queue is bounded — the engine rejects at submit when it is full
  (``FAILED("queue_full")``), and a preemption victim that cannot be
  re-queued without overflowing the bound is *shed* instead
  (``FAILED("shed")``) — bounded queues turn overload into explicit,
  counted outcomes instead of unbounded latency;
* per-request deadlines (``SamplingParams.deadline_ms`` / ``ttft_ms``)
  are enforced at every step boundary by ``expire_deadlines``: expired
  requests — waiting or running — move to ``TIMED_OUT`` with partial
  output retained and pages freed refcount-exactly;
* step-level failures quarantine via ``fail`` — same page accounting as
  ``abort``, state ``FAILED`` with the error in ``stop_reason``;
* ``snapshot``/``restore`` serialize scheduler state so an engine restart
  (node failure) resumes with pending work intact. The legacy mode
  (``full=False``) demotes running requests to waiting (their device KV
  is lost with the node) and folds generated text into the prompt; the
  ``full=True`` mode keeps the exact waiting/running split, slots,
  prefill positions, and the free-slot order — paired with the KV-pool
  snapshot in ``PagedKV4Cache.snapshot_state`` it supports bitwise
  replay of the remaining work (``serving/recovery.py``).
"""

from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Callable, Optional

from repro.serving.api import RequestState, SamplingParams

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: list                   # token ids
    max_new_tokens: int
    arrived_at: float = 0.0
    generated: list = dataclasses.field(default_factory=list)
    seq_slot: int = -1             # cache slot when running
    prefill_pos: int = 0           # prompt tokens already through the model
    stop_reason: Optional[str] = None   # None = ran to max_new_tokens
    first_token_at: float = 0.0    # wall clock of first generated token
    finished_at: float = 0.0       # wall clock of the terminal event —
    #                                with first_token_at this brackets
    #                                the decode window, so the serve CLI
    #                                derives TTFT/TPOT without polling
    params: Optional[SamplingParams] = None   # None → engine defaults
    state: RequestState = RequestState.QUEUED
    cached_tokens: int = 0         # prefix-cache hit tokens, last admission
    uid: int = -1                  # incarnation-qualified id: request_ids
    #                                are reusable after release(), so the
    #                                recovery journal and replica-group
    #                                routing key by this engine-lifetime
    #                                monotonic counter instead
    emitted: int = 0               # lifetime token events (survives the
    #                                preemption fold — the journal's
    #                                per-request delivery cursor)
    terminal_emitted: bool = dataclasses.field(   # exactly-one-terminal
        default=False, repr=False, compare=False)
    events: list = dataclasses.field(          # RequestOutput stream log
        default_factory=list, repr=False, compare=False)
    on_event: Optional[Callable] = dataclasses.field(
        default=None, repr=False, compare=False)

    def deadline_status(self, now: float) -> Optional[str]:
        """The stop_reason this request owes at wall-clock ``now``
        (``"deadline"`` / ``"ttft_budget"``), or ``None`` if within
        budget. Measured from ``arrived_at``; preemption keeps the
        arrival stamp, so a deadline survives re-queueing."""
        p = self.params
        if p is None:
            return None
        waited_ms = (now - self.arrived_at) * 1000.0
        if p.deadline_ms is not None and waited_ms > p.deadline_ms:
            return "deadline"
        if (p.ttft_ms is not None and not self.first_token_at
                and waited_ms > p.ttft_ms):
            return "ttft_budget"
        return None

    @property
    def prefilled(self) -> bool:
        return self.prefill_pos >= len(self.prompt)

    @prefilled.setter
    def prefilled(self, value: bool):
        self.prefill_pos = len(self.prompt) if value else 0

    @property
    def done(self) -> bool:
        return (self.stop_reason is not None
                or len(self.generated) >= self.max_new_tokens)

    @property
    def total_len(self) -> int:
        return len(self.prompt) + len(self.generated)


class Scheduler:
    def __init__(self, max_batch: int, max_seqs: int,
                 max_waiting: Optional[int] = None):
        self.max_batch = max_batch
        self.max_seqs = max_seqs
        self.max_waiting = max_waiting   # None = unbounded waiting queue
        self.waiting: deque[Request] = deque()
        self.running: list[Request] = []
        self.finished: list[Request] = []
        self._free_slots = list(range(max_seqs - 1, -1, -1))
        self.preemptions = 0
        self.released_count = 0     # terminal requests dropped via release
        self._plan_cursor = 0       # round-robin start for prefill plans

    @property
    def waiting_full(self) -> bool:
        """True when the bounded waiting queue cannot take another
        request — the engine's reject-at-submit backpressure signal."""
        return (self.max_waiting is not None
                and len(self.waiting) >= self.max_waiting)

    # ----------------------------------------------------------------- queue

    def submit(self, req: Request):
        self.waiting.append(req)

    def admit(self, cache, first_chunk_tokens: Optional[int] = None,
              prefix_cache: bool = False) -> list[Request]:
        """Admit waiting requests while pages + slots are available.

        ``first_chunk_tokens``: with chunked prefill, admission only
        needs pages for the first chunk (later chunks acquire pages via
        ``cache.grow_to``); ``None`` reserves the whole prompt (the
        whole-prompt baseline path).

        ``prefix_cache``: consult ``cache.match_prefix`` first — the
        matched pages are adopted rather than allocated, only the
        UN-CACHED pages are charged against ``pages_free``, and the
        request starts at ``prefill_pos = matched`` so just the suffix
        streams through the prefill plan."""
        admitted = []
        while (self.waiting and self._free_slots
               and len(self.running) < self.max_batch):
            req = self.waiting[0]
            if (cache.pages_needed(len(req.prompt))
                    > cache.pcfg.max_pages_per_seq
                    or cache.pages_needed(len(req.prompt) + 1)
                    > cache.pcfg.num_pages):
                # can never fit the per-seq page budget, or prompt + one
                # decode token can never fit the whole pool — fail fast
                # instead of livelocking admission/preemption (chunked
                # would stream until the pool is exhausted, self-preempt,
                # and restart forever). Token-granular: a prompt whose
                # last page has slack for its decode tokens is servable.
                self.waiting.popleft()
                req.stop_reason = "prompt_too_long"
                req.state = RequestState.FINISHED
                self.finished.append(req)
                continue
            pages, matched = (cache.match_prefix(req.prompt)
                              if prefix_cache else ([], 0))
            reserve = (len(req.prompt) if first_chunk_tokens is None
                       else min(len(req.prompt),
                                matched + first_chunk_tokens))
            # token-granular decode headroom: one extra TOKEN (not a
            # whole extra page) once the full prompt is resident — a
            # prompt whose last page has slack admits into an exactly-
            # sized pool
            headroom = reserve + 1 if reserve == len(req.prompt) else reserve
            if (cache.pages_needed(headroom) - len(pages)
                    > cache.pages_available_for(pages)):
                break
            slot = self._free_slots.pop()
            if not cache.allocate_seq(slot, reserve, prefix_pages=pages,
                                      prefix_tokens=matched):
                self._free_slots.append(slot)
                break
            req.seq_slot = slot
            req.prefill_pos = matched     # shared prefix is already resident
            req.cached_tokens = matched
            req.state = RequestState.PREFILLING
            self.waiting.popleft()
            self.running.append(req)
            admitted.append(req)
        return admitted

    def plan_prefill(self, cache,
                     token_budget: int) -> list[tuple[Request, int, int]]:
        """Plan this step's prefill chunk: ``[(req, start, take), ...]``.

        Packs up to ``token_budget`` prompt tokens across the running
        requests that still have prompt left, acquiring pages chunk-by-
        chunk (``cache.grow_to``); a request that can't get pages this
        step is simply skipped (decode keeps draining the pool).

        The scan start **round-robins** across the candidates (persistent
        cursor): a long prompt at the head of ``running`` would otherwise
        claim the whole budget every step and starve later arrivals of
        their first token. The engine passes a budget already debited for
        this step's decode rows, so chunk rows and decode rows share one
        per-step token budget — the unified forward's shape stays bounded
        by ``prefill_chunk_tokens`` regardless of the decode batch."""
        cands = [r for r in self.running if r.prefill_pos < len(r.prompt)]
        if not cands or token_budget <= 0:
            return []
        rot = self._plan_cursor % len(cands)
        self._plan_cursor += 1
        budget = token_budget
        plan: list[tuple[Request, int, int]] = []
        for req in cands[rot:] + cands[:rot]:
            if budget <= 0:
                break
            rem = len(req.prompt) - req.prefill_pos
            want = req.prefill_pos + min(rem, budget)
            cap = cache.grow_to(req.seq_slot, want)
            take = min(rem, budget, cap - req.prefill_pos)
            if take <= 0:
                continue
            plan.append((req, req.prefill_pos, take))
            budget -= take
        return plan

    def preempt_one(self, cache) -> Optional[Request]:
        """Evict the youngest running sequence to the waiting queue.

        Only the victim's own references are dropped (``cache.free_seq``
        is refcount-exact): pages it shared with other sequences stay
        mapped for them, and its own *published* prompt pages stay
        cached — re-admission goes back through ``match_prefix``, so a
        warm prefix cache turns the re-prefill into a page-table copy
        plus the un-cached tail.

        Finished requests (done but not yet completed by the engine's
        end-of-step sweep) are never victims: preempting one would fold
        its generated text back into the prompt and silently destroy its
        output. Their pages are released at completion instead."""
        candidates = [r for r in self.running if not r.done]
        if not candidates:
            return None
        req = max(candidates, key=lambda r: r.arrived_at)
        self.running.remove(req)
        cache.free_seq(req.seq_slot)
        self._free_slots.append(req.seq_slot)
        req.seq_slot = -1
        self.preemptions += 1
        if self.waiting_full:
            # load shed: re-queueing would overflow the bounded waiting
            # queue, so the victim is dropped terminally instead of
            # churning — pages are already freed, partial output kept.
            # The caller (engine) counts shed_count + emits the event.
            req.stop_reason = "shed"
            req.state = RequestState.FAILED
            self.finished.append(req)
            return req
        # keep generated text: re-admission prefills prompt+generated.
        # Mid-prefill victims (generated == []) simply restart at 0.
        req.prompt = req.prompt + req.generated
        req.max_new_tokens -= len(req.generated)
        req.generated = []
        req.prefill_pos = 0
        req.state = RequestState.QUEUED
        self.waiting.appendleft(req)
        return req

    def complete(self, req: Request, cache):
        self.running.remove(req)
        cache.free_seq(req.seq_slot)
        self._free_slots.append(req.seq_slot)
        req.seq_slot = -1
        req.state = RequestState.FINISHED
        self.finished.append(req)

    def _drop(self, req: Request, cache):
        """Detach ``req`` from wherever it lives (running: free pages
        refcount-exactly + return the slot; waiting: leave the queue).
        The shared teardown under abort / fail / timeout."""
        if req in self.running:
            self.running.remove(req)
            cache.free_seq(req.seq_slot)
            self._free_slots.append(req.seq_slot)
            req.seq_slot = -1
        elif req in self.waiting:
            self.waiting.remove(req)

    def abort(self, req: Request, cache) -> bool:
        """Cancel ``req`` wherever it is in the lifecycle. Running
        sequences (mid-prefill or mid-decode) drop their page references
        refcount-exactly; queued requests just leave the queue. Returns
        False if the request already reached a terminal state."""
        if req.state.terminal:
            return False
        self._drop(req, cache)
        req.stop_reason = "aborted"
        req.state = RequestState.ABORTED
        self.finished.append(req)
        return True

    def fail(self, req: Request, cache, reason: str) -> bool:
        """Quarantine ``req`` after a step-level failure: same exact
        page accounting as :meth:`abort`, terminal state ``FAILED`` with
        the error in ``stop_reason``. Partial output is retained (the
        tokens already streamed are real). Returns False if already
        terminal (a request cannot fail twice)."""
        if req.state.terminal:
            return False
        self._drop(req, cache)
        req.stop_reason = reason
        req.state = RequestState.FAILED
        self.finished.append(req)
        return True

    def reject(self, req: Request, reason: str = "queue_full"):
        """Refuse a request at submit (bounded-queue backpressure): it
        never enters the waiting queue — straight to ``FAILED`` with a
        policy reason, holding no pages or slots."""
        req.stop_reason = reason
        req.state = RequestState.FAILED
        self.finished.append(req)

    def expire_deadlines(self, cache, now: float) -> list[Request]:
        """Expire every waiting/running request past its deadline or
        TTFT budget to ``TIMED_OUT`` — pages freed refcount-exactly,
        partial output retained. Runs at each step boundary BEFORE
        admission, so a dead-on-arrival request never acquires pages.
        Returns the expired requests (the engine emits their terminal
        events and counts ``timeout_count``)."""
        expired = []
        for req in list(self.running) + list(self.waiting):
            why = req.deadline_status(now)
            if why is None:
                continue
            self._drop(req, cache)
            req.stop_reason = why
            req.state = RequestState.TIMED_OUT
            self.finished.append(req)
            expired.append(req)
        return expired

    def drain_waiting(self) -> list[Request]:
        """Hand off the ENTIRE waiting queue (FCFS order) — the replica-
        group migration seam. Waiting requests hold no pages or slots,
        so draining them off a recovered replica and resubmitting them
        to a survivor is pure bookkeeping: the drained requests leave
        this scheduler entirely (they are not failed, not finished —
        their lifecycle continues on whichever engine readmits them)."""
        drained = list(self.waiting)
        self.waiting.clear()
        return drained

    def release(self, req: Request) -> bool:
        """Forget a terminal request (bounded retention): drop it from
        ``finished`` so scheduler state scales with in-flight work, not
        lifetime traffic. Double-release is explicit, not silent: a
        request no longer in ``finished`` returns False and does not
        bump ``released_count``."""
        if req not in self.finished:
            return False
        self.finished.remove(req)
        self.released_count += 1
        return True

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------- fault tolerance

    @staticmethod
    def _req_entry(r: Request) -> dict:
        """Full-fidelity request record for the ``full=True`` snapshot:
        nothing folded, nothing demoted — enough to resume the exact
        incarnation (slot, prefill cursor, state, lifetime event count)."""
        entry = {
            "request_id": r.request_id,
            "prompt": list(r.prompt),
            "generated": list(r.generated),
            "max_new_tokens": r.max_new_tokens,
            "arrived_at": r.arrived_at,
            "first_token_at": r.first_token_at,
            "finished_at": r.finished_at,
            "cached_tokens": r.cached_tokens,
            "emitted": r.emitted,
            "uid": r.uid,
            "seq_slot": r.seq_slot,
            "prefill_pos": r.prefill_pos,
            "state": r.state.value,
            "stop_reason": r.stop_reason,
        }
        if r.params is not None:
            entry["params"] = dataclasses.asdict(r.params)
        return entry

    @staticmethod
    def _req_from_entry(e: dict) -> Request:
        params = e.get("params")
        req = Request(
            request_id=e["request_id"], prompt=list(e["prompt"]),
            max_new_tokens=e["max_new_tokens"],
            arrived_at=e.get("arrived_at", 0.0),
            first_token_at=e.get("first_token_at", 0.0),
            finished_at=e.get("finished_at", 0.0),
            cached_tokens=e.get("cached_tokens", 0),
            emitted=e.get("emitted", 0),
            uid=e.get("uid", -1),
            params=SamplingParams(**params) if params else None)
        req.generated = list(e.get("generated", []))
        req.seq_slot = e.get("seq_slot", -1)
        req.prefill_pos = e.get("prefill_pos", 0)
        req.state = RequestState(e.get("state", "queued"))
        req.stop_reason = e.get("stop_reason")
        req.terminal_emitted = req.state.terminal
        return req

    def snapshot(self, full: bool = False) -> str:
        """Serialize scheduler state.

        Legacy mode (default): running sequences are demoted to waiting
        — their device KV is lost with the node and is recomputed on
        restore — with generated text folded into the prompt.

        ``full=True``: the journaled-recovery mode. The exact
        waiting/running split, slot assignments, prefill cursors,
        free-slot order, and plan cursor are all captured, so a restore
        paired with :meth:`PagedKV4Cache.restore_state` resumes the
        very next step bit-identically (nothing re-prefills)."""
        if full:
            return json.dumps({
                "format": "full",
                "waiting": [self._req_entry(r) for r in self.waiting],
                "running": [self._req_entry(r) for r in self.running],
                "finished": [self._req_entry(r) for r in self.finished],
                "free_slots": list(self._free_slots),
                "plan_cursor": self._plan_cursor,
                "preemptions": self.preemptions,
                "released_count": self.released_count,
            })
        reqs = []
        for r in list(self.waiting) + self.running:
            entry = {
                "request_id": r.request_id,
                "prompt": list(r.prompt) + list(r.generated),
                "max_new_tokens": r.max_new_tokens - len(r.generated),
                "arrived_at": r.arrived_at,
                # TTFT / prefix-hit accounting must survive the restart:
                # a request that already produced its first token keeps
                # its stamp (restore must not re-measure TTFT against
                # the recomputed prefill), and cached_tokens keeps the
                # prefix-hit counters honest across the crash
                "first_token_at": r.first_token_at,
                "cached_tokens": r.cached_tokens,
                "emitted": r.emitted,
            }
            if r.params is not None:
                entry["params"] = dataclasses.asdict(r.params)
            reqs.append(entry)
        done = [{
            "request_id": r.request_id,
            "prompt": list(r.prompt),
            "generated": list(r.generated),
            "stop_reason": r.stop_reason,
            "state": r.state.value,
            "arrived_at": r.arrived_at,
            "first_token_at": r.first_token_at,
            "cached_tokens": r.cached_tokens,
            "emitted": r.emitted,
        } for r in self.finished]
        return json.dumps({"pending": reqs, "finished": done})

    @classmethod
    def restore(cls, blob: str, max_batch: int, max_seqs: int,
                max_waiting: Optional[int] = None) -> "Scheduler":
        state = json.loads(blob)
        sched = cls(max_batch, max_seqs, max_waiting)
        if state.get("format") == "full":
            for e in state["waiting"]:
                sched.waiting.append(cls._req_from_entry(e))
            for e in state["running"]:
                sched.running.append(cls._req_from_entry(e))
            for e in state["finished"]:
                sched.finished.append(cls._req_from_entry(e))
            sched._free_slots = list(state["free_slots"])
            sched._plan_cursor = state.get("plan_cursor", 0)
            sched.preemptions = state.get("preemptions", 0)
            sched.released_count = state.get("released_count", 0)
            return sched
        for r in state["pending"]:
            params = r.get("params")
            sched.submit(Request(
                request_id=r["request_id"], prompt=r["prompt"],
                max_new_tokens=r["max_new_tokens"],
                arrived_at=r["arrived_at"],
                first_token_at=r.get("first_token_at", 0.0),
                cached_tokens=r.get("cached_tokens", 0),
                emitted=r.get("emitted", 0),
                params=SamplingParams(**params) if params else None))
        for r in state["finished"]:
            req = Request(request_id=r["request_id"], prompt=r["prompt"],
                          max_new_tokens=0,
                          arrived_at=r.get("arrived_at", 0.0))
            req.generated = r["generated"]
            req.stop_reason = r.get("stop_reason")
            req.state = RequestState(r.get("state", "finished"))
            req.first_token_at = r.get("first_token_at", 0.0)
            req.cached_tokens = r.get("cached_tokens", 0)
            req.emitted = r.get("emitted", 0)
            req.terminal_emitted = req.state.terminal
            sched.finished.append(req)
        return sched
