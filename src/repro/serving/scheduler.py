"""Continuous-batching request scheduler with preemption + fault recovery.

COMET's end-to-end win (paper Fig. 11/12) comes from KV4 admitting larger
decode batches under a fixed memory budget; this scheduler is where that
batch is formed. Policy (vLLM-style):

* FCFS admission: a waiting request is admitted when the paged pool can
  hold its *first prefill chunk* — plus one decode token of headroom
  once the whole prompt is resident (chunked prefill — pass
  ``first_chunk_tokens``; whole-prompt admission reserves the full
  prompt). Prompts that can never fit ``max_pages_per_seq``, or whose
  prompt + one decode token exceeds the whole pool, are failed
  immediately with ``stop_reason="prompt_too_long"``.
* with ``prefix_cache=True`` admission first consults the cache's
  prefix index (``cache.match_prefix``): a request whose prompt prefix
  is already published adopts the shared pages, is charged only its
  un-cached pages against the pool, and starts with ``prefill_pos`` at
  the end of the shared prefix — only the suffix streams through
  ``plan_prefill``. A *preempted* request re-admits through the same
  path, so its own previously-published prompt pages are a warm hit;
* requests track ``prefill_pos`` (prompt tokens already through the
  model) so prefill proceeds chunk-by-chunk and preemption can fire
  mid-prefill — a preempted request simply restarts at ``prefill_pos=0``;
* decode batch = all running sequences (up to ``max_batch``);
* on pool exhaustion the *youngest* running sequence is preempted back to
  the waiting queue (its pages freed — recomputed on re-admission);
* ``snapshot``/``restore`` serialize scheduler state so an engine restart
  (node failure) resumes with pending work intact — generated text is
  reproducible because sampling is keyed by (request_id, position).
  Mid-prefill progress is device KV (lost with the node), so pending
  requests restore at ``prefill_pos=0`` with generated text folded in.
"""

from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Callable, Optional

from repro.serving.api import RequestState, SamplingParams

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: list                   # token ids
    max_new_tokens: int
    arrived_at: float = 0.0
    generated: list = dataclasses.field(default_factory=list)
    seq_slot: int = -1             # cache slot when running
    prefill_pos: int = 0           # prompt tokens already through the model
    stop_reason: Optional[str] = None   # None = ran to max_new_tokens
    first_token_at: float = 0.0    # wall clock of first generated token
    params: Optional[SamplingParams] = None   # None → engine defaults
    state: RequestState = RequestState.QUEUED
    cached_tokens: int = 0         # prefix-cache hit tokens, last admission
    events: list = dataclasses.field(          # RequestOutput stream log
        default_factory=list, repr=False, compare=False)
    on_event: Optional[Callable] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def prefilled(self) -> bool:
        return self.prefill_pos >= len(self.prompt)

    @prefilled.setter
    def prefilled(self, value: bool):
        self.prefill_pos = len(self.prompt) if value else 0

    @property
    def done(self) -> bool:
        return (self.stop_reason is not None
                or len(self.generated) >= self.max_new_tokens)

    @property
    def total_len(self) -> int:
        return len(self.prompt) + len(self.generated)


class Scheduler:
    def __init__(self, max_batch: int, max_seqs: int):
        self.max_batch = max_batch
        self.max_seqs = max_seqs
        self.waiting: deque[Request] = deque()
        self.running: list[Request] = []
        self.finished: list[Request] = []
        self._free_slots = list(range(max_seqs - 1, -1, -1))
        self.preemptions = 0
        self._plan_cursor = 0       # round-robin start for prefill plans

    # ----------------------------------------------------------------- queue

    def submit(self, req: Request):
        self.waiting.append(req)

    def admit(self, cache, first_chunk_tokens: Optional[int] = None,
              prefix_cache: bool = False) -> list[Request]:
        """Admit waiting requests while pages + slots are available.

        ``first_chunk_tokens``: with chunked prefill, admission only
        needs pages for the first chunk (later chunks acquire pages via
        ``cache.grow_to``); ``None`` reserves the whole prompt (the
        whole-prompt baseline path).

        ``prefix_cache``: consult ``cache.match_prefix`` first — the
        matched pages are adopted rather than allocated, only the
        UN-CACHED pages are charged against ``pages_free``, and the
        request starts at ``prefill_pos = matched`` so just the suffix
        streams through the prefill plan."""
        admitted = []
        while (self.waiting and self._free_slots
               and len(self.running) < self.max_batch):
            req = self.waiting[0]
            if (cache.pages_needed(len(req.prompt))
                    > cache.pcfg.max_pages_per_seq
                    or cache.pages_needed(len(req.prompt) + 1)
                    > cache.pcfg.num_pages):
                # can never fit the per-seq page budget, or prompt + one
                # decode token can never fit the whole pool — fail fast
                # instead of livelocking admission/preemption (chunked
                # would stream until the pool is exhausted, self-preempt,
                # and restart forever). Token-granular: a prompt whose
                # last page has slack for its decode tokens is servable.
                self.waiting.popleft()
                req.stop_reason = "prompt_too_long"
                req.state = RequestState.FINISHED
                self.finished.append(req)
                continue
            pages, matched = (cache.match_prefix(req.prompt)
                              if prefix_cache else ([], 0))
            reserve = (len(req.prompt) if first_chunk_tokens is None
                       else min(len(req.prompt),
                                matched + first_chunk_tokens))
            # token-granular decode headroom: one extra TOKEN (not a
            # whole extra page) once the full prompt is resident — a
            # prompt whose last page has slack admits into an exactly-
            # sized pool
            headroom = reserve + 1 if reserve == len(req.prompt) else reserve
            if (cache.pages_needed(headroom) - len(pages)
                    > cache.pages_available_for(pages)):
                break
            slot = self._free_slots.pop()
            if not cache.allocate_seq(slot, reserve, prefix_pages=pages,
                                      prefix_tokens=matched):
                self._free_slots.append(slot)
                break
            req.seq_slot = slot
            req.prefill_pos = matched     # shared prefix is already resident
            req.cached_tokens = matched
            req.state = RequestState.PREFILLING
            self.waiting.popleft()
            self.running.append(req)
            admitted.append(req)
        return admitted

    def plan_prefill(self, cache,
                     token_budget: int) -> list[tuple[Request, int, int]]:
        """Plan this step's prefill chunk: ``[(req, start, take), ...]``.

        Packs up to ``token_budget`` prompt tokens across the running
        requests that still have prompt left, acquiring pages chunk-by-
        chunk (``cache.grow_to``); a request that can't get pages this
        step is simply skipped (decode keeps draining the pool).

        The scan start **round-robins** across the candidates (persistent
        cursor): a long prompt at the head of ``running`` would otherwise
        claim the whole budget every step and starve later arrivals of
        their first token. The engine passes a budget already debited for
        this step's decode rows, so chunk rows and decode rows share one
        per-step token budget — the unified forward's shape stays bounded
        by ``prefill_chunk_tokens`` regardless of the decode batch."""
        cands = [r for r in self.running if r.prefill_pos < len(r.prompt)]
        if not cands or token_budget <= 0:
            return []
        rot = self._plan_cursor % len(cands)
        self._plan_cursor += 1
        budget = token_budget
        plan: list[tuple[Request, int, int]] = []
        for req in cands[rot:] + cands[:rot]:
            if budget <= 0:
                break
            rem = len(req.prompt) - req.prefill_pos
            want = req.prefill_pos + min(rem, budget)
            cap = cache.grow_to(req.seq_slot, want)
            take = min(rem, budget, cap - req.prefill_pos)
            if take <= 0:
                continue
            plan.append((req, req.prefill_pos, take))
            budget -= take
        return plan

    def preempt_one(self, cache) -> Optional[Request]:
        """Evict the youngest running sequence to the waiting queue.

        Only the victim's own references are dropped (``cache.free_seq``
        is refcount-exact): pages it shared with other sequences stay
        mapped for them, and its own *published* prompt pages stay
        cached — re-admission goes back through ``match_prefix``, so a
        warm prefix cache turns the re-prefill into a page-table copy
        plus the un-cached tail.

        Finished requests (done but not yet completed by the engine's
        end-of-step sweep) are never victims: preempting one would fold
        its generated text back into the prompt and silently destroy its
        output. Their pages are released at completion instead."""
        candidates = [r for r in self.running if not r.done]
        if not candidates:
            return None
        req = max(candidates, key=lambda r: r.arrived_at)
        self.running.remove(req)
        cache.free_seq(req.seq_slot)
        self._free_slots.append(req.seq_slot)
        req.seq_slot = -1
        # keep generated text: re-admission prefills prompt+generated.
        # Mid-prefill victims (generated == []) simply restart at 0.
        req.prompt = req.prompt + req.generated
        req.max_new_tokens -= len(req.generated)
        req.generated = []
        req.prefill_pos = 0
        req.state = RequestState.QUEUED
        self.waiting.appendleft(req)
        self.preemptions += 1
        return req

    def complete(self, req: Request, cache):
        self.running.remove(req)
        cache.free_seq(req.seq_slot)
        self._free_slots.append(req.seq_slot)
        req.seq_slot = -1
        req.state = RequestState.FINISHED
        self.finished.append(req)

    def abort(self, req: Request, cache) -> bool:
        """Cancel ``req`` wherever it is in the lifecycle. Running
        sequences (mid-prefill or mid-decode) drop their page references
        refcount-exactly; queued requests just leave the queue. Returns
        False if the request already reached a terminal state."""
        if req.state.terminal:
            return False
        if req in self.running:
            self.running.remove(req)
            cache.free_seq(req.seq_slot)
            self._free_slots.append(req.seq_slot)
            req.seq_slot = -1
        elif req in self.waiting:
            self.waiting.remove(req)
        req.stop_reason = "aborted"
        req.state = RequestState.ABORTED
        self.finished.append(req)
        return True

    def release(self, req: Request):
        """Forget a terminal request (bounded retention): drop it from
        ``finished`` so scheduler state scales with in-flight work, not
        lifetime traffic. No-op if the request was already released."""
        try:
            self.finished.remove(req)
        except ValueError:
            pass

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------- fault tolerance

    def snapshot(self) -> str:
        """Serialize pending work (running seqs are demoted to waiting —
        their device KV is lost on failure and recomputed on restore)."""
        reqs = []
        for r in list(self.waiting) + self.running:
            entry = {
                "request_id": r.request_id,
                "prompt": list(r.prompt) + list(r.generated),
                "max_new_tokens": r.max_new_tokens - len(r.generated),
                "arrived_at": r.arrived_at,
                # TTFT / prefix-hit accounting must survive the restart:
                # a request that already produced its first token keeps
                # its stamp (restore must not re-measure TTFT against
                # the recomputed prefill), and cached_tokens keeps the
                # prefix-hit counters honest across the crash
                "first_token_at": r.first_token_at,
                "cached_tokens": r.cached_tokens,
            }
            if r.params is not None:
                entry["params"] = dataclasses.asdict(r.params)
            reqs.append(entry)
        done = [{
            "request_id": r.request_id,
            "prompt": list(r.prompt),
            "generated": list(r.generated),
            "stop_reason": r.stop_reason,
            "state": r.state.value,
            "arrived_at": r.arrived_at,
            "first_token_at": r.first_token_at,
            "cached_tokens": r.cached_tokens,
        } for r in self.finished]
        return json.dumps({"pending": reqs, "finished": done})

    @classmethod
    def restore(cls, blob: str, max_batch: int, max_seqs: int) -> "Scheduler":
        state = json.loads(blob)
        sched = cls(max_batch, max_seqs)
        for r in state["pending"]:
            params = r.get("params")
            sched.submit(Request(
                request_id=r["request_id"], prompt=r["prompt"],
                max_new_tokens=r["max_new_tokens"],
                arrived_at=r["arrived_at"],
                first_token_at=r.get("first_token_at", 0.0),
                cached_tokens=r.get("cached_tokens", 0),
                params=SamplingParams(**params) if params else None))
        for r in state["finished"]:
            req = Request(request_id=r["request_id"], prompt=r["prompt"],
                          max_new_tokens=0,
                          arrived_at=r.get("arrived_at", 0.0))
            req.generated = r["generated"]
            req.stop_reason = r.get("stop_reason")
            req.state = RequestState(r.get("state", "finished"))
            req.first_token_at = r.get("first_token_at", 0.0)
            req.cached_tokens = r.get("cached_tokens", 0)
            sched.finished.append(req)
        return sched
