"""Continuous-batching request scheduler with preemption + fault recovery.

COMET's end-to-end win (paper Fig. 11/12) comes from KV4 admitting larger
decode batches under a fixed memory budget; this scheduler is where that
batch is formed. Policy (vLLM-style):

* FCFS admission: a waiting request is admitted when the paged pool can
  hold its prompt plus one page of headroom.
* decode batch = all running sequences (up to ``max_batch``);
* on pool exhaustion the *youngest* running sequence is preempted back to
  the waiting queue (its pages freed — recomputed on re-admission);
* ``snapshot``/``restore`` serialize scheduler state so an engine restart
  (node failure) resumes with pending work intact — generated text is
  reproducible because sampling is keyed by (request_id, position).
"""

from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Optional

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: list                   # token ids
    max_new_tokens: int
    arrived_at: float = 0.0
    generated: list = dataclasses.field(default_factory=list)
    seq_slot: int = -1             # cache slot when running
    prefilled: bool = False

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def total_len(self) -> int:
        return len(self.prompt) + len(self.generated)


class Scheduler:
    def __init__(self, max_batch: int, max_seqs: int):
        self.max_batch = max_batch
        self.max_seqs = max_seqs
        self.waiting: deque[Request] = deque()
        self.running: list[Request] = []
        self.finished: list[Request] = []
        self._free_slots = list(range(max_seqs - 1, -1, -1))
        self.preemptions = 0

    # ----------------------------------------------------------------- queue

    def submit(self, req: Request):
        self.waiting.append(req)

    def admit(self, cache) -> list[Request]:
        """Admit waiting requests while pages + slots are available."""
        admitted = []
        while (self.waiting and self._free_slots
               and len(self.running) < self.max_batch):
            req = self.waiting[0]
            need = cache.pages_needed(len(req.prompt)) + 1
            if need > cache.pages_free:
                break
            slot = self._free_slots.pop()
            if not cache.allocate_seq(slot, len(req.prompt)):
                self._free_slots.append(slot)
                break
            req.seq_slot = slot
            req.prefilled = False
            self.waiting.popleft()
            self.running.append(req)
            admitted.append(req)
        return admitted

    def preempt_one(self, cache) -> Optional[Request]:
        """Evict the youngest running sequence to the waiting queue."""
        if not self.running:
            return None
        req = max(self.running, key=lambda r: r.arrived_at)
        self.running.remove(req)
        cache.free_seq(req.seq_slot)
        self._free_slots.append(req.seq_slot)
        req.seq_slot = -1
        req.prefilled = False
        # keep generated text: re-admission prefills prompt+generated
        req.prompt = req.prompt + req.generated
        req.max_new_tokens -= len(req.generated)
        req.generated = []
        self.waiting.appendleft(req)
        self.preemptions += 1
        return req

    def complete(self, req: Request, cache):
        self.running.remove(req)
        cache.free_seq(req.seq_slot)
        self._free_slots.append(req.seq_slot)
        req.seq_slot = -1
        self.finished.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------- fault tolerance

    def snapshot(self) -> str:
        """Serialize pending work (running seqs are demoted to waiting —
        their device KV is lost on failure and recomputed on restore)."""
        reqs = []
        for r in list(self.waiting) + self.running:
            reqs.append({
                "request_id": r.request_id,
                "prompt": list(r.prompt) + list(r.generated),
                "max_new_tokens": r.max_new_tokens - len(r.generated),
                "arrived_at": r.arrived_at,
            })
        done = [{
            "request_id": r.request_id,
            "prompt": list(r.prompt),
            "generated": list(r.generated),
        } for r in self.finished]
        return json.dumps({"pending": reqs, "finished": done})

    @classmethod
    def restore(cls, blob: str, max_batch: int, max_seqs: int) -> "Scheduler":
        state = json.loads(blob)
        sched = cls(max_batch, max_seqs)
        for r in state["pending"]:
            sched.submit(Request(
                request_id=r["request_id"], prompt=r["prompt"],
                max_new_tokens=r["max_new_tokens"],
                arrived_at=r["arrived_at"]))
        for r in state["finished"]:
            req = Request(request_id=r["request_id"], prompt=r["prompt"],
                          max_new_tokens=0)
            req.generated = r["generated"]
            sched.finished.append(req)
        return sched
