"""Journaled crash recovery: full snapshots + a per-token event journal.

The engine's legacy ``snapshot``/``restore`` seam survives a crash by
demoting running work to waiting and re-prefilling it — correct, but not
bit-identical (re-prefill attends the in-flight chunk in fp, decode
reads int4 pages back; greedy argmax can flip on near-ties). A serving
tier that promises its clients at-most-once token streams needs more:
**exactly-once event delivery across a crash, with the continued output
bitwise equal to the uninterrupted run**. This module provides that by
pairing two artifacts:

* **Full snapshots** (``Engine.snapshot(full=True)``, taken every
  ``snapshot_every`` steps): the int4 pool bytes, block tables,
  free-list and prefix-LRU order, the exact waiting/running split,
  slots, prefill cursors, and each request's lifetime event count
  (``Request.emitted``). A restore resumes the very next step
  bit-identically — nothing re-prefills, so the fp-vs-int4 numerics
  hazard never arises.
* **A per-token event journal**: every event the engine emits is logged
  under the key ``(request_id, lifetime ordinal)`` — the ordinal is the
  request's ``emitted`` cursor, NOT ``len(generated)`` (which resets
  when a preemption folds generated text back into the prompt, so two
  different tokens could collide on the same key across incarnations).
  Terminal events use the sentinel ordinal -1 (exactly one per request,
  so the key is naturally unique).

Recovery replays the gap between the last snapshot and the crash: the
restored engine re-runs those steps, and every event it re-emits that is
already journaled is (a) **verified bitwise** against the journal — a
token mismatch raises :class:`ReplayMismatch`, the CI greedy-identical
assert — and (b) **suppressed** from delivery (``step()`` returns only
fresh events), so a downstream consumer sees each token exactly once
across the crash.

Two modes: in-memory (tests hand ``RecoveryLog.resume`` the old log's
``snapshot_blob``/``journal``) and directory-backed (``dir=`` writes
``snapshot.json`` atomically + appends ``journal.jsonl`` per step;
``RecoveryLog.open_dir`` rebuilds after a real process kill).
"""

from __future__ import annotations

import json
import os
from typing import Optional

__all__ = ["RecoveryLog", "ReplayMismatch"]

_TERMINAL = -1      # journal ordinal sentinel for a terminal event


class ReplayMismatch(RuntimeError):
    """A replayed event disagreed with the journal — the restored engine
    is NOT continuing the crashed run's output."""


class RecoveryLog:
    """Rides along with an :class:`~repro.serving.engine.Engine`: drive
    steps through :meth:`step` (instead of ``engine.step()`` +
    ``engine.events()``) and the log journals every event, checkpoints a
    full snapshot every ``snapshot_every`` steps, and — after a resume —
    verifies and deduplicates the replayed gap.

    ``journal`` entries: ``{"rid", "ord", "token", "state", "stop"}``
    (``ord`` = lifetime token ordinal, -1 for the terminal event).
    """

    def __init__(self, engine, snapshot_every: int = 8,
                 dir: Optional[str] = None, _journal=None,
                 _snapshot: Optional[str] = None):
        if snapshot_every < 1:
            raise ValueError("snapshot_every must be >= 1")
        self.engine = engine
        self.snapshot_every = snapshot_every
        self.dir = dir
        self.journal: list[dict] = list(_journal or [])
        self._by_key = {(e["rid"], e["ord"]): e for e in self.journal}
        # per-request delivery cursor: the next token event's lifetime
        # ordinal. Seeded from the (restored) requests' emitted counts
        # so replayed tokens key to the SAME ordinals the crashed run
        # journaled them under.
        self._cursor = {rid: r.emitted for rid, r in engine._by_id.items()}
        self.replayed = 0           # journaled events re-emitted + verified
        self.steps_logged = 0
        self._snapshot = _snapshot if _snapshot is not None \
            else engine.snapshot(full=True)
        self._snapshot_step = engine.steps
        if self.dir is not None:
            os.makedirs(self.dir, exist_ok=True)
            self._write_snapshot()

    # --------------------------------------------------------------- logging

    @property
    def snapshot_blob(self) -> str:
        """The latest checkpointed full snapshot (NOT live state)."""
        return self._snapshot

    def checkpoint(self):
        """Take a full snapshot now (normally automatic via
        ``snapshot_every``)."""
        self._snapshot = self.engine.snapshot(full=True)
        self._snapshot_step = self.engine.steps
        if self.dir is not None:
            self._write_snapshot()

    def step(self):
        """One engine step → the step's FRESH events (replayed
        duplicates verified against the journal and suppressed)."""
        self.engine.step()
        fresh = []
        new_entries = []
        for ev in self.engine.events():
            if ev.token is not None:
                ordn = self._cursor.get(ev.request_id, 0)
                self._cursor[ev.request_id] = ordn + 1
            else:
                ordn = _TERMINAL
            entry = {"rid": ev.request_id, "ord": ordn,
                     "token": ev.token, "state": ev.state.value,
                     "stop": ev.stop_reason}
            prior = self._by_key.get((ev.request_id, ordn))
            if prior is not None:
                # the crashed run already delivered this event: verify
                # the replay is bitwise identical, deliver nothing
                if prior["token"] != entry["token"]:
                    raise ReplayMismatch(
                        f"request {ev.request_id} token ordinal {ordn}: "
                        f"replay produced {entry['token']}, journal has "
                        f"{prior['token']} — continuation is not "
                        "bit-identical")
                self.replayed += 1
                continue
            self.journal.append(entry)
            self._by_key[(ev.request_id, ordn)] = entry
            new_entries.append(entry)
            fresh.append(ev)
        if self.dir is not None and new_entries:
            with open(os.path.join(self.dir, "journal.jsonl"), "a") as f:
                for e in new_entries:
                    f.write(json.dumps(e) + "\n")
        self.steps_logged += 1
        if self.engine.steps % self.snapshot_every == 0:
            self.checkpoint()
        return fresh

    def run(self, max_steps: int = 10_000):
        """Drive steps until the engine drains; → all fresh events."""
        out = []
        while self.engine.sched.has_work and max_steps > 0:
            out.extend(self.step())
            max_steps -= 1
        return out

    def tokens_for(self, rid: int) -> list[int]:
        """The journaled token stream for one request, in order."""
        return [e["token"] for e in self.journal
                if e["rid"] == rid and e["ord"] != _TERMINAL]

    def terminal_for(self, rid: int) -> Optional[dict]:
        return self._by_key.get((rid, _TERMINAL))

    # -------------------------------------------------------------- recovery

    @classmethod
    def resume(cls, snapshot_blob: str, journal: list, cfg, qparams,
               quant, ecfg, snapshot_every: int = 8,
               dir: Optional[str] = None, **engine_kw) -> "RecoveryLog":
        """Rebuild after a crash: restore the engine from the last full
        snapshot and seed the log with the crashed run's journal. Steps
        between the snapshot and the crash re-run — their events are
        verified against the journal and NOT redelivered."""
        from repro.serving.engine import Engine
        eng = Engine.restore(snapshot_blob, cfg, qparams, quant, ecfg,
                             **engine_kw)
        return cls(eng, snapshot_every=snapshot_every, dir=dir,
                   _journal=journal, _snapshot=snapshot_blob)

    @classmethod
    def open_dir(cls, dir: str, cfg, qparams, quant, ecfg,
                 snapshot_every: int = 8, **engine_kw) -> "RecoveryLog":
        """Resume from a directory-backed log after a process kill."""
        with open(os.path.join(dir, "snapshot.json")) as f:
            snapshot_blob = f.read()
        journal = []
        jpath = os.path.join(dir, "journal.jsonl")
        if os.path.exists(jpath):
            with open(jpath) as f:
                journal = [json.loads(line) for line in f if line.strip()]
        return cls.resume(snapshot_blob, journal, cfg, qparams, quant,
                          ecfg, snapshot_every=snapshot_every, dir=dir,
                          **engine_kw)

    def _write_snapshot(self):
        # atomic: a kill mid-write must not corrupt the last good
        # snapshot (rename is atomic on POSIX)
        tmp = os.path.join(self.dir, "snapshot.json.tmp")
        with open(tmp, "w") as f:
            f.write(self._snapshot)
        os.replace(tmp, os.path.join(self.dir, "snapshot.json"))
