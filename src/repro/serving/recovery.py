"""Journaled crash recovery: full snapshots + a per-token event journal.

The engine's legacy ``snapshot``/``restore`` seam survives a crash by
demoting running work to waiting and re-prefilling it — correct, but not
bit-identical (re-prefill attends the in-flight chunk in fp, decode
reads int4 pages back; greedy argmax can flip on near-ties). A serving
tier that promises its clients at-most-once token streams needs more:
**exactly-once event delivery across a crash, with the continued output
bitwise equal to the uninterrupted run**. This module provides that by
pairing two artifacts:

* **Full snapshots** (``Engine.snapshot(full=True)``, taken every
  ``snapshot_every`` steps): the int4 pool bytes, block tables,
  free-list and prefix-LRU order, the exact waiting/running split,
  slots, prefill cursors, and each request's lifetime event count
  (``Request.emitted``). A restore resumes the very next step
  bit-identically — nothing re-prefills, so the fp-vs-int4 numerics
  hazard never arises.
* **A per-token event journal** covering exactly the gap since the last
  snapshot: every event the engine emits is logged under the key
  ``(uid, lifetime ordinal)``. ``uid`` is the request's
  incarnation-qualified id (``Request.uid``, an engine-lifetime
  monotonic submit counter) — NOT the ``request_id``, which is reusable
  after ``Engine.release()`` and would let a new request's fresh tokens
  collide with a dead request's journal keys (silently suppressed as
  "replays", or spuriously flagged ``ReplayMismatch``). The ordinal is
  the request's ``emitted`` cursor, NOT ``len(generated)`` (which
  resets when a preemption folds generated text back into the prompt).
  Terminal events use the sentinel ordinal -1 (exactly one per
  incarnation, so the key is naturally unique).

**Compaction.** A journal entry at or before the last full snapshot's
per-request ``emitted`` cursor can never be replayed: a restore from
that snapshot seeds every request's delivery cursor AT the snapshot, so
re-run steps only regenerate events past it. Each checkpoint therefore
drops the dead prefix — in memory, and in dir mode by atomically
rewriting ``journal.jsonl`` (write-temp + rename, same contract as the
snapshot) — so both artifacts stay bounded by one snapshot interval of
traffic instead of growing with lifetime traffic. ``journaled_total`` /
``compacted_total`` count lifetime entries for observability.

Recovery replays the gap between the last snapshot and the crash: the
restored engine re-runs those steps, and every event it re-emits that is
already journaled is (a) **verified bitwise** against the journal — a
token mismatch raises :class:`ReplayMismatch`, the CI greedy-identical
assert — and (b) **suppressed** from delivery (``step()`` returns only
fresh events), so a downstream consumer sees each token exactly once
across the crash.

Two modes: in-memory (tests hand ``RecoveryLog.resume`` the old log's
``snapshot_blob``/``journal``) and directory-backed (``dir=`` writes
``snapshot.json`` atomically + appends ``journal.jsonl`` per step;
``RecoveryLog.open_dir`` rebuilds after a real process kill). The
``snapshot_write`` fault point (``serving/faults.py``) tears the
snapshot temp file mid-write to prove the rename keeps the last good
snapshot intact.

**The replica-group seam.** ``serving/replication.py`` builds
multi-replica availability on exactly this pair of artifacts: each
serving replica drives its engine through a private ``RecoveryLog``,
and after every healthy step the :class:`ReplicaGroup` controller
"ships" ``(snapshot_blob, journal, steps)`` — the standby's durable
view. A replica death is recovered ONLY from that shipped view (the
dead engine's live memory is never trusted): ``RecoveryLog.resume``
restores at the last shipped snapshot and the re-run gap is verified/
suppressed against the shipped journal, which is what makes failover
exactly-once and bitwise — whether the resumed engine is promoted whole
(standby mode) or drained into survivors (migrate mode). What is
per-replica: the engine, pools, scheduler, this log. What is
group-global: request ids, the delivered-event record, routing.
"""

from __future__ import annotations

import json
import os
from typing import Optional

from repro.serving.faults import InjectedFault

__all__ = ["RecoveryLog", "ReplayMismatch"]

_TERMINAL = -1      # journal ordinal sentinel for a terminal event


class ReplayMismatch(RuntimeError):
    """A replayed event disagreed with the journal — the restored engine
    is NOT continuing the crashed run's output."""


class RecoveryLog:
    """Rides along with an :class:`~repro.serving.engine.Engine`: drive
    steps through :meth:`step` (instead of ``engine.step()`` +
    ``engine.events()``) and the log journals every event, checkpoints a
    full snapshot every ``snapshot_every`` steps (compacting the journal
    down to the new gap), and — after a resume — verifies and
    deduplicates the replayed gap.

    ``journal`` entries: ``{"rid", "uid", "ord", "token", "state",
    "stop"}`` (``ord`` = lifetime token ordinal, -1 for the terminal
    event; ``uid`` = the incarnation-qualified id entries are keyed by).
    """

    def __init__(self, engine, snapshot_every: int = 8,
                 dir: Optional[str] = None, _journal=None,
                 _snapshot: Optional[str] = None):
        if snapshot_every < 1:
            raise ValueError("snapshot_every must be >= 1")
        self.engine = engine
        self.snapshot_every = snapshot_every
        self.dir = dir
        self.journal: list[dict] = list(_journal or [])
        self._by_key = {(e["uid"], e["ord"]): e for e in self.journal}
        # per-request delivery cursor: the next token event's lifetime
        # ordinal, keyed by uid. Seeded from the (restored) requests'
        # emitted counts so replayed tokens key to the SAME ordinals the
        # crashed run journaled them under.
        self._cursor = {r.uid: r.emitted for r in engine._by_id.values()}
        self._uid_of = {r.request_id: r.uid
                        for r in engine._by_id.values()}
        self.replayed = 0           # journaled events re-emitted + verified
        self.steps_logged = 0
        self.journaled_total = len(self.journal)   # lifetime entries seen
        self.compacted_total = 0    # entries dropped as unreplayable
        self._snapshot = _snapshot if _snapshot is not None \
            else engine.snapshot(full=True)
        self._snapshot_step = engine.steps
        if self.dir is not None:
            os.makedirs(self.dir, exist_ok=True)
            self._write_snapshot()

    # --------------------------------------------------------------- logging

    @property
    def snapshot_blob(self) -> str:
        """The latest checkpointed full snapshot (NOT live state)."""
        return self._snapshot

    @property
    def snapshot_step(self) -> int:
        """Engine step the latest checkpoint was taken at."""
        return self._snapshot_step

    def checkpoint(self):
        """Take a full snapshot now (normally automatic via
        ``snapshot_every``) and compact the journal: entries at or
        before the new snapshot's per-request ``emitted`` cursors can
        never replay — a resume from this snapshot starts every
        delivery cursor at the snapshot — so they are dropped in memory
        and ``journal.jsonl`` is atomically rewritten to match."""
        self._snapshot = self.engine.snapshot(full=True)
        self._snapshot_step = self.engine.steps
        self._compact()
        if self.dir is not None:
            self._write_snapshot()
            self._rewrite_journal()

    def _compact(self):
        """Drop journal entries the latest snapshot makes unreplayable.

        Keep an entry only if its request is live in the snapshot
        (released requests can never re-emit), non-terminal there (a
        terminal request restores with ``terminal_emitted`` set), and —
        for token entries — its ordinal is at or past the snapshot's
        ``emitted`` cursor. Taken at checkpoint time this retains
        nothing (the snapshot IS the present), but the predicate is the
        contract, not "clear()": a journal handed in by ``resume`` may
        already trail the snapshot it rides with."""
        live = {r.uid: r for r in self.engine._by_id.values()}

        def replayable(e):
            r = live.get(e["uid"])
            if r is None or r.state.terminal:
                return False
            return e["ord"] != _TERMINAL and e["ord"] >= r.emitted

        kept = [e for e in self.journal if replayable(e)]
        self.compacted_total += len(self.journal) - len(kept)
        self.journal = kept
        self._by_key = {(e["uid"], e["ord"]): e for e in kept}

    def step(self):
        """One engine step → the step's FRESH events (replayed
        duplicates verified against the journal and suppressed)."""
        self.engine.step()
        fresh = []
        new_entries = []
        for ev in self.engine.events():
            req = self.engine._by_id.get(ev.request_id)
            if req is not None:
                self._uid_of[ev.request_id] = req.uid
            uid = self._uid_of.get(ev.request_id, ev.request_id)
            if ev.token is not None:
                ordn = self._cursor.get(uid, 0)
                self._cursor[uid] = ordn + 1
            else:
                ordn = _TERMINAL
            entry = {"rid": ev.request_id, "uid": uid, "ord": ordn,
                     "token": ev.token, "state": ev.state.value,
                     "stop": ev.stop_reason}
            prior = self._by_key.get((uid, ordn))
            if prior is not None:
                # the crashed run already delivered this event: verify
                # the replay is bitwise identical, deliver nothing
                if prior["token"] != entry["token"]:
                    raise ReplayMismatch(
                        f"request {ev.request_id} (uid {uid}) token "
                        f"ordinal {ordn}: replay produced "
                        f"{entry['token']}, journal has "
                        f"{prior['token']} — continuation is not "
                        "bit-identical")
                self.replayed += 1
                continue
            self.journal.append(entry)
            self._by_key[(uid, ordn)] = entry
            new_entries.append(entry)
            fresh.append(ev)
        self.journaled_total += len(new_entries)
        if self.dir is not None and new_entries:
            with open(os.path.join(self.dir, "journal.jsonl"), "a") as f:
                for e in new_entries:
                    f.write(json.dumps(e) + "\n")
        self.steps_logged += 1
        if self.engine.steps % self.snapshot_every == 0:
            self.checkpoint()
        return fresh

    def run(self, max_steps: int = 10_000):
        """Drive steps until the engine drains; → all fresh events."""
        out = []
        while self.engine.sched.has_work and max_steps > 0:
            out.extend(self.step())
            max_steps -= 1
        return out

    def tokens_for(self, rid: int) -> list[int]:
        """The journaled token stream for one request SINCE THE LAST
        CHECKPOINT (compaction drops older entries), in order. The full
        delivered history is the caller's to keep — e.g.
        ``ReplicaGroup`` records every delivered token per request."""
        return [e["token"] for e in self.journal
                if e["rid"] == rid and e["ord"] != _TERMINAL]

    def terminal_for(self, rid: int) -> Optional[dict]:
        uid = self._uid_of.get(rid, rid)
        return self._by_key.get((uid, _TERMINAL))

    # -------------------------------------------------------------- recovery

    @classmethod
    def resume(cls, snapshot_blob: str, journal: list, cfg, qparams,
               quant, ecfg, snapshot_every: int = 8,
               dir: Optional[str] = None, **engine_kw) -> "RecoveryLog":
        """Rebuild after a crash: restore the engine from the last full
        snapshot and seed the log with the crashed run's journal. Steps
        between the snapshot and the crash re-run — their events are
        verified against the journal and NOT redelivered."""
        from repro.serving.engine import Engine
        eng = Engine.restore(snapshot_blob, cfg, qparams, quant, ecfg,
                             **engine_kw)
        return cls(eng, snapshot_every=snapshot_every, dir=dir,
                   _journal=journal, _snapshot=snapshot_blob)

    @classmethod
    def open_dir(cls, dir: str, cfg, qparams, quant, ecfg,
                 snapshot_every: int = 8, **engine_kw) -> "RecoveryLog":
        """Resume from a directory-backed log after a process kill."""
        with open(os.path.join(dir, "snapshot.json")) as f:
            snapshot_blob = f.read()
        journal = []
        jpath = os.path.join(dir, "journal.jsonl")
        if os.path.exists(jpath):
            with open(jpath) as f:
                journal = [json.loads(line) for line in f if line.strip()]
        return cls.resume(snapshot_blob, journal, cfg, qparams, quant,
                          ecfg, snapshot_every=snapshot_every, dir=dir,
                          **engine_kw)

    def _write_snapshot(self):
        # atomic: a kill mid-write must not corrupt the last good
        # snapshot (rename is atomic on POSIX). The snapshot_write fault
        # point simulates exactly that kill: a torn temp file, the
        # rename never reached — open_dir must still restore from the
        # previous good snapshot.json.
        tmp = os.path.join(self.dir, "snapshot.json.tmp")
        fault = self.engine.faults.check("snapshot_write")
        if fault is not None:
            with open(tmp, "w") as f:
                f.write(self._snapshot[: max(1, len(self._snapshot) // 2)])
            raise InjectedFault(
                "snapshot_write: killed mid-write (torn temp file)")
        with open(tmp, "w") as f:
            f.write(self._snapshot)
        os.replace(tmp, os.path.join(self.dir, "snapshot.json"))

    def _rewrite_journal(self):
        # same atomicity contract as the snapshot: the compacted journal
        # replaces journal.jsonl via write-temp + rename, so a kill
        # mid-rewrite leaves the previous (superset) journal — replaying
        # against a superset only suppresses more, never redelivers
        tmp = os.path.join(self.dir, "journal.jsonl.tmp")
        with open(tmp, "w") as f:
            for e in self.journal:
                f.write(json.dumps(e) + "\n")
        os.replace(tmp, os.path.join(self.dir, "journal.jsonl"))
