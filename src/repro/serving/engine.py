"""COMET serving engine: continuous batching over the paged KV4 cache.

The engine is the paper's §5 system layer: W4Ax projections + int4 paged
KV + vLLM-style scheduling. Unlike the scanned `LM.decode` (used for the
compile-time dry-run), the engine walks layers in a Python loop so each
layer's attention reads/writes the *paged* pool directly — the realistic
serving dataflow (append one token batched → block-table flash-decode).

Decode is gather-free: each layer issues exactly ONE paged-attention
kernel call for the whole decode batch, consuming the physical pools +
device block tables (O(pages touched) per step). The legacy
gather-then-attend path (`decode_attention="gather"`, a per-token
O(context) copy per sequence) is kept solely as the Fig. 11 benchmark
baseline.

Supported families here: dense, moe (the paper's evaluation set —
LLaMA/Qwen/Mistral class + MoE). Hybrid/ssm decode serve through
``LM.decode`` (their state is O(1) — paging buys nothing).

Fault tolerance: ``snapshot()`` captures scheduler state; ``Engine.
restore`` rebuilds mid-flight work after a crash (prompts re-prefill).
Sampling is keyed by (request_id, position), but regenerated text is not
bit-identical in general: re-prefill attends in fp while decode attends
over the int4 pages, so greedy argmax can flip on near-ties.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import qlinear as QL
from repro.kernels import ops
from repro.layers import attention as ATT
from repro.layers import common as C
from repro.layers import mlp as MLP
from repro.models.lm import LM, QuantConfig
from repro.serving.kv_cache import PagedKV4Cache, PagedKV4Config
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine", "EngineConfig"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 32
    num_pages: int = 512
    page_size: int = 64
    max_pages_per_seq: int = 64
    temperature: float = 0.0        # 0 → greedy
    top_k: int = 40
    decode_attention: str = "paged"  # "paged" (gather-free) | "gather"

    def __post_init__(self):
        if self.decode_attention not in ("paged", "gather"):
            raise ValueError(
                f"decode_attention must be 'paged' or 'gather', got "
                f"{self.decode_attention!r}")


class Engine:
    def __init__(self, cfg: ModelConfig, qparams, quant: QuantConfig,
                 ecfg: EngineConfig = EngineConfig()):
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"paged engine supports dense/moe; {cfg.family} serves via "
                "LM.decode")
        self.cfg = cfg
        self.quant = quant
        self.lm = LM(cfg, quant=quant)
        self.params = qparams
        self.ecfg = ecfg
        self.cache = PagedKV4Cache(
            cfg,
            PagedKV4Config(
                num_pages=ecfg.num_pages, page_size=ecfg.page_size,
                max_seqs=ecfg.max_batch * 2,
                max_pages_per_seq=ecfg.max_pages_per_seq),
            num_layer_slots=cfg.num_layers)
        self.sched = Scheduler(ecfg.max_batch, ecfg.max_batch * 2)
        self.steps = 0
        self.tokens_generated = 0

    # ------------------------------------------------------------------ API

    def add_request(self, request_id: int, prompt: list[int],
                    max_new_tokens: int):
        self.sched.submit(Request(
            request_id=request_id, prompt=list(prompt),
            max_new_tokens=max_new_tokens, arrived_at=time.time()))

    def run(self, max_steps: int = 10_000) -> list[Request]:
        while self.sched.has_work and self.steps < max_steps:
            self.step()
        return self.sched.finished

    def snapshot(self) -> str:
        return self.sched.snapshot()

    @classmethod
    def restore(cls, blob: str, cfg, qparams, quant,
                ecfg: EngineConfig = EngineConfig()) -> "Engine":
        eng = cls(cfg, qparams, quant, ecfg)
        eng.sched = Scheduler.restore(blob, ecfg.max_batch,
                                      ecfg.max_batch * 2)
        return eng

    # ----------------------------------------------------------------- step

    def step(self):
        self.steps += 1
        admitted = self.sched.admit(self.cache)
        for req in admitted:
            self._prefill(req)
        runnable = [r for r in self.sched.running if r.prefilled]
        if runnable:
            # page headroom: preempt until every runnable seq can extend
            i = 0
            while i < len(runnable):
                if not self.cache.extend_seq(runnable[i].seq_slot):
                    victim = self.sched.preempt_one(self.cache)
                    if victim in runnable:
                        runnable.remove(victim)
                    continue
                i += 1
            if runnable:
                self._decode_batch(runnable)
        for req in list(self.sched.running):
            if req.done:
                self.sched.complete(req, self.cache)

    # ------------------------------------------------------------- internals

    def _sample(self, logits: np.ndarray, request_id: int,
                position: int) -> int:
        if self.ecfg.temperature <= 0.0:
            return int(np.argmax(logits))
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), request_id), position)
        lg = jnp.asarray(logits) / self.ecfg.temperature
        topv, topi = jax.lax.top_k(lg, self.ecfg.top_k)
        idx = jax.random.categorical(key, topv)
        return int(topi[idx])

    def _block_params(self, li: int):
        return jax.tree.map(lambda a: a[li], self.params["blocks"])

    def _prefill(self, req: Request):
        cfg = self.cfg
        with self.lm._ctx():
            tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
            x = self.lm._embed(self.params, tokens)
            positions = jnp.arange(len(req.prompt))[None, :]
            for li in range(cfg.num_layers):
                bp = self._block_params(li)
                h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
                q, k, v = ATT._project_qkv(
                    bp["attn"], cfg, h, h, positions, positions)
                a = ATT.flash_attention(q, k, v, causal=cfg.causal)
                self.cache.write_prompt(li, req.seq_slot, k, v)
                a = a.astype(x.dtype).reshape(1, -1, cfg.q_dim)
                x = x + C.linear(bp["attn"]["wo"], a)
                h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
                if "moe" in bp:
                    y, _ = MLP.moe_apply(bp["moe"], h, cfg)
                else:
                    y = MLP.mlp_apply(bp["mlp"], h, cfg.mlp_act)
                x = x + y
            hN = C.apply_norm(self.params["final_norm"], x[:, -1:],
                              cfg.norm, cfg.norm_eps)
            logits = self.lm._head(self.params, hN)
        tok = self._sample(np.asarray(logits[0, -1]), req.request_id,
                           len(req.prompt))
        self.cache.extend_seq(req.seq_slot)
        req.generated.append(tok)
        req.prefilled = True
        self.tokens_generated += 1

    def _attend_paged(self, li: int, q, block_tables, lengths):
        """One kernel call for the whole decode batch — block tables in,
        no per-sequence materialization."""
        cache = self.cache
        return ops.paged_kv4_decode_attention(
            q[:, 0], cache.k_pool[li], cache.k_scale, cache.k_zero,
            cache.v_pool[li], cache.v_scale, cache.v_zero,
            block_tables, lengths, impl=self.quant.impl)

    def _attend_gather(self, li: int, q, slots, max_len, lengths):
        """[Benchmark baseline] per-token O(context) gather, then the
        contiguous KV4 kernel."""
        cache = self.cache
        kp, vp, _ = cache.gather_kv(li, slots, max_len)
        bsz = q.shape[0]
        bcast = lambda s: jnp.broadcast_to(s[None], (bsz, *s.shape))
        return ops.kv4_decode_attention(
            q[:, 0], kp, bcast(cache.k_scale), bcast(cache.k_zero),
            vp, bcast(cache.v_scale), bcast(cache.v_zero),
            lengths, impl=self.quant.impl)

    def _decode_batch(self, reqs: list[Request]):
        cfg = self.cfg
        slots = [r.seq_slot for r in reqs]
        bsz = len(reqs)
        last = jnp.asarray([[r.generated[-1]] for r in reqs], jnp.int32)

        lengths_np = self.cache.seq_len[slots].copy()
        max_len = int(lengths_np.max()) + 1
        paged = self.ecfg.decode_attention == "paged"
        # block tables are fixed for the step (extend_seq already ran);
        # lengths include the token being appended this step
        block_tables = self.cache.block_tables_device(slots, max_len)
        lengths = jnp.asarray(lengths_np + 1, jnp.int32)
        with self.lm._ctx():
            x = self.lm._embed(self.params, last)
            positions = jnp.asarray(lengths_np)[:, None]
            for li in range(cfg.num_layers):
                bp = self._block_params(li)
                h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
                q, k, v = ATT._project_qkv(
                    bp["attn"], cfg, h, h, positions, positions)
                # write the batch's new KV (one scatter), then attend over
                # the pools via block tables — one kernel call per layer
                self.cache.append_tokens(li, slots, k, v,
                                         positions=lengths_np)
                if paged:
                    out = self._attend_paged(li, q, block_tables, lengths)
                else:
                    out = self._attend_gather(li, q, slots, max_len, lengths)
                out = out.reshape(bsz, 1, cfg.q_dim).astype(x.dtype)
                x = x + C.linear(bp["attn"]["wo"], out)
                h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
                if "moe" in bp:
                    y, _ = MLP.moe_apply(bp["moe"], h, cfg)
                else:
                    y = MLP.mlp_apply(bp["mlp"], h, cfg.mlp_act)
                x = x + y
            hN = C.apply_norm(self.params["final_norm"], x,
                              cfg.norm, cfg.norm_eps)
            logits = np.asarray(self.lm._head(self.params, hN))
        self.cache.advance(slots)
        for bi, r in enumerate(reqs):
            tok = self._sample(logits[bi, -1], r.request_id, r.total_len)
            r.generated.append(tok)
            self.tokens_generated += 1
