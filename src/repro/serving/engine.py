"""COMET serving engine: continuous batching over the paged KV4 cache.

The engine is the paper's §5 system layer: W4Ax projections + int4 paged
KV + vLLM-style scheduling. Unlike the scanned `LM.decode` (used for the
compile-time dry-run), the engine walks layers in a Python loop so each
layer's attention reads/writes the *paged* pool directly — the realistic
serving dataflow (append one token batched → block-table flash-decode).

Prefill is chunked and batched (the QServe/Atom dataflow): each step
packs up to ``prefill_chunk_tokens`` prompt tokens from ALL partially-
prefilled requests into ONE ragged forward per layer (cu_seqlens-style
offsets), writes the chunk's quantized KV into the pools, and attends
with ``paged_kv4_prefill_attention`` — fp queries over the int4 paged
history plus the causal in-flight fp chunk. A prompt's KV is therefore
never resident in fp beyond one chunk (fp activation footprint is
bounded by ``prefill_chunk_tokens``), admission only needs pages for the
next chunk, preemption can fire mid-prefill, and decode steps interleave
with long-prompt prefill instead of stalling behind an O(T²) monolithic
forward. The legacy whole-prompt path (``prefill_mode="whole"``) is kept
as the Fig. 11 time-to-first-token benchmark baseline.

Decode is gather-free: each layer issues exactly ONE paged-attention
kernel call for the whole decode batch, consuming the physical pools +
device block tables (O(pages touched) per step). Per-step page
destinations are resolved on the host once and reused by every layer's
scatter (no per-layer block-table sync). The legacy gather-then-attend
path (`decode_attention="gather"`, a per-token O(context) copy per
sequence) is kept solely as the Fig. 11 benchmark baseline.

Sequences that hit ``max_pages_per_seq`` finish with
``stop_reason="length_cap"`` (preemption cannot help them — retrying
would livelock); prompts that can never fit the cap fail admission with
``stop_reason="prompt_too_long"``.

Supported families here: dense, moe (the paper's evaluation set —
LLaMA/Qwen/Mistral class + MoE). Hybrid/ssm decode serve through
``LM.decode`` (their state is O(1) — paging buys nothing).

Fault tolerance: ``snapshot()`` captures scheduler state; ``Engine.
restore`` rebuilds mid-flight work after a crash (prompts re-prefill
from ``prefill_pos=0`` — partial prefill is device KV, lost with the
node). Sampling is keyed by (request_id, position), but regenerated text
is not bit-identical in general: re-prefill attends in fp while decode
attends over the int4 pages, so greedy argmax can flip on near-ties.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import qlinear as QL
from repro.kernels import ops
from repro.layers import attention as ATT
from repro.layers import common as C
from repro.layers import mlp as MLP
from repro.models.lm import LM, QuantConfig
from repro.serving.kv_cache import PagedKV4Cache, PagedKV4Config
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine", "EngineConfig"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 32
    num_pages: int = 512
    page_size: int = 64
    max_pages_per_seq: int = 64
    temperature: float = 0.0        # 0 → greedy
    top_k: int = 40
    decode_attention: str = "paged"  # "paged" (gather-free) | "gather"
    prefill_mode: str = "chunked"    # "chunked" (ragged) | "whole" (baseline)
    prefill_chunk_tokens: int = 64   # ragged-prefill token budget per step
    kv_range: float = 16.0           # calibrated |k|,|v| range → int4 scales

    def __post_init__(self):
        if self.decode_attention not in ("paged", "gather"):
            raise ValueError(
                f"decode_attention must be 'paged' or 'gather', got "
                f"{self.decode_attention!r}")
        if self.prefill_mode not in ("chunked", "whole"):
            raise ValueError(
                f"prefill_mode must be 'chunked' or 'whole', got "
                f"{self.prefill_mode!r}")
        if self.prefill_chunk_tokens < 1:
            raise ValueError("prefill_chunk_tokens must be >= 1")


class Engine:
    def __init__(self, cfg: ModelConfig, qparams, quant: QuantConfig,
                 ecfg: EngineConfig = EngineConfig()):
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"paged engine supports dense/moe; {cfg.family} serves via "
                "LM.decode")
        self.cfg = cfg
        self.quant = quant
        self.lm = LM(cfg, quant=quant)
        self.params = qparams
        self.ecfg = ecfg
        self.cache = PagedKV4Cache(
            cfg,
            PagedKV4Config(
                num_pages=ecfg.num_pages, page_size=ecfg.page_size,
                max_seqs=ecfg.max_batch * 2,
                max_pages_per_seq=ecfg.max_pages_per_seq),
            num_layer_slots=cfg.num_layers,
            kv_range=ecfg.kv_range)
        self.sched = Scheduler(ecfg.max_batch, ecfg.max_batch * 2)
        self.steps = 0
        self.tokens_generated = 0
        # observability: largest fp-token prefill forward issued (bounded
        # by prefill_chunk_tokens in chunked mode) and how many steps ran
        # prefill and decode back-to-back (interleave evidence for fig11)
        self.peak_prefill_fp_tokens = 0
        self.interleaved_steps = 0

    # ------------------------------------------------------------------ API

    def add_request(self, request_id: int, prompt: list[int],
                    max_new_tokens: int):
        self.sched.submit(Request(
            request_id=request_id, prompt=list(prompt),
            max_new_tokens=max_new_tokens, arrived_at=time.time()))

    def run(self, max_steps: int = 10_000) -> list[Request]:
        while self.sched.has_work and self.steps < max_steps:
            self.step()
        return self.sched.finished

    def snapshot(self) -> str:
        return self.sched.snapshot()

    @classmethod
    def restore(cls, blob: str, cfg, qparams, quant,
                ecfg: EngineConfig = EngineConfig()) -> "Engine":
        eng = cls(cfg, qparams, quant, ecfg)
        eng.sched = Scheduler.restore(blob, ecfg.max_batch,
                                      ecfg.max_batch * 2)
        return eng

    # ----------------------------------------------------------------- step

    def step(self):
        self.steps += 1
        chunked = self.ecfg.prefill_mode == "chunked"
        admitted = self.sched.admit(
            self.cache,
            first_chunk_tokens=(self.ecfg.prefill_chunk_tokens if chunked
                                else None))
        if chunked:
            prefill_ran = self._prefill_chunked()
        else:
            for req in admitted:
                self._prefill(req)
            prefill_ran = bool(admitted)
        runnable = self._reserve_decode_slots(
            [r for r in self.sched.running if r.prefilled and not r.done])
        if runnable:
            self._decode_batch(runnable)
            if prefill_ran:
                self.interleaved_steps += 1
        for req in list(self.sched.running):
            if req.done:
                self.sched.complete(req, self.cache)

    def _reserve_decode_slots(self, runnable: list[Request]) -> list[Request]:
        """Page headroom for one decode token per runnable sequence.

        Preempts (youngest-first) until every remaining sequence can
        extend. A sequence already at ``max_pages_per_seq`` can never
        extend no matter how many pages are freed — it finishes with
        ``stop_reason="length_cap"`` instead of spinning the loop
        forever (the seed's infinite-loop bug)."""
        pending = list(runnable)
        ready: list[Request] = []
        while pending:
            r = pending.pop(0)
            if self.cache.extend_seq(r.seq_slot):
                ready.append(r)
                continue
            if self.cache.at_capacity(r.seq_slot):
                # complete NOW (not at end of step): the capped request
                # must leave sched.running before any later preempt_one
                # in this loop could victimize it and destroy its output,
                # and freeing its pages helps the still-pending sequences
                r.stop_reason = "length_cap"
                self.sched.complete(r, self.cache)
                continue
            victim = self.sched.preempt_one(self.cache)
            if victim is None:
                continue            # nothing to evict — stall r this step
            if victim in pending:
                pending.remove(victim)
            elif victim in ready:
                ready.remove(victim)
            if victim is not r:
                pending.insert(0, r)    # retry r with the freed pages
        return ready

    # ------------------------------------------------------------- internals

    def _sample(self, logits: np.ndarray, request_id: int,
                position: int) -> int:
        if self.ecfg.temperature <= 0.0:
            return int(np.argmax(logits))
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), request_id), position)
        lg = jnp.asarray(logits) / self.ecfg.temperature
        topv, topi = jax.lax.top_k(lg, self.ecfg.top_k)
        idx = jax.random.categorical(key, topv)
        return int(topi[idx])

    def _block_params(self, li: int):
        return jax.tree.map(lambda a: a[li], self.params["blocks"])

    def _prefill(self, req: Request):
        """[Benchmark baseline] whole-prompt prefill: one O(T²) fp flash
        forward per request; the full prompt's fp KV is live at once."""
        cfg = self.cfg
        self.peak_prefill_fp_tokens = max(self.peak_prefill_fp_tokens,
                                          len(req.prompt))
        with self.lm._ctx():
            tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
            x = self.lm._embed(self.params, tokens)
            positions = jnp.arange(len(req.prompt))[None, :]
            for li in range(cfg.num_layers):
                bp = self._block_params(li)
                h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
                q, k, v = ATT._project_qkv(
                    bp["attn"], cfg, h, h, positions, positions)
                a = ATT.flash_attention(q, k, v, causal=cfg.causal)
                self.cache.write_prompt(li, req.seq_slot, k, v)
                a = a.astype(x.dtype).reshape(1, -1, cfg.q_dim)
                x = x + C.linear(bp["attn"]["wo"], a)
                h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
                if "moe" in bp:
                    y, _ = MLP.moe_apply(bp["moe"], h, cfg)
                else:
                    y = MLP.mlp_apply(bp["mlp"], h, cfg.mlp_act)
                x = x + y
            hN = C.apply_norm(self.params["final_norm"], x[:, -1:],
                              cfg.norm, cfg.norm_eps)
            logits = self.lm._head(self.params, hN)
        tok = self._sample(np.asarray(logits[0, -1]), req.request_id,
                           len(req.prompt))
        self.cache.extend_seq(req.seq_slot)
        req.generated.append(tok)
        req.prefill_pos = len(req.prompt)
        if not req.first_token_at:      # preserve TTFT across preemptions
            req.first_token_at = time.time()
        self.tokens_generated += 1

    # ------------------------------------------------- chunked ragged prefill

    def _prefill_chunked(self) -> bool:
        """One chunked-prefill step: pack up to ``prefill_chunk_tokens``
        prompt tokens across ALL partially-prefilled running requests and
        push them through one ragged forward. Pages are acquired
        chunk-by-chunk (``grow_to``); a request that can't get pages this
        step simply waits (decode keeps draining the pool). Returns True
        if any prefill work ran."""
        budget = self.ecfg.prefill_chunk_tokens
        plan: list[tuple[Request, int, int]] = []   # (req, start, take)
        for req in self.sched.running:
            if budget <= 0:
                break
            rem = len(req.prompt) - req.prefill_pos
            if rem <= 0:
                continue
            want = req.prefill_pos + min(rem, budget)
            cap = self.cache.grow_to(req.seq_slot, want)
            take = min(rem, budget, cap - req.prefill_pos)
            if take <= 0:
                continue
            plan.append((req, req.prefill_pos, take))
            budget -= take
        if not plan:
            # no prefill progress possible: if nothing can decode either,
            # free pages so the next step can move (mid-prefill preemption)
            stuck = [r for r in self.sched.running if not r.prefilled]
            if stuck and not any(r.prefilled for r in self.sched.running):
                self.sched.preempt_one(self.cache)
            return False
        self._prefill_forward(plan)
        return True

    def _prefill_forward(self, plan: list[tuple[Request, int, int]]):
        """Run ONE ragged forward over the planned chunk slices.

        Tokens from all planned requests are packed into a single
        [1, T_total] sequence (cu_seqlens-style offsets) for the
        position-wise work (norms, W4Ax projections, MLP); attention
        unpacks to a padded [nseq, C_max] view for the paged prefill
        kernel, then repacks. Each layer writes the chunk's quantized KV
        into the pools via destinations precomputed once for the step."""
        cfg = self.cfg
        starts = np.asarray([s for _, s, _ in plan])
        takes = np.asarray([t for _, _, t in plan])
        slots = np.asarray([r.seq_slot for r, _, _ in plan])
        nseq, cmax, ttot = len(plan), int(takes.max()), int(takes.sum())
        cum = np.concatenate([[0], np.cumsum(takes)])

        # ragged layout: packed index → (sequence, in-chunk offset)
        tok_seq = np.repeat(np.arange(nseq), takes)
        tok_off = np.concatenate([np.arange(t) for t in takes])
        tok_pos = starts[tok_seq] + tok_off            # absolute positions
        tokens = np.concatenate(
            [r.prompt[s:s + t] for r, s, t in plan]).astype(np.int64)

        # page destinations: ONE host lookup for the step, all layers
        pages, offs = self.cache.token_dests(slots[tok_seq], tok_pos)
        block_tables = self.cache.block_tables_device(
            slots, max(int(starts.max()), 1))
        ctx = jnp.asarray(starts, jnp.int32)
        qlens = jnp.asarray(takes, jnp.int32)
        tseq = jnp.asarray(tok_seq)
        toff = jnp.asarray(tok_off)
        # packed↔padded fast paths: equal takes means the seq-major packed
        # layout IS the padded layout (reshape, no scatter/gather); chunks
        # with no paged history anywhere are pure fp causal attention
        uniform = bool((takes == takes[0]).all())
        no_history = int(starts.max()) == 0

        self.peak_prefill_fp_tokens = max(self.peak_prefill_fp_tokens, ttot)
        with self.lm._ctx():
            x = self.lm._embed(self.params,
                               jnp.asarray(tokens, jnp.int32)[None, :])
            positions = jnp.asarray(tok_pos)[None, :]
            for li in range(cfg.num_layers):
                bp = self._block_params(li)
                h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
                q, k, v = ATT._project_qkv(
                    bp["attn"], cfg, h, h, positions, positions)
                # quantize + page the chunk's KV, then attend: fp queries
                # over int4 history pages + the causal in-flight fp chunk
                self.cache.scatter_tokens(li, pages, offs, k, v)

                def pad(a):       # [1, Ttot, Hx, D] → [nseq, Cmax, Hx, D]
                    if uniform:
                        return a[0].reshape(nseq, cmax, *a.shape[2:])
                    z = jnp.zeros((nseq, cmax) + a.shape[2:], a.dtype)
                    return z.at[tseq, toff].set(a[0])

                if no_history:
                    # first chunk for every packed prompt: padding keys
                    # are causally masked, so plain fp flash is exact
                    out = ATT.flash_attention(pad(q), pad(k), pad(v),
                                              causal=True)
                else:
                    out = ops.paged_kv4_prefill_attention(
                        pad(q), pad(k), pad(v),
                        self.cache.k_pool[li], self.cache.k_scale,
                        self.cache.k_zero,
                        self.cache.v_pool[li], self.cache.v_scale,
                        self.cache.v_zero,
                        block_tables, ctx, qlens, impl=self.quant.impl)
                if uniform:
                    a = out.reshape(1, ttot, *out.shape[2:])
                else:
                    a = out[tseq, toff][None]          # repack [1, Ttot, ...]
                a = a.astype(x.dtype).reshape(1, ttot, cfg.q_dim)
                x = x + C.linear(bp["attn"]["wo"], a)
                h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
                if "moe" in bp:
                    y, _ = MLP.moe_apply(bp["moe"], h, cfg)
                else:
                    y = MLP.mlp_apply(bp["mlp"], h, cfg.mlp_act)
                x = x + y
            finished = [(si, r) for si, (r, s, t) in enumerate(plan)
                        if s + t == len(r.prompt)]
            if finished:
                last = [int(cum[si] + takes[si] - 1) for si, _ in finished]
                hN = C.apply_norm(self.params["final_norm"], x[:, last],
                                  cfg.norm, cfg.norm_eps)
                logits = np.asarray(self.lm._head(self.params, hN))

        for r, s, t in plan:
            r.prefill_pos = s + t
            self.cache.seq_len[r.seq_slot] = r.prefill_pos
        for j, (si, r) in enumerate(finished):
            tok = self._sample(logits[0, j], r.request_id, len(r.prompt))
            r.generated.append(tok)
            if not r.first_token_at:    # preserve TTFT across preemptions
                r.first_token_at = time.time()
            self.tokens_generated += 1

    def _attend_paged(self, li: int, q, block_tables, lengths):
        """One kernel call for the whole decode batch — block tables in,
        no per-sequence materialization."""
        cache = self.cache
        return ops.paged_kv4_decode_attention(
            q[:, 0], cache.k_pool[li], cache.k_scale, cache.k_zero,
            cache.v_pool[li], cache.v_scale, cache.v_zero,
            block_tables, lengths, impl=self.quant.impl)

    def _attend_gather(self, li: int, q, slots, max_len, lengths):
        """[Benchmark baseline] per-token O(context) gather, then the
        contiguous KV4 kernel."""
        cache = self.cache
        kp, vp, _ = cache.gather_kv(li, slots, max_len)
        bsz = q.shape[0]
        bcast = lambda s: jnp.broadcast_to(s[None], (bsz, *s.shape))
        return ops.kv4_decode_attention(
            q[:, 0], kp, bcast(cache.k_scale), bcast(cache.k_zero),
            vp, bcast(cache.v_scale), bcast(cache.v_zero),
            lengths, impl=self.quant.impl)

    def _decode_batch(self, reqs: list[Request]):
        cfg = self.cfg
        slots = [r.seq_slot for r in reqs]
        bsz = len(reqs)
        last = jnp.asarray([[r.generated[-1]] for r in reqs], jnp.int32)

        lengths_np = self.cache.seq_len[slots].copy()
        max_len = int(lengths_np.max()) + 1
        paged = self.ecfg.decode_attention == "paged"
        # block tables are fixed for the step (extend_seq already ran);
        # lengths include the token being appended this step. Page
        # destinations for the appends are resolved on the host ONCE and
        # reused by every layer's scatter (was: one block-table lookup +
        # validation per layer — num_layers host syncs per step).
        block_tables = self.cache.block_tables_device(slots, max_len)
        lengths = jnp.asarray(lengths_np + 1, jnp.int32)
        pages, offs = self.cache.token_dests(slots, lengths_np)
        with self.lm._ctx():
            x = self.lm._embed(self.params, last)
            positions = jnp.asarray(lengths_np)[:, None]
            for li in range(cfg.num_layers):
                bp = self._block_params(li)
                h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
                q, k, v = ATT._project_qkv(
                    bp["attn"], cfg, h, h, positions, positions)
                # write the batch's new KV (one scatter), then attend over
                # the pools via block tables — one kernel call per layer
                self.cache.scatter_tokens(li, pages, offs, k, v)
                if paged:
                    out = self._attend_paged(li, q, block_tables, lengths)
                else:
                    out = self._attend_gather(li, q, slots, max_len, lengths)
                out = out.reshape(bsz, 1, cfg.q_dim).astype(x.dtype)
                x = x + C.linear(bp["attn"]["wo"], out)
                h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
                if "moe" in bp:
                    y, _ = MLP.moe_apply(bp["moe"], h, cfg)
                else:
                    y = MLP.mlp_apply(bp["mlp"], h, cfg.mlp_act)
                x = x + y
            hN = C.apply_norm(self.params["final_norm"], x,
                              cfg.norm, cfg.norm_eps)
            logits = np.asarray(self.lm._head(self.params, hN))
        self.cache.advance(slots)
        for bi, r in enumerate(reqs):
            tok = self._sample(logits[bi, -1], r.request_id, r.total_len)
            r.generated.append(tok)
            self.tokens_generated += 1
