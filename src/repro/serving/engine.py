"""COMET serving engine: request-lifecycle API over continuous batching
on the paged KV4 cache.

The engine is the paper's §5 system layer: W4Ax projections + int4 paged
KV + vLLM-style scheduling. Unlike the scanned `LM.decode` (used for the
compile-time dry-run), the engine walks layers in a Python loop so each
layer's attention reads/writes the *paged* pool directly — the realistic
serving dataflow.

**Public surface (the request lifecycle — see serving/api.py).**
``submit(prompt, params) -> RequestHandle`` enqueues a request with
per-request :class:`SamplingParams`; ``step()`` advances every in-flight
request one scheduling quantum and emits :class:`RequestOutput` events
(one per sampled token, plus a terminal event); ``events()`` drains the
engine-wide event queue, ``stream(handle)`` yields one request's events
as they happen (driving ``step()`` internally), and ``submit(...,
on_event=...)`` delivers push-style callbacks. ``abort(handle)`` cancels
at any state — QUEUED, PREFILLING, or DECODING — releasing pages
refcount-exactly. The legacy batch API (``add_request`` + ``run``) is a
thin compatibility wrapper over this lifecycle.

**Prefix caching.** Full prompt pages are published into the cache's
chained-hash prefix index when a request's prefill completes; admission
matches each waiting prompt against the index (`PagedKV4Cache.
match_prefix`), adopts the shared pages refcounted, charges only the
un-cached suffix against the pool, and starts ``prefill_pos`` at the end
of the matched prefix — N requests sharing a system prompt forward its
KV once. Pages with refcount 0 stay cached on a reclaimable LRU and are
evicted before any preemption fires. Counters: ``prefix_hit_tokens``
(prompt tokens served from cache) and ``prefill_tokens`` (prompt tokens
actually forwarded). Enabled by ``EngineConfig.prefix_cache`` (chunked
prefill only; the whole-prompt baseline always recomputes).

**Unified step (the default).** Each step issues exactly ONE forward per
layer: decode tokens (a chunk of 1 with paged int4 history) and prompt
chunks from all partially-prefilled requests are packed into a single
ragged batch — one embed, one W4Ax projection pass per layer, one KV
scatter, one ``paged_kv4_prefill_attention`` call, one MLP, one head
call over the union of finished-prefill rows and decode rows, and one
vectorized sampling call. This is the QServe/Atom dataflow the paper's
throughput rests on: the accelerator sees one dense mixed-precision
stream instead of alternating prefill and decode passes (half the kernel
launches and weight traffic per step).

The unified forward is jitted over **bucketed shapes**: the packed
layout ``(nseq, cmax, ttot)`` plus the attention-schedule dimension
(work-item count under ``attention_schedule="work_queue"``, ``npages``
under ``"dense"``) is rounded up to powers of two, so steady-state
ragged traffic hits the jit cache instead of retracing every
``(nseq, cmax, ttot)`` combination (the dominant cost of the CPU
smoke engine). Padding tokens carry out-of-range scatter destinations
(dropped writes) and zero-length rows (masked in attention), so padding
is semantically inert. ``Engine.trace_count`` counts distinct compiled
forward variants — it plateaus after warmup; ``forward_calls`` proves
the one-forward-per-step invariant.

**Attention schedule** (COMET §4.4's SM-balance pillar, on TPU). The
default ``attention_schedule="work_queue"`` runs paged attention over a
flat Stream-K work pool: the host flattens the batch's REAL pages into
``(row, page, count, kind)`` descriptors (``PagedKV4Cache.
work_queue_np``), the kernel grid walks them uniformly (grid ≈ Σ pages
— a long row's history parallelizes across lanes, short rows pay only
their own pages), and a split-KV log-sum-exp combine merges the partial
flash states. ``"dense"`` keeps the ``(B·Hkv, max_npages)`` rectangle
as the measured baseline. Counters ``attn_work_items`` (real work,
schedule-invariant), ``attn_grid_items`` (launched grid) and
``attn_dense_grid_items`` (the rectangle the dense schedule pays) make
the padding waste measurable — fig10's ragged ablation asserts them.

**Tensor parallelism** (``Engine(..., mesh=..., param_axes=...)`` with a
``(data, model)`` mesh whose model axis > 1). The one-forward-per-step
seam is the ONLY device boundary: ``shard_map`` wraps the unified body,
sharding projection weights column-wise (wq/wk/wv/w_up/w_gate) and
row-wise (wo/w_down) and the int4 KV pools over kv heads, while the
scheduler, prefix index, and page allocator stay host-global — page ids
mean the same thing on every shard, so block tables and Stream-K
work-queue descriptors replicate untouched (each shard walks the same
page stream with its local head slice; per-shard real work is exactly
``attn_work_items / tp``, tracked in ``attn_work_items_per_shard``).
Exactly two all-reduces per layer, at the attention-output and
MLP-down projections (f32 partial sums, rounded to bf16 once after the
psum — greedy decode stays token-identical to single-device). The
embed table and lm head replicate (global token/vocab ids inside the
shard). Everything host-side — admission, preemption, prefix caching,
snapshot/restore — is unchanged and unaware of the mesh.

Prefill is chunked and ragged: the scheduler plans up to
``prefill_chunk_tokens`` prompt tokens per step (budget shared with the
step's decode rows, start round-robined so one long prompt cannot
starve the rest), writes each chunk's quantized KV into the pools, and
attends fp-queries-over-int4-history — a prompt's KV is never resident
in fp beyond one chunk. Admission only needs pages for the next chunk
and preemption can fire mid-prefill.

**Speculative decode** (unified path; ``SamplingParams.speculation=k``).
Decode amortizes the W4Ax weight pass over ONE token per request per
forward — the bottleneck speculation attacks. Each step, a host-side
:class:`~repro.serving.speculation.DraftSource` (default: deterministic
n-gram prompt lookup over the request's prompt + generated history;
pluggable seam for a draft model sharing the page pools) proposes up to
k tokens per speculating decode row. The row then rides the SAME ragged
forward as a qlen-(k+1) chunk — last sampled token + k drafts, int4
paged history, in-flight KV fake-quantized like every decode token —
through the same bucketed jit cache; no new kernel, no second forward.
The head gathers logits at every chunk position of speculating rows
(spec-off steps keep the historical one-logit-per-row layout
bit-for-bit, so their jit cache entries are untouched), and
verification walks them position-by-position: greedy rows accept on
exact argmax match (emitted text is bitwise identical to
speculation-off, just in fewer forwards — the fake-quantize contract
makes a token's in-flight chunk KV equal the int4 page readback its
non-speculative step would see); stochastic rows accept by exact
rejection sampling against the deterministic point-mass proposal (the
output distribution is unchanged). The first rejected position commits
the corrected token; full acceptance commits a bonus token from the
final logits — 1..k+1 tokens per step, emitted in order through the
normal event stream. Unaccepted drafts roll back via the refcount/
prefix-safe ``PagedKV4Cache.truncate_seq`` (pages return to their
pre-draft baseline; the ``sanitize=True`` kv-length-consistency
invariant pins the landing spot every step). Draft tokens debit the
step's ``prefill_chunk_tokens`` budget so spec rows compete fairly with
prefill chunks. Counters: ``spec_draft_tokens`` / ``spec_accepted_tokens``
/ ``spec_rollback_tokens`` (acceptance rate in the serve CLI),
``spec_noop_count`` (drafting suppressed with ≤1 token remaining),
``draft_errors`` (a raising/garbage draft source degrades to plain
decode — drafting is best-effort, never fatal). Fault points ``draft``
and ``verify`` cover the new path; TP sharding is oblivious to it (a
spec row is just another chunk).

**Benchmark baselines** (Fig. 11): ``unified_step=False`` splits the
step back into a ragged prefill forward plus a separate decode forward
(the PR-2 dataflow); ``prefill_mode="whole"`` runs one O(T²) fp forward
per prompt (TTFT baseline); ``decode_attention="gather"`` materializes
each sequence's packed KV per step (the seed's dataflow). All three
imply the split step.

Sequences that hit ``max_pages_per_seq`` finish with
``stop_reason="length_cap"`` (preemption cannot help them — retrying
would livelock); prompts that can never fit the cap fail admission with
``stop_reason="prompt_too_long"``.

Supported families here: dense, moe (the paper's evaluation set —
LLaMA/Qwen/Mistral class + MoE). Hybrid/ssm decode serve through
``LM.decode`` (their state is O(1) — paging buys nothing).

**Fault tolerance.** ``step()`` never propagates a per-request failure:
an exception in the forward (or an ``InjectedFault`` from the
``serving/faults.py`` harness — armed via ``EngineConfig.inject_faults``
or an explicit ``faults=`` injector) quarantines every request in that
step's batch to ``FAILED`` with refcount-exact page release, a sampler
exception or a non-finite logits row quarantines exactly the rows being
sampled, and requests outside the failed batch keep decoding. A
throwing ``on_event`` callback is detached (``callback_errors``), never
fatal. Per-request deadlines (``SamplingParams.deadline_ms`` /
``ttft_ms``) are enforced at every step boundary BEFORE admission —
expired requests land in ``TIMED_OUT`` with partial output retained.
Under pressure the engine degrades instead of stalling: the allocator
drains the reclaimable prefix LRU before any preemption, a bounded
waiting queue (``EngineConfig.max_waiting``) rejects at submit
(``FAILED("queue_full")``, the handle returns already terminal), and a
preemption victim that cannot re-queue is shed (``FAILED("shed")``).
Counters: ``failed_count``, ``timeout_count``, ``shed_count``,
``rejected_count``, ``internal_errors``, ``callback_errors``. An
unexpected exception anywhere else in the step is swallowed into
``internal_errors``/``last_error`` — the serving loop survives
everything. These guards cover the unified step (the default); the
split/whole/gather fig11 baselines rely on the outer backstop only.

Crash recovery: ``snapshot()`` captures scheduler state; ``Engine.
restore`` rebuilds mid-flight work after a crash (prompts re-prefill
from ``prefill_pos=0`` — partial prefill is device KV, lost with the
node). Sampling is keyed by (request_id, position), but regenerated text
is not bit-identical in general: re-prefill attends in fp while decode
attends over the int4 pages, so greedy argmax can flip on near-ties.
``snapshot(full=True)`` instead captures EVERYTHING — int4 pool bytes,
allocator free-list/LRU order, the exact waiting/running split and
cursors — so ``restore`` of a full blob resumes the very next step
bit-identically (nothing re-prefills); ``serving/recovery.py`` pairs it
with a per-token event journal for exactly-once redelivery and a
bitwise replay check. Availability above one engine lives in
``serving/replication.py``: a :class:`ReplicaGroup` runs N engines on
the data axis, health-checks each step, ships the RecoveryLog
artifacts after every healthy step, and fails over (standby promotion
or exactly-once request migration) from the shipped view.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import qlinear as QL
from repro.core.qlinear import BLOCK_K
from repro.kernels import ops
from repro.layers import attention as ATT
from repro.layers import common as C
from repro.layers import mlp as MLP
from repro.models.lm import LM, QuantConfig
from repro.parallel import sharding as SH
from repro.serving import kv_cache as KVC
from repro.serving.api import (RequestHandle, RequestOutput, RequestState,
                               SamplingParams)
from repro.serving.faults import FaultInjector, InjectedFault
from repro.serving.jit_args import argnums_of
from repro.serving.kv_cache import PagedKV4Cache, PagedKV4Config
from repro.serving.sanitize import check_engine
from repro.serving.scheduler import Request, Scheduler
from repro.serving.speculation import DraftSource, PromptLookupDraft

__all__ = ["Engine", "EngineConfig", "SamplingParams", "RequestState",
           "RequestOutput", "RequestHandle"]


def _bucket(n: int, lo: int = 1) -> int:
    """Round ``n`` up to a power of two (≥ lo) — the jit-cache shape key."""
    return max(lo, 1 << max(int(n) - 1, 0).bit_length())


def _spec_probs(row: np.ndarray, temp: float, top_k: int) -> np.ndarray:
    """Top-k/temperature sampling distribution for one logits row
    (float64 host softmax — the speculative verifier's reference
    measure)."""
    lg = np.asarray(row, np.float64) / max(temp, 1e-8)
    if top_k < lg.shape[0]:
        kth = np.partition(lg, -top_k)[-top_k]
        lg = np.where(lg >= kth, lg, -np.inf)
    lg = lg - lg.max()
    p = np.exp(lg)
    return p / p.sum()


def _reject_sample(row: np.ndarray, temp: float, top_k: int,
                   drafted: Optional[int], rid: int, pos: int):
    """Exact rejection sampling against a DETERMINISTIC draft proposal.

    The prompt-lookup draft is a point mass q = δ(drafted), so the
    textbook accept probability min(1, p/q) collapses to p(drafted) and
    the residual distribution to p restricted to x ≠ drafted,
    renormalized — together they reproduce p exactly, which is the
    speculative-sampling guarantee. ``drafted=None`` (the bonus
    position after full acceptance) is a plain draw from p. Seeded by
    (request_id, position) like the batched sampler, so reruns replay.
    Returns (token, accepted)."""
    p = _spec_probs(row, temp, top_k)
    rng = np.random.default_rng((int(rid) & 0x7FFFFFFF, int(pos), 0x5BEC))
    if drafted is not None:
        if rng.random() < p[drafted]:
            return int(drafted), True
        residual = p.copy()
        residual[drafted] = 0.0
        mass = residual.sum()
        if mass <= 0.0:
            # p WAS the point mass at the draft — the residual is empty
            # and the only exact outcome is the drafted token
            return int(drafted), True
        p = residual / mass
    return int(rng.choice(p.shape[0], p=p)), False


def _pad_to(a: np.ndarray, n: int, fill=0) -> np.ndarray:
    out = np.full((n,), fill, np.int32)
    out[: len(a)] = a
    return out


def _row_linear(p, x, tp_axis):
    """Row-parallel (K-sharded) projection seam: each shard holds a
    K-slice of the weight, so its output is a partial sum that must
    all-reduce over ``tp_axis``. With ``tp_axis=None`` this is exactly
    ``C.linear`` (the single-device path stays bit-identical).

    Numerics under TP: the act-quant must see the input in the SAME
    dtype as the single-device path (``absmax_scale`` divides in the
    input dtype before its f32 cast, so a bf16-valued f32 input still
    shifts the scale's last bit and flips int4 codes on rounding ties).
    So the handler gets the bf16 input unchanged and only the GEMM
    *output* is kept f32 (``out_dtype``) for the psum — rounding to
    bf16 once, after the cross-shard sum. psum over bf16-rounded
    partials would instead inject ~0.4% logit noise and flip greedy
    argmax on near-ties."""
    if tp_axis is None:
        return C.linear(p, x)
    xb = x.astype(jnp.bfloat16)
    pl = {k: v for k, v in p.items() if k != "b"}
    if "w_packed" in pl:
        y = QL._dispatch_qlinear(pl, xb, out_dtype=jnp.float32)
    else:
        y = C.linear(pl, xb.astype(jnp.float32),
                     compute_dtype=jnp.float32)
    y = jax.lax.psum(y, tp_axis)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(jnp.bfloat16)


def _mlp_row(p, x, act: str, tp_axis):
    """Dense MLP with the down-projection as the TP all-reduce seam:
    up/gate are column-sharded (bit-identical per-channel math), the
    silu·up product stays local, and only w_down's K-sharded partial
    sums cross shards. ``tp_axis=None`` delegates to ``MLP.mlp_apply``
    unchanged."""
    if tp_axis is None:
        return MLP.mlp_apply(p, x, act)
    up = C.linear(p["w_up"], x)
    if act == "swiglu":
        h = jax.nn.silu(C.linear(p["w_gate"], x)) * up
    elif act == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(act)
    return _row_linear(p["w_down"], h, tp_axis)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 32
    num_pages: int = 512
    page_size: int = 64
    max_pages_per_seq: int = 64
    temperature: float = 0.0        # 0 → greedy
    top_k: int = 40
    decode_attention: str = "paged"  # "paged" (gather-free) | "gather"
    prefill_mode: str = "chunked"    # "chunked" (ragged) | "whole" (baseline)
    prefill_chunk_tokens: int = 64   # ragged-prefill token budget per step
    kv_range: float = 16.0           # calibrated |k|,|v| range → int4 scales
    unified_step: bool = True        # ONE forward/step (decode ∪ prefill);
    #                                  False → split-step fig11 baseline
    prefix_cache: bool = True        # publish/reuse shared prompt pages
    #                                  (refcounted; chunked prefill only)
    attention_schedule: str = "work_queue"  # "work_queue" (Stream-K flat
    #                                  descriptors + split-KV combine) |
    #                                  "dense" ((B·Hkv, max_npages) grid —
    #                                  the measured fig10 baseline)
    prefix_cache_max_bytes: Optional[int] = None  # byte cap on the
    #                                  reclaimable prefix-page LRU
    max_waiting: Optional[int] = None  # bound on the waiting queue —
    #                                  submits past it are rejected
    #                                  (FAILED "queue_full") and preempt
    #                                  victims are shed, not re-queued
    inject_faults: Optional[str] = None  # fault schedule spec
    #                                  (serving/faults.py grammar), e.g.
    #                                  "forward:step=3,action=nan"
    sanitize: bool = False          # re-derive the core invariants
    #                                  (page-refcount conservation,
    #                                  exactly-one-terminal, no-token-
    #                                  after-terminal) after EVERY step
    #                                  and raise SanitizerError on the
    #                                  first violation — the runtime
    #                                  half of repro.analysis.cometlint
    #                                  (serving/sanitize.py)

    def __post_init__(self):
        if self.max_waiting is not None and self.max_waiting < 1:
            raise ValueError("max_waiting must be >= 1 (None = unbounded)")
        if self.decode_attention not in ("paged", "gather"):
            raise ValueError(
                f"decode_attention must be 'paged' or 'gather', got "
                f"{self.decode_attention!r}")
        if self.prefill_mode not in ("chunked", "whole"):
            raise ValueError(
                f"prefill_mode must be 'chunked' or 'whole', got "
                f"{self.prefill_mode!r}")
        if self.prefill_chunk_tokens < 1:
            raise ValueError("prefill_chunk_tokens must be >= 1")
        if self.attention_schedule not in ("work_queue", "dense"):
            raise ValueError(
                f"attention_schedule must be 'work_queue' or 'dense', "
                f"got {self.attention_schedule!r}")

    @property
    def unified(self) -> bool:
        """Unified step needs the chunked ragged path and the paged
        kernel; the whole-prompt / gather baselines imply a split step."""
        return (self.unified_step and self.prefill_mode == "chunked"
                and self.decode_attention == "paged")

    @property
    def prefix_caching(self) -> bool:
        """Prefix reuse rides on ``prefill_pos`` chunk streaming — the
        whole-prompt baseline always forwards the full prompt."""
        return self.prefix_cache and self.prefill_mode == "chunked"


class Engine:
    # declared jit intent (rule R2): which _unified_forward parameters
    # are static (shape-bucket keys traced per value) and which are
    # donated (pool buffers updated in place). Indices are derived from
    # these NAMES at construction via jit_args.argnums_of, so adding or
    # reordering a forward parameter re-resolves correctly and renaming
    # one fails loudly instead of staticizing/donating the wrong arg.
    _FWD_STATIC_ARGS = ("cmax", "no_history", "schedule")
    _FWD_DONATE_ARGS = ("k_pool", "v_pool")

    # rule R1 (snapshot-completeness) allowlist: __init__ attrs that are
    # deliberately NOT in the full-snapshot blob — rebuilt by the
    # constructor (model/params/jit caches/sharding layouts) or
    # process-lifetime observability counters a restored incarnation
    # starts from zero (the serve CLI reports them per process).
    _SNAPSHOT_EXEMPT = frozenset({
        # rebuilt by __init__ / only meaningful in-process
        "lm", "params", "donate_pools", "_fwd", "_fwd_shapes",
        "_sample_fns", "_gather_bcast", "_param_pspecs", "_scale_pspec",
        "_events", "draft_source",
        # per-process observability counters
        "peak_prefill_fp_tokens", "interleaved_steps", "forward_calls",
        "trace_count", "prefix_hit_tokens", "prefill_tokens",
        "aborted_count", "failed_count", "timeout_count", "shed_count",
        "rejected_count", "callback_errors", "internal_errors",
        "last_error", "sanitize_checks", "attn_work_items",
        "attn_grid_items", "attn_dense_grid_items", "attn_forwards",
        "attn_work_items_per_shard", "spec_draft_tokens",
        "spec_accepted_tokens", "spec_rollback_tokens", "spec_noop_count",
        "draft_errors",
    })

    def __init__(self, cfg: ModelConfig, qparams, quant: QuantConfig,
                 ecfg: EngineConfig = EngineConfig(), *,
                 mesh=None, param_axes=None, faults=None, clock=time.time,
                 draft_source: Optional[DraftSource] = None):
        """``mesh``/``param_axes`` (both optional) turn on tensor-parallel
        sharded serving: a ``(data, model)`` mesh whose "model" axis > 1
        shards projection weights and the int4 KV pools over kv heads
        (``shard_map`` around the unified forward; see
        :meth:`_unified_forward`). ``param_axes`` is the logical-axes
        tree ``LM.quantize`` returns alongside ``qparams`` — required
        whenever the model axis is sharded. A mesh with model == 1 (or
        ``mesh=None``) is the single-device engine, unchanged.

        ``faults``: a :class:`FaultInjector` to ride along (chaos tests
        hand one in directly; ``ecfg.inject_faults`` builds one from the
        CLI spec grammar). ``clock``: the wall-clock source for arrival
        stamps and deadline enforcement — injectable so deadline tests
        are deterministic. ``draft_source``: the speculative-decode
        proposal oracle (serving/speculation.py) — defaults to n-gram
        :class:`PromptLookupDraft`; only consulted for requests with
        ``SamplingParams.speculation > 0``."""
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"paged engine supports dense/moe; {cfg.family} serves via "
                "LM.decode")
        self.cfg = cfg
        self.quant = quant
        self.lm = LM(cfg, quant=quant)
        self.params = qparams
        self.ecfg = ecfg
        self.mesh = mesh
        self.tp_size = (int(mesh.shape["model"])
                        if mesh is not None and "model" in mesh.axis_names
                        else 1)
        self.cache = PagedKV4Cache(
            cfg,
            PagedKV4Config(
                num_pages=ecfg.num_pages, page_size=ecfg.page_size,
                max_seqs=ecfg.max_batch * 2,
                max_pages_per_seq=ecfg.max_pages_per_seq,
                reclaimable_max_bytes=ecfg.prefix_cache_max_bytes),
            num_layer_slots=cfg.num_layers,
            kv_range=ecfg.kv_range)
        self.sched = Scheduler(ecfg.max_batch, ecfg.max_batch * 2,
                               max_waiting=ecfg.max_waiting)
        self.clock = clock
        # fault-injection harness (serving/faults.py): shared with the
        # cache so alloc_page/append_kv fire at their real call sites
        if faults is None:
            faults = (FaultInjector.from_spec(ecfg.inject_faults)
                      if ecfg.inject_faults else FaultInjector())
        self.faults = faults
        self.cache.faults = faults
        self.steps = 0
        self.tokens_generated = 0
        # observability: largest fp-token prefill forward issued (bounded
        # by prefill_chunk_tokens in chunked mode), steps that ran
        # prefill and decode work back-to-back (interleave evidence for
        # fig11), model forwards issued (unified: exactly one per step
        # with work), and distinct compiled forward variants (unified:
        # real jit traces, counted inside the traced body; split: one per
        # new packed-shape signature — the eager dispatch cache key)
        self.peak_prefill_fp_tokens = 0
        self.interleaved_steps = 0
        self.forward_calls = 0
        self.trace_count = 0
        # prefix-cache + lifecycle counters: prompt tokens served from
        # published pages vs actually forwarded, and aborted requests
        self.prefix_hit_tokens = 0
        self.prefill_tokens = 0
        self.aborted_count = 0
        # robustness counters: step-level quarantines, deadline/TTFT
        # expiries, load-shed preemption victims, bounded-queue submit
        # rejections, throwing on_event callbacks (detached, not fatal),
        # and the last-resort backstop for unexpected step exceptions
        self.failed_count = 0
        self.timeout_count = 0
        self.shed_count = 0
        self.rejected_count = 0
        self.callback_errors = 0
        self.internal_errors = 0
        self.last_error: Optional[str] = None
        # step boundaries that passed the runtime sanitizer (ecfg.sanitize)
        self.sanitize_checks = 0
        # speculative decode: the pluggable host-side draft oracle and
        # its acceptance accounting — drafted/accepted give the
        # acceptance rate, rollback counts the int4 KV retracted by
        # truncate_seq, spec_noop_count the drafts suppressed because
        # ≤1 token remained, draft_errors the raising/garbage draft
        # calls degraded to plain decode
        self.draft_source = (draft_source if draft_source is not None
                             else PromptLookupDraft())
        self.spec_draft_tokens = 0
        self.spec_accepted_tokens = 0
        self.spec_rollback_tokens = 0
        self.spec_noop_count = 0
        self.draft_errors = 0
        # attention-schedule counters (fig10 measured ablation): real
        # work items (Σ real pages + chunk items, per kv head — equal
        # under both schedules), grid items actually launched (dense:
        # the padded (B·Hkv)·(npages+1) rectangle; work_queue: the
        # pow-2-bucketed flat count), the dense-equivalent grid for the
        # same forwards, and how many attention forwards contributed
        self.attn_work_items = 0
        self.attn_grid_items = 0
        self.attn_dense_grid_items = 0
        self.attn_forwards = 0
        # per-shard real work: each model shard attends its local kv
        # heads over the SAME per-sequence page stream, so the split is
        # exact — equal entries here are the load-balance evidence the
        # fig11 sharded part asserts (single device: one entry, equal
        # to attn_work_items)
        self.attn_work_items_per_shard = [0] * self.tp_size
        self._fwd_shapes: set = set()
        self._gather_bcast: dict = {}      # bsz → broadcast scales/zeros
        # donate the pool buffers so the traced KV scatter updates them
        # in place instead of copying ~num_pages of int4 every step; CPU
        # has no buffer donation (XLA warns and copies), so gate it to
        # the accelerator backends where it is honored
        self.donate_pools = jax.default_backend() in ("tpu", "gpu")
        self._param_pspecs = None
        self._pool_pspec = None
        self._scale_pspec = None
        if self.tp_size > 1:
            self._init_sharding(param_axes)
        self._fwd = jax.jit(
            self._unified_forward,
            static_argnums=argnums_of(self._unified_forward,
                                      *self._FWD_STATIC_ARGS),
            donate_argnums=(argnums_of(self._unified_forward,
                                       *self._FWD_DONATE_ARGS)
                            if self.donate_pools else ()))
        self._sample_fns: dict = {}        # kmax → jitted batched sampler
        self._by_id: dict[int, Request] = {}
        self._next_id = 0
        # monotonically increasing submission counter: request_ids are
        # REUSABLE after release(), so anything that must key per-request
        # state durably (the recovery journal, replica migration) keys by
        # Request.uid — the incarnation-qualified id — instead
        self._submit_seq = 0
        self._events: list[RequestOutput] = []

    # --------------------------------------------------- tensor parallelism

    def _init_sharding(self, param_axes):
        """Lay params + pools out over the mesh for TP-sharded serving.

        Weights shard by SERVE_RULES (column-parallel wq/wk/wv/w_up/
        w_gate over their N dims, row-parallel wo/w_down over their K
        dims); the embed table and lm head are overridden to REPLICATED
        — inside ``shard_map`` the token gather and the vocab matmul use
        global ids, so a vocab-sharded table would read garbage. The
        int4 KV pools shard over kv heads via ``cache_pspecs`` (pages
        stay a host-global namespace). Divisibility is validated up
        front rather than silently falling back to replication, because
        a PARTIALLY sharded projection (w_packed sharded, w_scale
        replicated) is shape-inconsistent inside the W4Ax matmul."""
        cfg, m, mesh = self.cfg, self.tp_size, self.mesh
        if param_axes is None:
            raise ValueError(
                "TP-sharded serving needs param_axes — the axes tree "
                "LM.quantize returns alongside qparams")
        if not self.ecfg.unified:
            raise ValueError(
                "TP-sharded serving runs through the unified one-forward "
                "step; the split/whole/gather baselines are single-device")
        if cfg.family != "dense":
            raise NotImplementedError(
                "TP-sharded serving covers dense models; MoE needs expert-"
                "parallel dispatch at this seam")
        if cfg.num_heads % m or cfg.num_kv_heads % m:
            raise ValueError(
                f"num_heads={cfg.num_heads}, num_kv_heads="
                f"{cfg.num_kv_heads} must both divide by the model axis "
                f"size {m}")
        if cfg.q_dim % (BLOCK_K * m) or cfg.d_ff % (BLOCK_K * m):
            raise ValueError(
                f"row-parallel W4Ax shards must hold whole {BLOCK_K}-"
                f"channel quant blocks: q_dim={cfg.q_dim} and d_ff="
                f"{cfg.d_ff} must divide by {BLOCK_K}*model={BLOCK_K * m}")
        specs = SH.tree_pspecs(param_axes, self.params, mesh,
                               SH.SERVE_RULES)
        for name in ("embed", "lm_head"):
            if name in specs:
                specs[name] = jax.tree.map(
                    lambda s, p: P(*([None] * p.ndim)),
                    specs[name], self.params[name],
                    is_leaf=lambda x: isinstance(x, P))
        self._param_pspecs = specs

        def put(a, s):
            return jax.device_put(a, NamedSharding(mesh, s))

        self.params = jax.tree.map(put, self.params, specs,
                                   is_leaf=lambda x: isinstance(x, P))
        cache = self.cache
        cspecs = SH.cache_pspecs(
            {"k_pool": cache.k_pool, "v_pool": cache.v_pool,
             "k_scale": cache.k_scale, "k_zero": cache.k_zero,
             "v_scale": cache.v_scale, "v_zero": cache.v_zero}, mesh)
        self._pool_pspec = cspecs["k_pool"]
        self._scale_pspec = cspecs["k_scale"]
        cache.k_pool = put(cache.k_pool, cspecs["k_pool"])
        cache.v_pool = put(cache.v_pool, cspecs["v_pool"])
        cache.k_scale = put(cache.k_scale, cspecs["k_scale"])
        cache.k_zero = put(cache.k_zero, cspecs["k_zero"])
        cache.v_scale = put(cache.v_scale, cspecs["v_scale"])
        cache.v_zero = put(cache.v_zero, cspecs["v_zero"])

    # ----------------------------------------------------- lifecycle API

    def submit(self, prompt: list[int],
               params: Optional[SamplingParams] = None,
               request_id: Optional[int] = None,
               on_event=None) -> RequestHandle:
        """Enqueue a request (state QUEUED) and return its handle.

        ``params`` defaults to the engine-wide sampling configuration;
        ``on_event`` is an optional push callback invoked with every
        :class:`RequestOutput` the request emits.

        Backpressure: with ``EngineConfig.max_waiting`` set and the
        waiting queue full, the request is rejected — the returned
        handle resolves to a request already terminal in ``FAILED``
        with ``stop_reason="queue_full"`` (terminal event emitted, no
        pages or slots ever held)."""
        if params is None:
            params = SamplingParams(temperature=self.ecfg.temperature,
                                    top_k=self.ecfg.top_k)
        if params.speculation + 1 > self.ecfg.prefill_chunk_tokens:
            # the verify chunk is k drafts + the last sampled token; a k
            # that cannot fit the per-step token budget could never ride
            # one forward — reject at submit, not silently mid-step
            raise ValueError(
                f"speculation={params.speculation} exceeds the per-step "
                f"token budget: the k+1-token verify chunk must fit "
                f"prefill_chunk_tokens={self.ecfg.prefill_chunk_tokens}")
        if params.speculation > 0 and params.max_new_tokens == 1:
            # a single-token request never decodes (its one token comes
            # off the prefill's logits), so speculation can never engage
            # — a silent no-op worth counting, not an error
            self.spec_noop_count += 1
        if request_id is None:
            while self._next_id in self._by_id:
                self._next_id += 1
            request_id = self._next_id
        old = self._by_id.get(request_id)
        if old is not None and not old.state.terminal:
            raise ValueError(f"request_id {request_id} already in flight")
        req = Request(
            request_id=request_id, prompt=list(prompt),
            max_new_tokens=params.max_new_tokens, arrived_at=self.clock(),
            params=params, on_event=on_event, uid=self._submit_seq)
        self._submit_seq += 1
        self._by_id[request_id] = req
        if self.sched.waiting_full:
            self.sched.reject(req)
            self.rejected_count += 1
            self._emit(req)
        else:
            self.sched.submit(req)
        return RequestHandle(request_id=request_id, prompt_len=len(prompt))

    def _resolve(self, handle) -> Optional[Request]:
        rid = handle.request_id if isinstance(handle, RequestHandle) \
            else int(handle)
        return self._by_id.get(rid)

    def abort(self, handle) -> bool:
        """Cancel a request at ANY lifecycle state. Pages are released
        refcount-exactly (``cache.pages_free`` returns to its
        pre-submit baseline; shared prefix pages stay cached for their
        other owners). Emits a terminal ABORTED event. Returns False if
        the request is unknown or already terminal."""
        req = self._resolve(handle)
        if req is None or not self.sched.abort(req, self.cache):
            return False
        self.aborted_count += 1
        self._emit(req)
        return True

    def events(self) -> list[RequestOutput]:
        """Drain the engine-wide event queue fed by ``step()``: one
        event per sampled token (in order) plus a terminal event per
        finished/aborted request. A long-running server must drain this
        (or consume via ``stream``/``on_event`` and ignore it) — the
        queue is unbounded by design so the batch ``run()`` wrapper
        loses nothing. Terminal request state itself is retained for
        the engine's lifetime (same policy as ``sched.finished``)."""
        evs, self._events = self._events, []
        return evs

    def stream(self, handle):
        """Yield one request's :class:`RequestOutput` events as they
        happen, driving ``step()`` while the request is in flight (other
        requests keep batching through the same steps). Terminates after
        the request's terminal event."""
        req = self._resolve(handle)
        if req is None:
            return
        cursor = 0
        while True:
            while cursor < len(req.events):
                yield req.events[cursor]
                cursor += 1
            if req.state.terminal or not self.sched.has_work:
                return
            self.step()

    def result(self, handle) -> Optional[Request]:
        """The request's current state (its final state once terminal)."""
        return self._resolve(handle)

    def release(self, handle) -> bool:
        """Drop a TERMINAL request's retained state — its entry in the
        id map, its slot in ``sched.finished``, and its event log — so
        a long-running server's memory scales with in-flight work, not
        lifetime traffic. Call after consuming ``result``/``stream``;
        the request_id becomes immediately reusable. Returns False for
        unknown or still-in-flight requests (aborting first is the way
        to drop those)."""
        req = self._resolve(handle)
        if req is None or not req.state.terminal:
            return False
        self.sched.release(req)
        self._by_id.pop(req.request_id, None)
        req.events.clear()
        req.on_event = None
        return True

    # ----------------------------------------------------- batch-compat API

    def add_request(self, request_id: int, prompt: list[int],
                    max_new_tokens: int):
        """[Compat] the pre-lifecycle batch API: submit with engine-wide
        sampling defaults and an explicit id."""
        self.submit(prompt,
                    SamplingParams(max_new_tokens=max_new_tokens,
                                   temperature=self.ecfg.temperature,
                                   top_k=self.ecfg.top_k),
                    request_id=request_id)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """[Compat] drive ``step()`` until all work drains; the offline
        batch wrapper over the streaming lifecycle."""
        while self.sched.has_work and self.steps < max_steps:
            self.step()
        return self.sched.finished

    def snapshot(self, full: bool = False) -> str:
        """Serialize engine state for crash recovery.

        Legacy mode (default): scheduler-only — running work demotes to
        waiting and re-prefills on restore (device KV lost with the
        node); continuation is plausible but not bit-identical.

        ``full=True``: the journaled-recovery blob — the exact
        scheduler split/cursors (``Scheduler.snapshot(full=True)``) plus
        the entire cache (``PagedKV4Cache.snapshot_state``: int4 pool
        bytes, block tables, free-list and prefix-LRU order). A restore
        resumes the very next step bit-identically."""
        if full:
            return json.dumps({
                "format": "engine_full",
                "sched": self.sched.snapshot(full=True),
                "cache": self.cache.snapshot_state(),
                "steps": self.steps,
                "tokens_generated": self.tokens_generated,
                "next_id": self._next_id,
                "submit_seq": self._submit_seq,
            })
        return self.sched.snapshot()

    @classmethod
    def restore(cls, blob: str, cfg, qparams, quant,
                ecfg: EngineConfig = EngineConfig(), *,
                mesh=None, param_axes=None, faults=None,
                clock=time.time) -> "Engine":
        eng = cls(cfg, qparams, quant, ecfg, mesh=mesh,
                  param_axes=param_axes, faults=faults, clock=clock)
        state = json.loads(blob)
        if isinstance(state, dict) and state.get("format") == "engine_full":
            eng.sched = Scheduler.restore(
                state["sched"], ecfg.max_batch, ecfg.max_batch * 2,
                max_waiting=ecfg.max_waiting)
            eng.cache.restore_state(state["cache"])
            if eng.tp_size > 1:
                # restore_state loads host pools; re-lay them out over
                # the mesh (kv-head sharding) for the sharded forward
                put = lambda a: jax.device_put(
                    a, NamedSharding(eng.mesh, eng._pool_pspec))
                eng.cache.k_pool = put(eng.cache.k_pool)
                eng.cache.v_pool = put(eng.cache.v_pool)
            eng.steps = state["steps"]
            eng.tokens_generated = state["tokens_generated"]
            eng._next_id = state["next_id"]
            eng._by_id = {r.request_id: r for r in
                          list(eng.sched.waiting) + eng.sched.running
                          + eng.sched.finished}
            eng._restore_uids(state.get("submit_seq"))
            return eng
        eng.sched = Scheduler.restore(blob, ecfg.max_batch,
                                      ecfg.max_batch * 2,
                                      max_waiting=ecfg.max_waiting)
        eng._by_id = {r.request_id: r for r in
                      list(eng.sched.waiting) + eng.sched.finished}
        eng._restore_uids(None)
        return eng

    def _restore_uids(self, submit_seq):
        """Re-establish the incarnation counter after a restore: blobs
        from before uid tracking (or the legacy scheduler snapshot) carry
        requests with ``uid == -1`` — assign them fresh uids so the
        recovery journal's ``(uid, ord)`` keys stay collision-free."""
        reqs = (list(self.sched.waiting) + self.sched.running
                + self.sched.finished)
        top = max((r.uid for r in reqs), default=-1) + 1
        self._submit_seq = max(top, submit_seq or 0)
        for r in reqs:
            if r.uid < 0:
                r.uid = self._submit_seq
                self._submit_seq += 1

    # ----------------------------------------------------------- events

    def _emit(self, req: Request, token: Optional[int] = None):
        """Single event choke point. A terminal event (``token is
        None``) is emitted at most once per request — the exactly-one-
        terminal contract holds even when several failure paths race to
        finish the same request in one step. ``on_event`` delivery is
        guarded: a throwing callback (or an injected ``emit_event``
        fault) is detached and counted, never fatal, and the event log
        keeps the event either way."""
        if token is None:
            if req.terminal_emitted:
                return
            req.terminal_emitted = True
            if not req.finished_at:     # TPOT window end (serve CLI SLOs)
                req.finished_at = self.clock()
        out = RequestOutput(
            request_id=req.request_id, state=req.state, token=token,
            num_generated=len(req.generated), stop_reason=req.stop_reason,
            finished=req.state.terminal)
        self._events.append(out)
        req.events.append(out)
        if req.on_event is not None:
            try:
                if self.faults.check("emit_event"):
                    raise InjectedFault(
                        "emit_event: injected callback failure")
                req.on_event(out)
            except Exception:  # noqa: BLE001 — user-callback boundary:
                # client code may raise anything; detach + count it so
                # one bad callback can't poison the serving loop
                self.callback_errors += 1
                req.on_event = None

    def _record_token(self, req: Request, tok: int):
        """Single choke point for a sampled token: append, stamp TTFT,
        flip PREFILLING→DECODING, and emit the streaming event.
        ``emitted`` is the request's LIFETIME token-event count (unlike
        ``len(generated)``, it survives the preemption fold) — the
        journal's per-request delivery cursor."""
        if req.state.terminal:
            # reentrant abort: an on_event callback cancelled this
            # request earlier in the same step's sampling loop — its
            # terminal event must stay last, so drop the token
            return
        req.generated.append(int(tok))
        req.emitted += 1
        if not req.first_token_at:      # preserve TTFT across preemptions
            req.first_token_at = self.clock()
        if req.state == RequestState.PREFILLING:
            req.state = RequestState.DECODING
        self.tokens_generated += 1
        self._emit(req, token=int(tok))

    def _complete(self, req: Request):
        self.sched.complete(req, self.cache)
        self._emit(req)

    def _fail(self, req: Request, reason: str):
        """Quarantine one request after a step-level failure: pages
        released refcount-exactly, terminal FAILED event, counted."""
        if self.sched.fail(req, self.cache, reason):
            self.failed_count += 1
            self._emit(req)

    def _preempt_one(self) -> Optional[Request]:
        """Preempt the youngest runnable sequence; when the bounded
        waiting queue is full the scheduler sheds the victim instead of
        re-queueing it — count it and emit its terminal event here."""
        victim = self.sched.preempt_one(self.cache)
        if victim is not None and victim.state.terminal:
            self.shed_count += 1
            self._emit(victim)
        return victim

    # ----------------------------------------------------------------- step

    def step(self):
        """Advance every in-flight request one scheduling quantum.

        NEVER raises: per-request failures are quarantined inside
        (``_forward_step``'s guards), and anything unexpected that still
        escapes is swallowed into ``internal_errors``/``last_error`` —
        one poisoned step must not take down the serving loop.

        Exception: ``ecfg.sanitize`` runs the step-boundary invariant
        checks (serving/sanitize.py) OUTSIDE the backstop — a
        ``SanitizerError`` means engine state is already corrupt, and
        the whole point is to stop before serving wrong answers."""
        self.steps += 1
        self.faults.begin_step(self.steps)
        try:
            self._step_inner()
        except Exception as e:  # noqa: BLE001 — the serving-loop backstop
            self.internal_errors += 1
            self.last_error = repr(e)
        if self.ecfg.sanitize:
            check_engine(self)
            self.sanitize_checks += 1

    def _step_inner(self):
        # deadline/TTFT expiry runs BEFORE admission: a dead-on-arrival
        # request must never acquire pages just to be torn down
        for req in self.sched.expire_deadlines(self.cache, self.clock()):
            self.timeout_count += 1
            self._emit(req)
        chunked = self.ecfg.prefill_mode == "chunked"
        nfin = len(self.sched.finished)
        admitted = self.sched.admit(
            self.cache,
            first_chunk_tokens=(self.ecfg.prefill_chunk_tokens if chunked
                                else None),
            prefix_cache=self.ecfg.prefix_caching)
        # admission-time rejections (prompt_too_long) reach finished
        # without passing through _complete — they still owe their
        # terminal event
        for r in self.sched.finished[nfin:]:
            self._emit(r)
        self.prefix_hit_tokens += sum(r.cached_tokens for r in admitted)
        # chunk rows and decode rows share one token budget: the decode
        # batch debits the prefill plan so the forward stays bounded by
        # ~prefill_chunk_tokens (min 1 keeps long prompts progressing)
        n_decode_est = sum(1 for r in self.sched.running
                           if r.prefilled and not r.done)
        budget = max(1, self.ecfg.prefill_chunk_tokens - n_decode_est)
        if self.ecfg.unified:
            self._step_unified(budget)
        else:
            self._step_split(admitted, chunked, budget)
        for req in list(self.sched.running):
            if req.done:
                self._complete(req)

    def _step_unified(self, budget: int):
        """ONE forward for the union of decode rows and prompt chunks.

        Decode slots are reserved *before* the prefill plan: reservation
        can preempt a mid-prefill victim, which would invalidate a plan
        built earlier. Speculative drafts are planned between the two —
        they need the reserved slots to size their verify chunks, and
        their token count debits the prefill budget so spec rows compete
        fairly with prompt chunks for the step's forward."""
        decode = self._reserve_decode_slots(
            [r for r in self.sched.running if r.prefilled and not r.done])
        drafts = self._plan_speculation(decode, budget)
        budget = max(1, budget - sum(len(d) for d in drafts))
        plan = self.sched.plan_prefill(self.cache, budget)
        if not plan and not decode:
            # no forward possible: if prompts are stuck with nothing
            # decodable, free pages so the next step can move
            stuck = [r for r in self.sched.running if not r.prefilled]
            if stuck and not any(r.prefilled for r in self.sched.running):
                self._preempt_one()
            return
        if plan and decode:
            self.interleaved_steps += 1
        self._forward_step(plan, list(zip(decode, drafts)))

    def _plan_speculation(self, decode: list[Request],
                          budget: int) -> list[list[int]]:
        """Plan one draft per decode row (aligned list; ``[]`` = plain
        one-token decode). Pure host work: consult the draft source,
        clamp k to the tokens the request can still commit and to the
        step budget (one token is held back for prefill progress while
        any prompt is mid-stream), validate the proposal, and grow the
        row's page capacity to cover its k+1-token verify chunk —
        trimming the draft instead of preempting anyone when the pool
        is short (drafts are opportunistic; they must never evict real
        work). A raising draft source — or an injected ``draft`` fault
        — degrades to no-draft and counts ``draft_errors``."""
        drafts: list[list[int]] = [[] for _ in decode]
        if not any(r.params is not None and r.params.speculation > 0
                   for r in decode):
            return drafts
        avail = budget - 1 if any(not r.prefilled
                                  for r in self.sched.running) else budget
        for i, r in enumerate(decode):
            k = r.params.speculation if r.params is not None else 0
            if k <= 0:
                continue
            remaining = r.max_new_tokens - len(r.generated)
            if remaining <= 1:
                # at most one token left to commit: a draft would be
                # guaranteed rollback, so speculation no-ops
                self.spec_noop_count += 1
                continue
            k = min(k, remaining - 1, avail)
            if k <= 0:
                continue
            try:
                fault = self.faults.check("draft")
                if fault is not None and fault.action == "raise":
                    raise InjectedFault("draft: injected draft failure")
                d = ([] if fault is not None
                     else list(self.draft_source.draft(
                         r.prompt, r.generated, k))[:k])
                if any(not 0 <= int(t) < self.cfg.vocab_size for t in d):
                    raise ValueError(f"draft token out of vocab: {d}")
            except Exception as e:  # noqa: BLE001 — draft oracles are
                # untrusted; degradation to plain decode, never fatal
                self.draft_errors += 1
                self.last_error = f"draft: {e!r}"
                d = []
            if not d:
                continue
            # page capacity for the verify chunk (ctx + last token + k
            # drafts); the pool decides how much speculation it backs
            ctx = int(self.cache.seq_len[r.seq_slot])
            cap = self.cache.grow_to(r.seq_slot, ctx + 1 + len(d))
            d = [int(t) for t in d[:max(0, cap - ctx - 1)]]
            if d:
                drafts[i] = d
                avail -= len(d)
                self.spec_draft_tokens += len(d)
        return drafts

    def _step_split(self, admitted: list[Request], chunked: bool,
                    budget: int):
        """[Benchmark baseline] the PR-2 two-forward step: ragged prefill
        chunk, then a separate decode forward."""
        if chunked:
            plan = self.sched.plan_prefill(self.cache, budget)
            if plan:
                self._prefill_forward(plan)
            else:
                stuck = [r for r in self.sched.running if not r.prefilled]
                if stuck and not any(r.prefilled
                                     for r in self.sched.running):
                    self._preempt_one()
            prefill_ran = bool(plan)
        else:
            for req in admitted:
                self._prefill(req)
            prefill_ran = bool(admitted)
        runnable = self._reserve_decode_slots(
            [r for r in self.sched.running if r.prefilled and not r.done])
        if runnable:
            self._decode_batch(runnable)
            if prefill_ran:
                self.interleaved_steps += 1

    def _reserve_decode_slots(self, runnable: list[Request]) -> list[Request]:
        """Page headroom for one decode token per runnable sequence.

        Preempts (youngest-first) until every remaining sequence can
        extend. A sequence already at ``max_pages_per_seq`` can never
        extend no matter how many pages are freed — it finishes with
        ``stop_reason="length_cap"`` instead of spinning the loop
        forever (the seed's infinite-loop bug)."""
        pending = list(runnable)
        ready: list[Request] = []
        while pending:
            r = pending.pop(0)
            if r.seq_slot < 0 or r.state.terminal:
                # a length_cap _complete below emits an event whose
                # on_event callback may reentrantly abort() a request
                # still sitting in these local lists — its slot is gone,
                # so it must not reach extend_seq or the forward
                continue
            if self.cache.extend_seq(r.seq_slot):
                ready.append(r)
                continue
            if self.cache.at_capacity(r.seq_slot):
                # complete NOW (not at end of step): the capped request
                # must leave sched.running before any later preempt_one
                # in this loop could victimize it and destroy its output,
                # and freeing its pages helps the still-pending sequences
                r.stop_reason = "length_cap"
                self._complete(r)
                continue
            victim = self._preempt_one()
            if victim is None:
                continue            # nothing to evict — stall r this step
            if victim in pending:
                pending.remove(victim)
            elif victim in ready:
                ready.remove(victim)
            if victim is not r:
                pending.insert(0, r)    # retry r with the freed pages
        # drop rows a reentrant abort invalidated after they were ready
        return [r for r in ready if r.seq_slot >= 0 and not r.state.terminal]

    # ------------------------------------------------------------- sampling

    def _make_sample_fn(self, kmax: int):
        """Batched per-request sampler: one call serves rows mixing
        greedy and stochastic requests with different temperature/top_k.
        ``kmax`` (the bucketed max top_k this batch) is the only static
        shape — per-row k is a mask over the top-kmax candidates."""

        def sample(logits, rids, positions, temps, topks):
            key0 = jax.random.PRNGKey(0)
            keys = jax.vmap(lambda r, p: jax.random.fold_in(
                jax.random.fold_in(key0, r), p))(rids, positions)
            topv, topi = jax.lax.top_k(logits, kmax)
            safe_t = jnp.where(temps > 0, temps, 1.0)[:, None]
            masked = jnp.where(jnp.arange(kmax)[None, :] < topks[:, None],
                               topv / safe_t, -jnp.inf)
            idx = jax.vmap(jax.random.categorical)(keys, masked)
            samp = jnp.take_along_axis(topi, idx[:, None], axis=1)[:, 0]
            greedy = jnp.argmax(logits, axis=-1).astype(samp.dtype)
            return jnp.where(temps > 0, samp, greedy)

        return jax.jit(sample)

    def _sample_batch(self, logits: np.ndarray, reqs: list[Request],
                      positions: list[int]) -> list[int]:
        """ONE vectorized sampling call for all rows needing a token
        this step, honoring each request's own SamplingParams. Rows are
        padded up to a power-of-two bucket so steady-state steps reuse
        the compiled sampler; all-greedy batches (the common serving
        default) take a pure-numpy argmax fast path."""
        n = logits.shape[0]
        dflt = self.ecfg
        temps = np.asarray(
            [r.params.temperature if r.params else dflt.temperature
             for r in reqs], np.float32)
        if (temps <= 0.0).all():
            return [int(t) for t in np.argmax(logits, axis=-1)]
        topks = np.asarray(
            [min(r.params.top_k if r.params else dflt.top_k,
                 logits.shape[1]) for r in reqs], np.int32)
        kmax = min(_bucket(int(topks.max())), logits.shape[1])
        fn = self._sample_fns.get(kmax)
        if fn is None:
            fn = self._sample_fns[kmax] = self._make_sample_fn(kmax)
        nb = _bucket(n)
        lg = np.zeros((nb, logits.shape[1]), np.float32)
        lg[:n] = logits
        tp = np.zeros((nb,), np.float32)       # pad rows sample greedily
        tp[:n] = temps
        rids = np.asarray([r.request_id for r in reqs], np.int32)
        toks = fn(
            jnp.asarray(lg),
            jnp.asarray(_pad_to(rids, nb)),
            jnp.asarray(_pad_to(np.asarray(positions, np.int32), nb)),
            jnp.asarray(tp),
            jnp.asarray(_pad_to(topks, nb, fill=1)))
        return [int(t) for t in np.asarray(toks)[:n]]

    def _sample(self, logits: np.ndarray, req: Request,
                position: int) -> int:
        return self._sample_batch(logits[None], [req], [position])[0]

    def _block_params(self, li: int):
        return jax.tree.map(lambda a: a[li], self.params["blocks"])

    # --------------------------------------------------- unified one-forward

    def _forward_step(self, plan: list[tuple[Request, int, int]],
                      decode: list[tuple[Request, list]]):
        """Pack prompt-chunk rows and decode rows into ONE ragged forward.

        A decode row is a chunk of 1 (its last sampled token) whose paged
        history is the whole sequence so far — the same
        fp-queries-over-int4-history contract the prefill kernel already
        serves, so the union needs no second attention dataflow. A
        SPECULATING decode row (``decode`` pairs each request with its
        planned draft, possibly empty) is the same thing with qlen
        1+k: last sampled token + k drafted tokens, verified from the
        chunk's per-position logits after the forward. The packed layout
        is bucketed (powers of two) so repeated steps hit the jit cache;
        padding tokens scatter to out-of-range pages (dropped) and pad
        rows have qlen 0 (masked).

        Failure isolation: everything from destination resolution
        through the forward runs under a guard — an exception there
        (including injected ``append_kv``/``forward`` faults)
        quarantines every request in THIS batch to FAILED and returns;
        requests outside the batch are untouched. Page accounting stays
        exact because all host state (prefill_pos, seq_len, advance)
        only moves AFTER the forward succeeds, so ``free_seq`` on a
        quarantined row returns the pools to baseline. After the
        forward, a per-row non-finite guard fails exactly the rows
        whose logits are NaN/Inf (a spec row checks its whole verify
        chunk), and the sampler/verifier run under their own guards
        (rows mid-prefill are never touched by either)."""
        rows = list(plan) + [
            (r, int(self.cache.seq_len[r.seq_slot]), 1 + len(d))
            for r, d in decode]
        starts = np.asarray([s for _, s, _ in rows])
        takes = np.asarray([t for _, _, t in rows])
        slots = np.asarray([r.seq_slot for r, _, _ in rows])
        nseq, cmax, ttot = len(rows), int(takes.max()), int(takes.sum())
        cum = np.concatenate([[0], np.cumsum(takes)])

        # ragged layout: packed index → (row, in-chunk offset)
        tok_seq = np.repeat(np.arange(nseq), takes)
        tok_off = np.concatenate([np.arange(t) for t in takes])
        tok_pos = starts[tok_seq] + tok_off            # absolute positions
        tokens = np.concatenate(
            [np.asarray(r.prompt[s:s + t]) for r, s, t in plan]
            + [[r.generated[-1]] + d for r, d in decode]).astype(np.int64)
        # logit slots: by default one per row (its LAST packed token —
        # exactly the historical cum[1:]-1 layout, so spec-off steps
        # reuse their jit cache entries bit-for-bit); a speculating row
        # contributes every chunk position, since verification needs
        # logits at each drafted token
        nplan = len(plan)
        slot0: list[int] = []
        logit_idx: list[int] = []
        for si in range(nseq):
            slot0.append(len(logit_idx))
            if si >= nplan and takes[si] > 1:
                logit_idx.extend(range(int(cum[si]), int(cum[si + 1])))
            else:
                logit_idx.append(int(cum[si + 1]) - 1)
        try:
            logits, nan_fault = self._guarded_forward(
                plan, rows, starts, takes, slots, nseq, cmax, ttot, cum,
                tok_seq, tok_off, tok_pos, tokens,
                np.asarray(logit_idx))
        except Exception as e:  # noqa: BLE001 — batch-granular quarantine
            # drafts die with the batch: counted as rollbacks so
            # drafted == accepted + rollback stays conserved under faults
            self.spec_rollback_tokens += sum(len(d) for _, d in decode)
            for r, _, _ in rows:
                self._fail(r, f"forward: {e!r}")
            return

        # host state: prompt progress + decode appends; a completed
        # prompt publishes its full pages into the prefix index.
        # Speculating rows do NOT advance here — their resident length
        # is decided by verification (accepted prefix) via truncate_seq
        for r, s, t in plan:
            r.prefill_pos = s + t
            self.cache.seq_len[r.seq_slot] = r.prefill_pos
            if self.ecfg.prefix_caching and r.prefill_pos == len(r.prompt):
                self.cache.publish_prefix(r.seq_slot, r.prompt)
        self.cache.advance([r.seq_slot for r, d in decode if not d])

        # one vectorized sample over finished-prefill rows ∪ plain
        # decode rows; speculating rows verify per-position afterwards
        need = [(slot0[si], r, len(r.prompt))
                for si, (r, s, t) in enumerate(plan)
                if s + t == len(r.prompt)]
        need += [(slot0[nplan + j], r, r.total_len)
                 for j, (r, d) in enumerate(decode) if not d]
        spec = [(slot0[nplan + j], r, int(starts[nplan + j]), d)
                for j, (r, d) in enumerate(decode) if d]
        if not need and not spec:
            return
        if nan_fault is not None:
            # injected NaN lands on a row actually being consumed (row
            # clamped into the sampled/verified slots), so the schedule
            # reliably exercises the guards below
            sampled = ([si for si, _, _ in need]
                       + [s0 for s0, _, _, _ in spec])
            logits[sampled[min(nan_fault.row, len(sampled) - 1)], :] = \
                np.nan
        # per-row non-finite guard: a NaN/Inf logits row — injected or
        # real — quarantines exactly that request; finite rows sample on
        if need:
            finite = np.isfinite(
                logits[[si for si, _, _ in need]]).all(axis=-1)
            if not finite.all():
                for (_, r, _), ok in zip(need, finite):
                    if not ok:
                        self._fail(r, "non_finite_logits")
                need = [t for t, ok in zip(need, finite) if ok]
        if need:
            self._sample_rows(logits, need)
        for s0, r, ctx, d in spec:
            if np.isfinite(logits[s0:s0 + len(d) + 1]).all():
                self._verify_row(logits, s0, r, ctx, d)
            else:
                self.spec_rollback_tokens += len(d)
                self._fail(r, "non_finite_logits")

    def _sample_rows(self, logits: np.ndarray, need: list):
        """Guarded batched sampling: a sampler exception (or injected
        ``sample`` fault) fails exactly the rows being sampled — rows
        mid-prefill never reach here."""
        try:
            if self.faults.check("sample"):
                raise InjectedFault("sample: injected sampler failure")
            toks = self._sample_batch(
                logits[[si for si, _, _ in need]],
                [r for _, r, _ in need],
                [p for _, _, p in need])
        except Exception as e:  # noqa: BLE001 — row-granular quarantine
            for _, r, _ in need:
                self._fail(r, f"sample: {e!r}")
            return
        for (_, r, _), tok in zip(need, toks):
            self._record_token(r, tok)

    def _verify_row(self, logits: np.ndarray, s0: int, r: Request,
                    ctx: int, draft: list):
        """Commit one speculating row's verified prefix.

        The forward already wrote KV for the WHOLE 1+k chunk (last
        sampled token + k drafts) at positions [ctx, ctx+k]; the walk
        over the chunk's per-position logits decides how much of it was
        real. ``truncate_seq`` lands the row's resident length at
        ctx + len(committed) FIRST — retracting rejected drafts' KV
        (refcount/prefix-safe) and advancing over accepted ones in one
        move — and only then do the committed tokens emit, so a
        reentrant ``abort()`` from an ``on_event`` callback mid-loop
        finds page accounting already consistent. A verification
        failure (or an injected ``verify`` fault) quarantines exactly
        this request; the rest of the batch keeps its step."""
        if r.seq_slot < 0 or r.state.terminal:
            # reentrant abort earlier in this step's loop — the draft
            # died with the request's pages; count it rolled back
            self.spec_rollback_tokens += len(draft)
            return
        try:
            if self.faults.check("verify"):
                raise InjectedFault("verify: injected verifier failure")
            committed, accepted = self._verify_tokens(logits, s0, r, draft)
            self.cache.truncate_seq(r.seq_slot, ctx + len(committed))
        except Exception as e:  # noqa: BLE001 — row-granular quarantine
            self.spec_rollback_tokens += len(draft)
            self._fail(r, f"verify: {e!r}")
            return
        self.spec_accepted_tokens += accepted
        self.spec_rollback_tokens += len(draft) - accepted
        for tok in committed:
            self._record_token(r, tok)

    def _verify_tokens(self, logits: np.ndarray, s0: int, r: Request,
                       draft: list):
        """Walk the verify chunk's logits; return (committed, accepted).

        Position i of the chunk is conditioned on the last sampled
        token plus drafts 0..i-1, so its logits row is EXACTLY what a
        plain decode step would have produced after committing those
        drafts. Greedy (the serving default): argmax each row; a match
        accepts the draft and moves on, the first mismatch commits the
        corrected token and stops — bitwise the tokens speculation-off
        greedy would emit, just several per forward. Stochastic:
        point-mass rejection sampling per position (accept draft w.p.
        p(draft), else draw the renormalized residual) — the committed
        tokens are distributed exactly as i.i.d. draws from each
        position's sampling distribution. Either way the row after the
        last accepted draft yields one bonus token, so a verified step
        always commits ≥ 1 token. ``accepted`` counts draft tokens
        kept (the acceptance-rate numerator)."""
        p = r.params
        temp = p.temperature if p is not None else self.ecfg.temperature
        top_k = min(p.top_k if p is not None else self.ecfg.top_k,
                    logits.shape[1])
        remaining = r.max_new_tokens - len(r.generated)
        committed: list[int] = []
        accepted = 0
        i = 0
        while i <= len(draft) and len(committed) < remaining:
            row = logits[s0 + i]
            drafted = int(draft[i]) if i < len(draft) else None
            if temp <= 0.0:
                tok, ok = int(np.argmax(row)), False
                ok = drafted is not None and tok == drafted
            else:
                tok, ok = _reject_sample(row, temp, top_k, drafted,
                                         r.request_id, r.total_len + i)
            committed.append(tok)
            if not ok:
                break
            accepted += 1
            i += 1
        return committed, accepted

    def _guarded_forward(self, plan, rows, starts, takes, slots, nseq,
                         cmax, ttot, cum, tok_seq, tok_off, tok_pos,
                         tokens, logit_idx):
        """The fault-guarded section of :meth:`_forward_step`:
        destination resolution (the ``append_kv`` fault point), shape
        bucketing, and the ONE forward (the ``forward`` fault point —
        ``raise`` aborts here; ``nan`` returns the armed fault so the
        caller corrupts a sampled row). ``logit_idx`` lists the packed
        token indices whose logits the caller consumes (one per row
        unless a row speculates, then all its chunk positions); its own
        bucket ``lb`` joins the jit-cache key, and collapses to the
        historical ``nb`` whenever no row speculates. Returns (writable
        logits ndarray [lb, V], nan_fault). No host scheduler/cache
        bookkeeping moves in here — an exception leaves page accounting
        untouched, so the caller's quarantine frees back to baseline."""
        pages_np, offs_np = self.cache.token_dests_np(slots[tok_seq],
                                                      tok_pos)
        # shape buckets — the jit cache key
        tb = _bucket(ttot, lo=8)
        nb = _bucket(nseq)
        cb = _bucket(cmax)
        npb = min(_bucket(self.cache.pages_needed(max(int(starts.max()), 1))),
                  self.cache.pcfg.max_pages_per_seq)

        pf_tokens = int(sum(t for _, _, t in plan))
        self.peak_prefill_fp_tokens = max(self.peak_prefill_fp_tokens,
                                          pf_tokens)
        self.prefill_tokens += pf_tokens
        self.forward_calls += 1
        # all rows history-free (a pure first-chunk step, so no decode
        # rows either) → the causal fp flash path, exactly like the
        # split baseline's fast path (its own static trace variant)
        no_history = int(starts.max()) == 0
        schedule = self.ecfg.attention_schedule
        hkv = self.cfg.num_kv_heads
        hkv_loc = hkv // self.tp_size
        wq = schedule == "work_queue" and not no_history
        if wq:
            # flat Stream-K descriptors over the rows' REAL pages (+ one
            # chunk item per row), pow-2 padded — the work count replaces
            # npages as the attention dimension of the jit-cache key, so
            # the dense block tables collapse to a constant-shape dummy.
            # The padding sentinel must clear the BUCKETED row count:
            # rows [nseq, nb) are live (qlen-0) segments in the combine.
            # Under TP the descriptor is built for the LOCAL head count:
            # every shard attends the same page stream with its own head
            # slice, so one replicated descriptor drives all shards
            desc_np = self.cache.work_queue_np(slots, starts, takes,
                                               pad_row=nb * hkv_loc,
                                               num_kv_heads=hkv_loc)
            tables = np.zeros((nb, 1), np.int32)
        else:
            desc_np = np.zeros((8, 4), np.int32)
            tables = np.zeros((nb, npb), np.int32)
            tables[:nseq] = self.cache.block_tables_np(slots, npb)
        if not no_history:
            # fig10 measured-ablation counters: the real work is the
            # same under both schedules; the launched grid is not
            self.attn_forwards += 1
            items = int(
                hkv * (np.sum((starts + self.ecfg.page_size - 1)
                              // self.ecfg.page_size) + nseq))
            self.attn_work_items += items
            # exact split: work per shard is hkv_loc · (pages + rows)
            per = items // self.tp_size
            for i in range(self.tp_size):
                self.attn_work_items_per_shard[i] += per
            self.attn_dense_grid_items += nb * hkv * (npb + 1)
            self.attn_grid_items += (desc_np.shape[0] * self.tp_size if wq
                                     else nb * hkv * (npb + 1))
        nan_fault = None
        fwd_fault = self.faults.check("forward")
        if fwd_fault is not None:
            if fwd_fault.action == "raise":
                raise InjectedFault("forward: injected forward failure")
            nan_fault = fwd_fault
        logits, k_pool, v_pool = self._fwd(
            cb, no_history, schedule, self.params, self.cache.k_pool,
            self.cache.v_pool,
            jnp.asarray(_pad_to(tokens, tb)),
            jnp.asarray(_pad_to(tok_pos, tb)),
            # padding destinations: page == num_pages is out of range →
            # the scatter update is dropped; row == nb likewise drops in
            # the packed→padded scatter
            jnp.asarray(_pad_to(pages_np, tb,
                                fill=self.cache.pcfg.num_pages)),
            jnp.asarray(_pad_to(offs_np, tb)),
            jnp.asarray(_pad_to(tok_seq, tb, fill=nb)),
            jnp.asarray(_pad_to(tok_off, tb)),
            # decode tokens (the packed tail) fake-quantize their
            # in-flight KV so self-attention matches the int4 the split
            # decode path reads back
            jnp.asarray(_pad_to(np.arange(ttot) >= cum[len(plan)], tb)),
            jnp.asarray(tables),
            jnp.asarray(_pad_to(starts, nb)),          # ctx per row
            jnp.asarray(_pad_to(takes, nb)),           # qlens per row
            # consumed logit slots, own bucket (== nb when nothing
            # speculates — the historical last-token-per-row layout)
            jnp.asarray(_pad_to(logit_idx, _bucket(len(logit_idx)))),
            jnp.asarray(desc_np),                      # wq work items
            self.cache.k_scale, self.cache.k_zero,
            self.cache.v_scale, self.cache.v_zero)
        self.cache.k_pool, self.cache.v_pool = k_pool, v_pool
        # np.array (copy): the device buffer view is read-only and the
        # caller mutates rows in place (nan injection)
        return np.array(logits), nan_fault

    def _unified_forward(self, cmax: int, no_history: bool, schedule: str,
                         params, k_pool, v_pool, tokens, positions, pages,
                         offs, tseq, toff, dq_mask, block_tables, ctx,
                         qlens, last_idx, work_items,
                         k_scale, k_zero, v_scale, v_zero):
        """The jitted unified forward (one trace per shape bucket).

        tokens/positions/pages/offs/tseq/toff/dq_mask: [Tb] int32 packed
        layout; block_tables: [Nb, NPb]; ctx/qlens: [Nb]; last_idx:
        [Lb] consumed logit slots (== [Nb] last-token-per-row when no
        row speculates, else every spec chunk position too);
        work_items: [Wb, 4] flat Stream-K descriptors (the attention
        shape key under ``schedule="work_queue"`` — block_tables is a
        [Nb, 1] dummy there; under "dense" the roles swap);
        k_scale/k_zero/v_scale/v_zero: the cache's static per-channel
        int4 scales [Hkv, 1, D] (explicit args so ``shard_map`` can hand
        each shard its head slice). Returns (logits [Lb, V] f32, k_pool,
        v_pool) — pools updated with the step's quantized KV.

        Single device: runs :meth:`_unified_body` directly. TP: wraps
        the same body in ``shard_map`` over the engine mesh — params and
        pools enter pre-sharded (placed by ``_init_sharding``), every
        int32 layout array is replicated (page ids are host-global), and
        each shard computes its kv-head slice end to end with psums only
        at the wo / w_down seams (inside ``_row_linear``)."""
        self.trace_count += 1          # traced body: fires once per compile
        args = (params, k_pool, v_pool, tokens, positions, pages, offs,
                tseq, toff, dq_mask, block_tables, ctx, qlens, last_idx,
                work_items, k_scale, k_zero, v_scale, v_zero)
        if self.tp_size == 1:
            # single device: hand the body the CLOSURE scales (trace-time
            # constants, the historical graph) rather than the traced
            # copies — embedding them keeps the compiled HLO bit-identical
            # to the pre-TP engine, so pinned greedy parity workloads
            # cannot flip on recompilation noise. The traced scale args
            # are dead here (DCE'd); only shard_map needs them live, to
            # hand each shard its head slice
            return self._unified_body(cmax, no_history, schedule, None,
                                      *args[:15], self.cache.k_scale,
                                      self.cache.k_zero, self.cache.v_scale,
                                      self.cache.v_zero)
        body = functools.partial(self._unified_body, cmax, no_history,
                                 schedule, "model")
        pool, scale = self._pool_pspec, self._scale_pspec
        return shard_map(
            body, mesh=self.mesh,
            in_specs=(self._param_pspecs, pool, pool) + (P(),) * 12
            + (scale,) * 4,
            out_specs=(P(), pool, pool),
            check_rep=False)(*args)

    def _unified_body(self, cmax: int, no_history: bool, schedule: str,
                      tp_axis, params, k_pool, v_pool, tokens, positions,
                      pages, offs, tseq, toff, dq_mask, block_tables, ctx,
                      qlens, last_idx, work_items,
                      k_scale, k_zero, v_scale, v_zero):
        """Per-shard unified forward. With ``tp_axis=None`` this IS the
        single-device forward (bit-identical math); under ``shard_map``
        every array is the local shard and ``tp_axis`` names the mesh
        axis the two all-reduce seams psum over. Head counts are derived
        from the local weight shapes via ``_project_qkv`` overrides; the
        attention kernels are already shape-agnostic (they read head
        counts off q/pool shapes), so the same work-queue descriptors
        drive every shard's local heads."""
        cfg = self.cfg
        tp = self.tp_size if tp_axis is not None else 1
        hq_loc = cfg.num_heads // tp
        hkv_loc = cfg.num_kv_heads // tp
        nseq = block_tables.shape[0]
        with self.lm._ctx():
            x = self.lm._embed(params, tokens[None, :])
            pos2 = positions[None, :]
            for li in range(cfg.num_layers):
                bp = jax.tree.map(lambda a: a[li], params["blocks"])
                h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
                q, k, v = ATT._project_qkv(
                    bp["attn"], cfg, h, h, pos2, pos2,
                    num_heads=hq_loc, num_kv_heads=hkv_loc)
                # quantize + page the union's KV (padding rides on OOB
                # destinations), then attend: fp queries over the int4
                # history pages + each row's causal in-flight fp chunk
                kq, vq = KVC.quantize_kv_with(
                    k, v, k_scale, k_zero, v_scale, v_zero)
                hkv, half = kq.shape[1], kq.shape[-1]  # [1, Hloc, Tb, D/2]
                kq = jnp.moveaxis(kq, 1, 2).reshape(-1, hkv, half)
                vq = jnp.moveaxis(vq, 1, 2).reshape(-1, hkv, half)
                k_pool = k_pool.at[li, pages, offs].set(kq, mode="drop")
                v_pool = v_pool.at[li, pages, offs].set(vq, mode="drop")
                # decode rows' self-attention reads the fake-quantized
                # chunk — the same values their int4 page dequantizes to
                kdq, vdq = KVC.qdq_kv_with(
                    k, v, k_scale, k_zero, v_scale, v_zero)
                m = (dq_mask != 0)[None, :, None, None]
                k_att = jnp.where(m, kdq, k.astype(jnp.float32))
                v_att = jnp.where(m, vdq, v.astype(jnp.float32))

                def pad(a):    # packed [1, Tb, Hx, D] → [Nb, Cb, Hx, D]
                    z = jnp.zeros((nseq, cmax) + a.shape[2:], a.dtype)
                    return z.at[tseq, toff].set(a[0], mode="drop")

                if no_history:
                    # first chunk for every packed prompt: padding keys
                    # are causally masked, so plain fp flash is exact
                    out = ATT.flash_attention(pad(q), pad(k_att),
                                              pad(v_att), causal=True)
                elif schedule == "work_queue":
                    out = ops.paged_kv4_prefill_attention_wq(
                        pad(q), pad(k_att), pad(v_att),
                        k_pool[li], k_scale, k_zero,
                        v_pool[li], v_scale, v_zero,
                        work_items, impl=self.quant.impl)
                else:
                    out = ops.paged_kv4_prefill_attention(
                        pad(q), pad(k_att), pad(v_att),
                        k_pool[li], k_scale, k_zero,
                        v_pool[li], v_scale, v_zero,
                        block_tables, ctx, qlens, impl=self.quant.impl)
                a = out[tseq, toff][None]          # repack [1, Tb, ...]
                a = a.astype(x.dtype).reshape(1, -1, hq_loc * cfg.head_dim)
                x = x + _row_linear(bp["attn"]["wo"], a, tp_axis)
                h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
                if "moe" in bp:
                    y, _ = MLP.moe_apply(bp["moe"], h, cfg)
                else:
                    y = _mlp_row(bp["mlp"], h, cfg.mlp_act, tp_axis)
                x = x + y
            hN = C.apply_norm(params["final_norm"], x[:, last_idx],
                              cfg.norm, cfg.norm_eps)
            logits = self.lm._head(params, hN)
        return logits[0], k_pool, v_pool

    # -------------------------------------------- split-step fig11 baseline

    def _count_trace(self, sig):
        """Split-path proxy for ``trace_count``: eager dispatch caches
        per packed shape, so each new signature is a compile."""
        if sig not in self._fwd_shapes:
            self._fwd_shapes.add(sig)
            self.trace_count += 1

    def _prefill(self, req: Request):
        """[Benchmark baseline] whole-prompt prefill: one O(T²) fp flash
        forward per request; the full prompt's fp KV is live at once."""
        cfg = self.cfg
        self.peak_prefill_fp_tokens = max(self.peak_prefill_fp_tokens,
                                          len(req.prompt))
        self.prefill_tokens += len(req.prompt)
        self.forward_calls += 1
        self._count_trace(("whole", len(req.prompt)))
        with self.lm._ctx():
            tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
            x = self.lm._embed(self.params, tokens)
            positions = jnp.arange(len(req.prompt))[None, :]
            for li in range(cfg.num_layers):
                bp = self._block_params(li)
                h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
                q, k, v = ATT._project_qkv(
                    bp["attn"], cfg, h, h, positions, positions)
                a = ATT.flash_attention(q, k, v, causal=cfg.causal)
                self.cache.write_prompt(li, req.seq_slot, k, v)
                a = a.astype(x.dtype).reshape(1, -1, cfg.q_dim)
                x = x + C.linear(bp["attn"]["wo"], a)
                h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
                if "moe" in bp:
                    y, _ = MLP.moe_apply(bp["moe"], h, cfg)
                else:
                    y = MLP.mlp_apply(bp["mlp"], h, cfg.mlp_act)
                x = x + y
            hN = C.apply_norm(self.params["final_norm"], x[:, -1:],
                              cfg.norm, cfg.norm_eps)
            logits = self.lm._head(self.params, hN)
        tok = self._sample(np.asarray(logits[0, -1]), req,
                           len(req.prompt))
        self.cache.extend_seq(req.seq_slot)
        req.prefill_pos = len(req.prompt)
        self._record_token(req, tok)

    def _prefill_forward(self, plan: list[tuple[Request, int, int]]):
        """[Split baseline] ONE ragged forward over the planned chunk
        slices (no decode rows — those run in a second forward).

        Tokens from all planned requests are packed into a single
        [1, T_total] sequence (cu_seqlens-style offsets) for the
        position-wise work (norms, W4Ax projections, MLP); attention
        unpacks to a padded [nseq, C_max] view for the paged prefill
        kernel, then repacks. Each layer writes the chunk's quantized KV
        into the pools via destinations precomputed once for the step."""
        cfg = self.cfg
        starts = np.asarray([s for _, s, _ in plan])
        takes = np.asarray([t for _, _, t in plan])
        slots = np.asarray([r.seq_slot for r, _, _ in plan])
        nseq, cmax, ttot = len(plan), int(takes.max()), int(takes.sum())
        cum = np.concatenate([[0], np.cumsum(takes)])

        # ragged layout: packed index → (sequence, in-chunk offset)
        tok_seq = np.repeat(np.arange(nseq), takes)
        tok_off = np.concatenate([np.arange(t) for t in takes])
        tok_pos = starts[tok_seq] + tok_off            # absolute positions
        tokens = np.concatenate(
            [r.prompt[s:s + t] for r, s, t in plan]).astype(np.int64)

        # page destinations: ONE host lookup for the step, all layers
        pages, offs = self.cache.token_dests(slots[tok_seq], tok_pos)
        block_tables = self.cache.block_tables_device(
            slots, max(int(starts.max()), 1))
        ctx = jnp.asarray(starts, jnp.int32)
        qlens = jnp.asarray(takes, jnp.int32)
        tseq = jnp.asarray(tok_seq)
        toff = jnp.asarray(tok_off)
        # packed↔padded fast paths: equal takes means the seq-major packed
        # layout IS the padded layout (reshape, no scatter/gather); chunks
        # with no paged history anywhere are pure fp causal attention
        uniform = bool((takes == takes[0]).all())
        no_history = int(starts.max()) == 0

        self.peak_prefill_fp_tokens = max(self.peak_prefill_fp_tokens, ttot)
        self.prefill_tokens += ttot
        self.forward_calls += 1
        self._count_trace(("prefill", nseq, cmax, ttot, no_history))
        with self.lm._ctx():
            x = self.lm._embed(self.params,
                               jnp.asarray(tokens, jnp.int32)[None, :])
            positions = jnp.asarray(tok_pos)[None, :]
            for li in range(cfg.num_layers):
                bp = self._block_params(li)
                h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
                q, k, v = ATT._project_qkv(
                    bp["attn"], cfg, h, h, positions, positions)
                # quantize + page the chunk's KV, then attend: fp queries
                # over int4 history pages + the causal in-flight fp chunk
                self.cache.scatter_tokens(li, pages, offs, k, v)

                def pad(a):       # [1, Ttot, Hx, D] → [nseq, Cmax, Hx, D]
                    if uniform:
                        return a[0].reshape(nseq, cmax, *a.shape[2:])
                    z = jnp.zeros((nseq, cmax) + a.shape[2:], a.dtype)
                    return z.at[tseq, toff].set(a[0])

                if no_history:
                    # first chunk for every packed prompt: padding keys
                    # are causally masked, so plain fp flash is exact
                    out = ATT.flash_attention(pad(q), pad(k), pad(v),
                                              causal=True)
                else:
                    out = ops.paged_kv4_prefill_attention(
                        pad(q), pad(k), pad(v),
                        self.cache.k_pool[li], self.cache.k_scale,
                        self.cache.k_zero,
                        self.cache.v_pool[li], self.cache.v_scale,
                        self.cache.v_zero,
                        block_tables, ctx, qlens, impl=self.quant.impl)
                if uniform:
                    a = out.reshape(1, ttot, *out.shape[2:])
                else:
                    a = out[tseq, toff][None]          # repack [1, Ttot, ...]
                a = a.astype(x.dtype).reshape(1, ttot, cfg.q_dim)
                x = x + C.linear(bp["attn"]["wo"], a)
                h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
                if "moe" in bp:
                    y, _ = MLP.moe_apply(bp["moe"], h, cfg)
                else:
                    y = MLP.mlp_apply(bp["mlp"], h, cfg.mlp_act)
                x = x + y
            finished = [(si, r) for si, (r, s, t) in enumerate(plan)
                        if s + t == len(r.prompt)]
            if finished:
                last = [int(cum[si] + takes[si] - 1) for si, _ in finished]
                hN = C.apply_norm(self.params["final_norm"], x[:, last],
                                  cfg.norm, cfg.norm_eps)
                logits = np.asarray(self.lm._head(self.params, hN))

        for r, s, t in plan:
            r.prefill_pos = s + t
            self.cache.seq_len[r.seq_slot] = r.prefill_pos
            if self.ecfg.prefix_caching and r.prefill_pos == len(r.prompt):
                self.cache.publish_prefix(r.seq_slot, r.prompt)
        if finished:
            toks = self._sample_batch(
                logits[0], [r for _, r in finished],
                [len(r.prompt) for _, r in finished])
            for (_, r), tok in zip(finished, toks):
                self._record_token(r, tok)

    def _attend_paged(self, li: int, q, block_tables, lengths,
                      work_items=None):
        """One kernel call for the whole decode batch — block tables in,
        no per-sequence materialization. With ``work_items`` set (the
        work-queue schedule) the flat descriptors replace the dense
        block-table walk."""
        cache = self.cache
        if work_items is not None:
            return ops.paged_kv4_decode_attention_wq(
                q[:, 0], cache.k_pool[li], cache.k_scale, cache.k_zero,
                cache.v_pool[li], cache.v_scale, cache.v_zero,
                work_items, impl=self.quant.impl)
        return ops.paged_kv4_decode_attention(
            q[:, 0], cache.k_pool[li], cache.k_scale, cache.k_zero,
            cache.v_pool[li], cache.v_scale, cache.v_zero,
            block_tables, lengths, impl=self.quant.impl)

    def _attend_gather(self, li: int, q, slots, max_len, lengths):
        """[Benchmark baseline] per-token O(context) gather, then the
        contiguous KV4 kernel. The batch-broadcast scale/zero tensors are
        cached per batch size — they are step-invariant, and rebuilding
        them allocated four arrays per layer per step."""
        cache = self.cache
        bsz = q.shape[0]
        kp, vp, _ = cache.gather_kv(li, slots, max_len)
        if bsz not in self._gather_bcast:
            bcast = lambda s: jnp.broadcast_to(s[None], (bsz, *s.shape))
            self._gather_bcast[bsz] = (
                bcast(cache.k_scale), bcast(cache.k_zero),
                bcast(cache.v_scale), bcast(cache.v_zero))
        ks, kz, vs, vz = self._gather_bcast[bsz]
        return ops.kv4_decode_attention(
            q[:, 0], kp, ks, kz, vp, vs, vz, lengths,
            impl=self.quant.impl)

    def _decode_batch(self, reqs: list[Request]):
        """[Split baseline] the separate decode forward."""
        cfg = self.cfg
        slots = [r.seq_slot for r in reqs]
        bsz = len(reqs)
        last = jnp.asarray([[r.generated[-1]] for r in reqs], jnp.int32)

        lengths_np = self.cache.seq_len[slots].copy()
        max_len = int(lengths_np.max()) + 1
        paged = self.ecfg.decode_attention == "paged"
        # block tables are fixed for the step (extend_seq already ran);
        # lengths include the token being appended this step. Page
        # destinations for the appends are resolved on the host ONCE and
        # reused by every layer's scatter (was: one block-table lookup +
        # validation per layer — num_layers host syncs per step).
        lengths = jnp.asarray(lengths_np + 1, jnp.int32)
        pages, offs = self.cache.token_dests(slots, lengths_np)
        self.forward_calls += 1
        hkv = self.cfg.num_kv_heads
        npages = self.cache.pages_needed(max_len)
        work_items = None
        block_tables = None
        if paged and self.ecfg.attention_schedule == "work_queue":
            # the decode batch attends over ctx + the token written this
            # step — descriptors cover exactly those real pages, and the
            # dense block tables never ship to the device
            desc_np = self.cache.work_queue_np(slots, lengths_np + 1)
            work_items = jnp.asarray(desc_np)
            self.attn_grid_items += desc_np.shape[0]
            self._count_trace(("decode", bsz, desc_np.shape[0]))
        else:
            if paged:
                block_tables = self.cache.block_tables_device(
                    slots, max_len)
                self.attn_grid_items += bsz * hkv * npages
            self._count_trace(("decode", bsz, npages))
        if paged:
            self.attn_forwards += 1
            items = int(hkv * np.sum(
                (lengths_np + self.ecfg.page_size) // self.ecfg.page_size))
            self.attn_work_items += items
            self.attn_work_items_per_shard[0] += items  # split: one device
            self.attn_dense_grid_items += bsz * hkv * npages
        with self.lm._ctx():
            x = self.lm._embed(self.params, last)
            positions = jnp.asarray(lengths_np)[:, None]
            for li in range(cfg.num_layers):
                bp = self._block_params(li)
                h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
                q, k, v = ATT._project_qkv(
                    bp["attn"], cfg, h, h, positions, positions)
                # write the batch's new KV (one scatter), then attend over
                # the pools via block tables — one kernel call per layer
                self.cache.scatter_tokens(li, pages, offs, k, v)
                if paged:
                    out = self._attend_paged(li, q, block_tables, lengths,
                                             work_items)
                else:
                    out = self._attend_gather(li, q, slots, max_len, lengths)
                out = out.reshape(bsz, 1, cfg.q_dim).astype(x.dtype)
                x = x + C.linear(bp["attn"]["wo"], out)
                h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
                if "moe" in bp:
                    y, _ = MLP.moe_apply(bp["moe"], h, cfg)
                else:
                    y = MLP.mlp_apply(bp["mlp"], h, cfg.mlp_act)
                x = x + y
            hN = C.apply_norm(self.params["final_norm"], x,
                              cfg.norm, cfg.norm_eps)
            logits = np.asarray(self.lm._head(self.params, hN))
        self.cache.advance(slots)
        toks = self._sample_batch(
            logits[:, -1], reqs, [r.total_len for r in reqs])
        for r, tok in zip(reqs, toks):
            self._record_token(r, tok)
