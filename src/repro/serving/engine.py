"""COMET serving engine: continuous batching over the paged KV4 cache.

The engine is the paper's §5 system layer: W4Ax projections + int4 paged
KV + vLLM-style scheduling. Unlike the scanned `LM.decode` (used for the
compile-time dry-run), the engine walks layers in a Python loop so each
layer's attention reads/writes the *paged* pool directly — the realistic
serving dataflow (gather pages → KV4 flash-decode → append one token).

Supported families here: dense, moe (the paper's evaluation set —
LLaMA/Qwen/Mistral class + MoE). Hybrid/ssm decode serve through
``LM.decode`` (their state is O(1) — paging buys nothing).

Fault tolerance: ``snapshot()`` captures scheduler state; ``Engine.
restore`` rebuilds mid-flight work after a crash (prompts re-prefill; the
sampler is keyed by (request_id, position) so regenerated text is
identical).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import qlinear as QL
from repro.kernels import ops
from repro.layers import attention as ATT
from repro.layers import common as C
from repro.layers import mlp as MLP
from repro.models.lm import LM, QuantConfig
from repro.serving.kv_cache import PagedKV4Cache, PagedKV4Config
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine", "EngineConfig"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 32
    num_pages: int = 512
    page_size: int = 64
    max_pages_per_seq: int = 64
    temperature: float = 0.0        # 0 → greedy
    top_k: int = 40


class Engine:
    def __init__(self, cfg: ModelConfig, qparams, quant: QuantConfig,
                 ecfg: EngineConfig = EngineConfig()):
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"paged engine supports dense/moe; {cfg.family} serves via "
                "LM.decode")
        self.cfg = cfg
        self.quant = quant
        self.lm = LM(cfg, quant=quant)
        self.params = qparams
        self.ecfg = ecfg
        self.cache = PagedKV4Cache(
            cfg,
            PagedKV4Config(
                num_pages=ecfg.num_pages, page_size=ecfg.page_size,
                max_seqs=ecfg.max_batch * 2,
                max_pages_per_seq=ecfg.max_pages_per_seq),
            num_layer_slots=cfg.num_layers)
        self.sched = Scheduler(ecfg.max_batch, ecfg.max_batch * 2)
        self.steps = 0
        self.tokens_generated = 0

    # ------------------------------------------------------------------ API

    def add_request(self, request_id: int, prompt: list[int],
                    max_new_tokens: int):
        self.sched.submit(Request(
            request_id=request_id, prompt=list(prompt),
            max_new_tokens=max_new_tokens, arrived_at=time.time()))

    def run(self, max_steps: int = 10_000) -> list[Request]:
        while self.sched.has_work and self.steps < max_steps:
            self.step()
        return self.sched.finished

    def snapshot(self) -> str:
        return self.sched.snapshot()

    @classmethod
    def restore(cls, blob: str, cfg, qparams, quant,
                ecfg: EngineConfig = EngineConfig()) -> "Engine":
        eng = cls(cfg, qparams, quant, ecfg)
        eng.sched = Scheduler.restore(blob, ecfg.max_batch,
                                      ecfg.max_batch * 2)
        return eng

    # ----------------------------------------------------------------- step

    def step(self):
        self.steps += 1
        admitted = self.sched.admit(self.cache)
        for req in admitted:
            self._prefill(req)
        runnable = [r for r in self.sched.running if r.prefilled]
        if runnable:
            # page headroom: preempt until every runnable seq can extend
            i = 0
            while i < len(runnable):
                if not self.cache.extend_seq(runnable[i].seq_slot):
                    victim = self.sched.preempt_one(self.cache)
                    if victim in runnable:
                        runnable.remove(victim)
                    continue
                i += 1
            if runnable:
                self._decode_batch(runnable)
        for req in list(self.sched.running):
            if req.done:
                self.sched.complete(req, self.cache)

    # ------------------------------------------------------------- internals

    def _sample(self, logits: np.ndarray, request_id: int,
                position: int) -> int:
        if self.ecfg.temperature <= 0.0:
            return int(np.argmax(logits))
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), request_id), position)
        lg = jnp.asarray(logits) / self.ecfg.temperature
        topv, topi = jax.lax.top_k(lg, self.ecfg.top_k)
        idx = jax.random.categorical(key, topv)
        return int(topi[idx])

    def _block_params(self, li: int):
        return jax.tree.map(lambda a: a[li], self.params["blocks"])

    def _prefill(self, req: Request):
        cfg = self.cfg
        with self.lm._ctx():
            tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
            x = self.lm._embed(self.params, tokens)
            positions = jnp.arange(len(req.prompt))[None, :]
            for li in range(cfg.num_layers):
                bp = self._block_params(li)
                h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
                q, k, v = ATT._project_qkv(
                    bp["attn"], cfg, h, h, positions, positions)
                a = ATT.flash_attention(q, k, v, causal=cfg.causal)
                self.cache.write_prompt(li, req.seq_slot, k, v)
                a = a.astype(x.dtype).reshape(1, -1, cfg.q_dim)
                x = x + C.linear(bp["attn"]["wo"], a)
                h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
                if "moe" in bp:
                    y, _ = MLP.moe_apply(bp["moe"], h, cfg)
                else:
                    y = MLP.mlp_apply(bp["mlp"], h, cfg.mlp_act)
                x = x + y
            hN = C.apply_norm(self.params["final_norm"], x[:, -1:],
                              cfg.norm, cfg.norm_eps)
            logits = self.lm._head(self.params, hN)
        tok = self._sample(np.asarray(logits[0, -1]), req.request_id,
                           len(req.prompt))
        self.cache.extend_seq(req.seq_slot)
        req.generated.append(tok)
        req.prefilled = True
        self.tokens_generated += 1

    def _decode_batch(self, reqs: list[Request]):
        cfg = self.cfg
        slots = [r.seq_slot for r in reqs]
        last = jnp.asarray([[r.generated[-1]] for r in reqs], jnp.int32)
        max_len = int(self.cache.seq_len[slots].max()) + 1

        lengths_np = self.cache.seq_len[slots].copy()
        with self.lm._ctx():
            x = self.lm._embed(self.params, last)
            positions = jnp.asarray(lengths_np)[:, None]
            for li in range(cfg.num_layers):
                bp = self._block_params(li)
                h = C.apply_norm(bp["attn_norm"], x, cfg.norm, cfg.norm_eps)
                q, k, v = ATT._project_qkv(
                    bp["attn"], cfg, h, h, positions, positions)
                # write the new token's KV into its page, then gather+attend
                for bi, r in enumerate(reqs):
                    self.cache.append_token(
                        li, r.seq_slot, k[bi:bi+1], v[bi:bi+1],
                        pos=lengths_np[bi])
                kp, vp, _ = self.cache.gather_kv(li, slots, max_len)
                bsz = len(reqs)
                bcast = lambda s: jnp.broadcast_to(
                    s[None], (bsz, *s.shape))
                out = ops.kv4_decode_attention(
                    q[:, 0], kp, bcast(self.cache.k_scale),
                    bcast(self.cache.k_zero), vp,
                    bcast(self.cache.v_scale), bcast(self.cache.v_zero),
                    jnp.asarray(lengths_np) + 1,
                    impl=self.quant.impl)
                out = out.reshape(bsz, 1, cfg.q_dim).astype(x.dtype)
                x = x + C.linear(bp["attn"]["wo"], out)
                h = C.apply_norm(bp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
                if "moe" in bp:
                    y, _ = MLP.moe_apply(bp["moe"], h, cfg)
                else:
                    y = MLP.mlp_apply(bp["mlp"], h, cfg.mlp_act)
                x = x + y
            hN = C.apply_norm(self.params["final_norm"], x,
                              cfg.norm, cfg.norm_eps)
            logits = np.asarray(self.lm._head(self.params, hN))
        self.cache.advance(slots)
        for bi, r in enumerate(reqs):
            tok = self._sample(logits[bi, -1], r.request_id, r.total_len)
            r.generated.append(tok)
            self.tokens_generated += 1
