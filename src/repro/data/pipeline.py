"""Deterministic, host-sharded synthetic token pipeline.

Every batch is a pure function of (seed, step, host) — the property that
makes restart-after-failure exact: a restored run at step N produces the
same remaining data stream as an uninterrupted one, with no iterator
state to checkpoint. The "dataset" is a mixture of synthetic n-gram
processes so a tiny LM has real structure to learn (benchmarks use it
for the ppl-proxy experiments).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticLMData", "batch_for_step"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    order: int = 2               # markov order of the synthetic process


class SyntheticLMData:
    """Order-k Markov chain sampler with a fixed random transition table."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = min(cfg.vocab_size, 512)       # structure lives in a sub-vocab
        self.sub_vocab = v
        # sparse-ish transition logits: each context prefers ~8 tokens
        self.table = rng.integers(0, v, size=(v, 8)).astype(np.int32)

    def batch_for_step(self, step: int) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % cfg.num_hosts == 0
        local_b = cfg.global_batch // cfg.num_hosts
        seed = (cfg.seed * 1_000_003 + step) * 4_096 + cfg.host_id
        rng = np.random.default_rng(seed)
        v = self.sub_vocab
        toks = np.empty((local_b, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, size=local_b)
        choice = rng.integers(0, 8, size=(local_b, cfg.seq_len))
        noise = rng.random((local_b, cfg.seq_len)) < 0.1
        rand_tok = rng.integers(0, v, size=(local_b, cfg.seq_len))
        for t in range(cfg.seq_len):
            nxt = self.table[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
            "mask": jnp.ones((local_b, cfg.seq_len), jnp.float32),
        }


def batch_for_step(cfg: DataConfig, step: int) -> dict:
    return SyntheticLMData(cfg).batch_for_step(step)
