"""Static analysis for the serving core's repo contracts (cometlint).

The engine's COMET-style guarantees — refcount-exact int4 page pools,
exactly-once event delivery, one-forward-per-step jit hygiene, bitwise
snapshot/restore — are conventions that reviewers have already missed at
least once each. This package machine-checks them:

- ``python -m repro.analysis.cometlint src/ tests/`` runs the AST rules
  R1 (snapshot-completeness), R2 (jit-argnum hygiene), R3 (fault-point
  coverage), R4 (exception-swallow), R5 (counter-registry drift) and
  R6 (host/device boundary) with a zero-findings CI gate.
- ``EngineConfig(sanitize=True)`` is the paired RUNTIME mode: the same
  invariants asserted live at every ``Engine.step()`` boundary
  (``serving/sanitize.py``).

``docs/invariants.md`` maps each rule to the guarantee it protects, the
historical bug that motivated it, and the recipe for adding a rule.
"""

from .rules import Finding, Project, RULES, run_rules  # noqa: F401
