"""cometlint — the repo-contract static analyzer (CLI driver).

Usage::

    PYTHONPATH=src python -m repro.analysis.cometlint src/ tests/
    PYTHONPATH=src python -m repro.analysis.cometlint --rules R1,R4 src/
    PYTHONPATH=src python -m repro.analysis.cometlint --json src/ tests/

Exit status 0 iff zero findings (the CI ``lint-cpu`` gate). The rules
(R1–R6) live in :mod:`repro.analysis.rules`; what each one protects is
catalogued in ``docs/invariants.md``. Directories named ``fixtures`` are
never scanned — that is where the deliberately-bad rule fixtures live.
"""

from __future__ import annotations

import argparse
import json
import sys

from .rules import RULES, Project, run_rules


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="cometlint",
        description="AST-based contract checks for the COMET serving "
                    "core (rules R1-R6; see docs/invariants.md)")
    ap.add_argument("paths", nargs="+",
                    help="files or directory roots to scan")
    ap.add_argument("--rules", default=None, metavar="R1,R4,...",
                    help="run only this comma-separated subset")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings report")
    args = ap.parse_args(argv)

    only = None
    if args.rules:
        only = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = sorted(set(only) - set(RULES))
        if unknown:
            ap.error(f"unknown rule(s) {unknown}; have {sorted(RULES)}")

    project = Project.from_paths(args.paths)
    findings = run_rules(project, only=only)

    if args.as_json:
        print(json.dumps({
            "files_scanned": len(project.files),
            "rules": sorted(only or RULES),
            "findings": [vars(f) for f in findings],
            "skipped": [{"path": p, "error": str(e)}
                        for p, e in project.skipped],
        }, indent=2))
    else:
        for f in findings:
            print(f.format())
        for p, e in project.skipped:
            print(f"{p}: skipped (syntax error: {e})", file=sys.stderr)
        print(f"cometlint: {len(findings)} finding(s) in "
              f"{len(project.files)} file(s) "
              f"({len(only) if only else len(RULES)} rule(s))")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
