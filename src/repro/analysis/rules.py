"""Rule registry + the R1–R6 repo-contract rules for cometlint.

Each rule is a function ``(Project) -> list[Finding]`` registered under a
stable id. Rules are pure AST/source analyses — no imports of the code
under scan — so the linter runs on a broken tree and in fixture
sandboxes. The invariant each rule protects (and the historical bug that
motivated it) is catalogued in ``docs/invariants.md``; keep the two in
sync when adding a rule.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass
from typing import Callable, Iterable, Optional

__all__ = [
    "Finding", "SourceFile", "Project", "RULES", "rule", "run_rules",
    "SNAPSHOT_CONTRACTS", "HOST_ONLY_MODULES", "COUNTER_SUFFIXES",
]

# ---------------------------------------------------------------- project

# deliberately-bad rule fixtures live under a directory literally named
# "fixtures" — they must be loadable by Project.from_paths in tests but
# must never leak into the repo-wide zero-findings gate
SKIP_DIR_NAMES = {"fixtures", "__pycache__"}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class SourceFile:
    """One parsed python file: path (posix) for reporting, ``rel`` —
    the path relative to the scan root's parent — for tree-layout
    classification (so a fixture mini-tree under tests/analysis/fixtures
    classifies by ITS OWN serving/ and tests/ directories, not by where
    the fixture happens to live in the real repo)."""

    def __init__(self, path: str, text: str, rel: Optional[str] = None):
        self.path = path.replace(os.sep, "/")
        self.rel = (rel or path).replace(os.sep, "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)

    def line(self, lineno: int) -> str:
        if 0 < lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    @property
    def basename(self) -> str:
        return self.path.rsplit("/", 1)[-1]

    @property
    def parts(self) -> tuple:
        return tuple(self.rel.split("/"))


class Project:
    """The set of files one lint run sees. Cross-file rules (R3's
    check-site/test-reference pairing, R5's serve-CLI surfacing) resolve
    against this set only — hand ``from_sources`` a self-contained
    mini-tree in fixtures."""

    def __init__(self, files: list):
        self.files = files
        self.skipped: list = []     # (path, SyntaxError) — reported, not fatal

    @classmethod
    def from_paths(cls, roots: Iterable[str]) -> "Project":
        files, skipped = [], []
        for root in roots:
            base = os.path.dirname(os.path.abspath(root.rstrip("/")))
            if os.path.isfile(root):
                paths = [root]
            else:
                paths = []
                for dirpath, dirnames, filenames in os.walk(root):
                    dirnames[:] = sorted(d for d in dirnames
                                         if d not in SKIP_DIR_NAMES)
                    paths.extend(os.path.join(dirpath, f)
                                 for f in sorted(filenames)
                                 if f.endswith(".py"))
            for p in paths:
                with open(p, "r", encoding="utf-8") as fh:
                    text = fh.read()
                rel = os.path.relpath(os.path.abspath(p), base)
                try:
                    files.append(SourceFile(p, text, rel=rel))
                except SyntaxError as e:
                    skipped.append((p, e))
        proj = cls(files)
        proj.skipped = skipped
        return proj

    @classmethod
    def from_sources(cls, pairs: Iterable[tuple]) -> "Project":
        return cls([SourceFile(path, text) for path, text in pairs])

    def serving_sources(self) -> list:
        """src-side serving modules (R3 instrumentation, R5 counters)."""
        return [f for f in self.files
                if "serving" in f.parts and "tests" not in f.parts]

    def serving_tests(self) -> list:
        return [f for f in self.files
                if "serving" in f.parts and "tests" in f.parts]

    def launch_sources(self) -> list:
        """The serve-CLI layer — R5's 'surfaced in the summary' witness."""
        return [f for f in self.files
                if "launch" in f.parts and "tests" not in f.parts]


# --------------------------------------------------------------- registry

RULES: dict = {}


def rule(rule_id: str, title: str) -> Callable:
    def deco(fn):
        fn.rule_id = rule_id
        fn.title = title
        RULES[rule_id] = fn
        return fn
    return deco


def run_rules(project: Project,
              only: Optional[Iterable[str]] = None) -> list:
    wanted = set(only) if only else None
    findings: list = []
    for rid in sorted(RULES):
        if wanted is not None and rid not in wanted:
            continue
        findings.extend(RULES[rid](project))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


# ------------------------------------------------------------ AST helpers

def _self_attr_target(node) -> Optional[str]:
    """``self.X`` as an assignment target → ``X``."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _init_attrs(cls_node: ast.ClassDef) -> dict:
    """Attrs assigned in ``__init__`` → first assignment line."""
    attrs: dict = {}
    for item in cls_node.body:
        if isinstance(item, ast.FunctionDef) and item.name == "__init__":
            for node in ast.walk(item):
                targets = []
                if isinstance(node, ast.Assign):
                    targets = node.targets
                elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                    targets = [node.target]
                for t in targets:
                    elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) \
                        else [t]
                    for elt in elts:
                        name = _self_attr_target(elt)
                        if name is not None and name not in attrs:
                            attrs[name] = node.lineno
    return attrs


def _string_collection(node) -> Optional[set]:
    """Evaluate a literal collection of strings: ``frozenset({...})``,
    ``{...}``, ``(...)``, ``[...]``. None if anything is non-literal."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("frozenset", "set", "tuple", "list"):
        if not node.args:
            return set()
        return _string_collection(node.args[0]) if len(node.args) == 1 \
            else None
    if isinstance(node, (ast.Set, ast.Tuple, ast.List)):
        out = set()
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.add(elt.value)
            else:
                return None
        return out
    return None


def _method(cls_node: ast.ClassDef, name: str):
    for item in cls_node.body:
        if isinstance(item, ast.FunctionDef) and item.name == name:
            return item
    return None


def _name_tokens(nodes) -> set:
    """Every identifier-ish token in the given ASTs: attribute names,
    plain names, function args, and string constants (snapshot blobs key
    state by name, so a dict key counts as coverage)."""
    tokens: set = set()
    for root in nodes:
        for node in ast.walk(root):
            if isinstance(node, ast.Attribute):
                tokens.add(node.attr)
            elif isinstance(node, ast.Name):
                tokens.add(node.id)
            elif isinstance(node, ast.arg):
                tokens.add(node.arg)
            elif isinstance(node, ast.Constant) and isinstance(node.value,
                                                              str):
                tokens.add(node.value)
    return tokens


def _classes(f: SourceFile):
    for node in ast.walk(f.tree):
        if isinstance(node, ast.ClassDef):
            yield node


# ------------------------------------------------------- R1: snapshot

# class name → the (snapshot, restore) method pair its attrs must reach
SNAPSHOT_CONTRACTS = {
    "Scheduler": ("snapshot", "restore"),
    "PagedKV4Cache": ("snapshot_state", "restore_state"),
    "Engine": ("snapshot", "restore"),
}


@rule("R1", "snapshot-completeness")
def r1_snapshot_completeness(project: Project) -> list:
    """Every mutable attr assigned in ``__init__`` of a snapshot-bearing
    serving class must appear in its snapshot/restore pair or in the
    class's explicit ``_SNAPSHOT_EXEMPT`` allowlist (and exempt names
    must still exist — a stale allowlist entry is itself a finding)."""
    findings = []
    for f in project.files:
        for cls in _classes(f):
            contract = SNAPSHOT_CONTRACTS.get(cls.name)
            if contract is None:
                continue
            methods = [m for m in (_method(cls, n) for n in contract) if m]
            if not methods:
                continue            # same-named helper class, no contract
            attrs = _init_attrs(cls)
            exempt: set = set()
            for item in cls.body:
                if (isinstance(item, ast.Assign)
                        and any(isinstance(t, ast.Name)
                                and t.id == "_SNAPSHOT_EXEMPT"
                                for t in item.targets)):
                    vals = _string_collection(item.value)
                    if vals is None:
                        findings.append(Finding(
                            "R1", f.path, item.lineno,
                            f"{cls.name}._SNAPSHOT_EXEMPT must be a "
                            "literal collection of attr-name strings"))
                    else:
                        exempt = vals
            covered = _name_tokens(methods)
            for name, lineno in sorted(attrs.items()):
                if name in exempt:
                    continue
                if name in covered or name.lstrip("_") in covered:
                    continue
                findings.append(Finding(
                    "R1", f.path, lineno,
                    f"{cls.name}.{name} is assigned in __init__ but "
                    f"reaches neither {'/'.join(contract)} nor "
                    f"_SNAPSHOT_EXEMPT — a restore would silently drop "
                    f"it"))
            for name in sorted(exempt - set(attrs)):
                findings.append(Finding(
                    "R1", f.path, cls.lineno,
                    f"{cls.name}._SNAPSHOT_EXEMPT lists {name!r} which "
                    f"__init__ no longer assigns — stale allowlist "
                    f"entry"))
    return findings


# ------------------------------------------------------ R2: jit argnums

@rule("R2", "jit-argnum-hygiene")
def r2_jit_argnum_hygiene(project: Project) -> list:
    """``static_argnums``/``donate_argnums`` passed to a ``jit`` call
    must not contain integer literals: positional indices silently shift
    when a parameter is added, staticizing or donating the wrong buffer.
    Derive them from parameter names (``serving.jit_args.argnums_of``
    over a declared intent list)."""
    findings = []
    for f in project.files:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            is_jit = (isinstance(func, ast.Name) and func.id == "jit") or \
                     (isinstance(func, ast.Attribute) and func.attr == "jit")
            if not is_jit:
                continue
            for kw in node.keywords:
                if kw.arg not in ("static_argnums", "donate_argnums"):
                    continue
                for sub in ast.walk(kw.value):
                    if (isinstance(sub, ast.Constant)
                            and isinstance(sub.value, int)
                            and not isinstance(sub.value, bool)):
                        findings.append(Finding(
                            "R2", f.path, kw.value.lineno,
                            f"integer literal in {kw.arg} — derive "
                            f"indices from parameter names "
                            f"(jit_args.argnums_of) so signature "
                            f"changes fail loudly"))
                        break
    return findings


# --------------------------------------------------- R3: fault coverage

def _fault_points(project: Project):
    """Evaluate FAULT_POINTS from serving/faults.py (handles the
    ``ENGINE_FAULT_POINTS + (...)`` concat). None if faults.py is not in
    this project (fixture sandboxes without it skip R3)."""
    faults_file = None
    for f in project.files:
        if f.basename == "faults.py" and "serving" in f.parts:
            faults_file = f
            break
    if faults_file is None:
        return None, None

    env: dict = {}

    def ev(node):
        if isinstance(node, ast.Tuple):
            out = []
            for elt in node.elts:
                if not (isinstance(elt, ast.Constant)
                        and isinstance(elt.value, str)):
                    return None
                out.append(elt.value)
            return tuple(out)
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
            left, right = ev(node.left), ev(node.right)
            if left is None or right is None:
                return None
            return left + right
        if isinstance(node, ast.Name):
            return env.get(node.id)
        return None

    for stmt in faults_file.tree.body:
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name) and t.id.endswith("FAULT_POINTS"):
                    val = ev(stmt.value)
                    if val is not None:
                        env[t.id] = val
    return faults_file, env.get("FAULT_POINTS")


@rule("R3", "fault-point-coverage")
def r3_fault_point_coverage(project: Project) -> list:
    """Every declared fault point needs ≥1 live ``.check("<point>")``
    instrumentation site in the serving sources and ≥1 reference in the
    serving tests — an unexercised point is chaos coverage that silently
    rotted."""
    faults_file, points = _fault_points(project)
    if faults_file is None:
        return []
    if points is None:
        return [Finding("R3", faults_file.path, 1,
                        "could not evaluate FAULT_POINTS as a literal "
                        "tuple of strings")]
    check_sites: dict = {p: 0 for p in points}
    for f in project.serving_sources():
        if f.basename == "faults.py":
            continue
        for node in ast.walk(f.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "check" and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and node.args[0].value in check_sites):
                check_sites[node.args[0].value] += 1
    test_refs: dict = {p: 0 for p in points}
    for f in project.serving_tests():
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Constant) and isinstance(node.value,
                                                            str):
                for p in points:
                    if p in node.value:
                        test_refs[p] += 1
    findings = []
    for p in points:
        if check_sites[p] == 0:
            findings.append(Finding(
                "R3", faults_file.path, 1,
                f"fault point {p!r} has no .check({p!r}) instrumentation "
                f"site in the serving sources"))
        if test_refs[p] == 0:
            findings.append(Finding(
                "R3", faults_file.path, 1,
                f"fault point {p!r} is never referenced by the serving "
                f"tests — its failure path is untested"))
    return findings


# ------------------------------------------------- R4: exception swallow

_BROAD = {"Exception", "BaseException"}


def _catches_broad(type_node) -> bool:
    nodes = type_node.elts if isinstance(type_node, ast.Tuple) \
        else [type_node]
    for n in nodes:
        if isinstance(n, ast.Name) and n.id in _BROAD:
            return True
        if isinstance(n, ast.Attribute) and n.attr in _BROAD:
            return True
    return False


@rule("R4", "exception-swallow")
def r4_exception_swallow(project: Project) -> list:
    """Bare ``except:`` is always a finding. ``except Exception`` and
    pass-only handlers need a ``# noqa: BLE001`` rationale on the except
    line — the sanctioned serving-loop backstops carry one; anything
    else is a swallowed failure waiting to corrupt state silently."""
    findings = []
    for f in project.files:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            sanctioned = "noqa: BLE001" in f.line(node.lineno)
            if node.type is None:
                findings.append(Finding(
                    "R4", f.path, node.lineno,
                    "bare except: — name the exception type (a bare "
                    "except eats KeyboardInterrupt/SystemExit too)"))
                continue
            if _catches_broad(node.type) and not sanctioned:
                findings.append(Finding(
                    "R4", f.path, node.lineno,
                    "except Exception without a '# noqa: BLE001' "
                    "rationale — narrow the type or annotate why the "
                    "backstop is sanctioned"))
                continue
            if (len(node.body) == 1 and isinstance(node.body[0], ast.Pass)
                    and not sanctioned):
                findings.append(Finding(
                    "R4", f.path, node.lineno,
                    "except-with-pass body silently swallows the "
                    "failure — handle it, count it, or annotate a "
                    "'# noqa: BLE001' rationale"))
    return findings


# ------------------------------------------------ R5: counter registry

COUNTER_SUFFIXES = ("_count", "_counts", "_errors")

# a counter is "surfaced" if it reaches one of these same-class reporting
# methods, or the serve-CLI summary (any attribute/string mention under
# launch/)
_SURFACE_METHODS = ("counters", "snapshot", "snapshot_state", "summary",
                    "stats")


@rule("R5", "counter-registry-drift")
def r5_counter_registry_drift(project: Project) -> list:
    """Every ``self.*_count``-style counter incremented in serving/ must
    be declared/reset in ``__init__`` and surfaced through the class's
    own reporting methods or the serve-CLI summary — an unsurfaced
    counter is observability that silently drifted out of the reports."""
    launch_tokens: set = set()
    for f in project.launch_sources():
        launch_tokens |= _name_tokens([f.tree])
    findings = []
    for f in project.serving_sources():
        for cls in _classes(f):
            surface_nodes = [m for m in (_method(cls, n)
                                         for n in _SURFACE_METHODS) if m]
            surface_tokens = _name_tokens(surface_nodes)
            init_names = set(_init_attrs(cls))
            seen: set = set()
            for node in ast.walk(cls):
                if not (isinstance(node, ast.AugAssign)
                        and isinstance(node.op, ast.Add)):
                    continue
                name = _self_attr_target(node.target)
                if name is None or name in seen \
                        or not name.endswith(COUNTER_SUFFIXES):
                    continue
                seen.add(name)
                if name not in init_names:
                    findings.append(Finding(
                        "R5", f.path, node.lineno,
                        f"counter {cls.name}.{name} is incremented but "
                        f"never declared/reset in __init__"))
                    continue
                if name not in surface_tokens and name not in launch_tokens:
                    findings.append(Finding(
                        "R5", f.path, node.lineno,
                        f"counter {cls.name}.{name} is never surfaced — "
                        f"add it to {cls.name}.counters()/snapshot or "
                        f"the serve-CLI summary"))
    return findings


# ---------------------------------------------- R6: host/device boundary

# serving modules that must stay pure-host: they run inside the step's
# failure-isolation boundary and in restore paths where no device (or a
# different device topology) is present
HOST_ONLY_MODULES = ("scheduler.py", "faults.py", "recovery.py",
                     "speculation.py")


@rule("R6", "host-device-boundary")
def r6_host_device_boundary(project: Project) -> list:
    """No jax/jnp in the host-only serving modules, and no builtin
    ``hash()`` anywhere — per-process hash seeding makes it
    irreproducible across restarts and it is forgeable (the prefix cache
    keys KV pages by content; a collision would serve another prompt's
    KV). Use hashlib digests."""
    findings = []
    for f in project.files:
        host_only = f.basename in HOST_ONLY_MODULES and "serving" in f.parts
        for node in ast.walk(f.tree):
            if host_only and isinstance(node, (ast.Import, ast.ImportFrom)):
                mods = [a.name for a in node.names] \
                    if isinstance(node, ast.Import) \
                    else [node.module or ""]
                for m in mods:
                    if m == "jax" or m.startswith("jax."):
                        findings.append(Finding(
                            "R6", f.path, node.lineno,
                            f"host-only module imports {m!r} — device "
                            f"work belongs in engine/kv_cache, behind "
                            f"the step isolation boundary"))
            if host_only and isinstance(node, ast.Name) \
                    and node.id in ("jnp", "jax") \
                    and isinstance(node.ctx, ast.Load):
                findings.append(Finding(
                    "R6", f.path, node.lineno,
                    f"host-only module uses {node.id!r} — no device "
                    f"array ops in {f.basename}"))
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "hash"):
                findings.append(Finding(
                    "R6", f.path, node.lineno,
                    "builtin hash() is process-seeded and forgeable — "
                    "key content with hashlib (see "
                    "PagedKV4Cache._page_keys)"))
    return findings
