"""Training step construction: chunked cross-entropy loss, remat, and the
pjit-ready ``train_step`` used by both the launcher and the dry-run.

The vocabulary-chunked loss never materializes the full [B, S, V] logits
tensor: the final projection + softmax-CE run per sequence chunk inside a
rematerialized scan, keeping the live logits buffer at [B, chunk, V] —
the difference between fitting and OOM for 150k-vocab × 4k-seq training.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.lm import LM
from repro.training import optimizer as OPT

__all__ = ["cross_entropy", "chunked_lm_loss", "make_train_step",
           "make_loss_fn"]


def cross_entropy(logits, labels, mask=None):
    """logits [..., V] f32, labels [...] int32 → mean CE (masked)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = logz - gold
    if mask is not None:
        ce = ce * mask
        return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(ce)


def chunked_lm_loss(lm: LM, params, hidden, labels, mask=None,
                    chunk: int = 512):
    """hidden [B, S, D] (post final-norm) → scalar CE without full logits."""
    b, s, _ = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    mask_full = mask if mask is not None else jnp.ones((b, s), jnp.float32)
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask_full = jnp.pad(mask_full, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // chunk
    hc = jnp.moveaxis(hidden.reshape(b, nc, chunk, -1), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)
    mc = jnp.moveaxis(mask_full.reshape(b, nc, chunk), 1, 0)

    def body(carry, xs):
        tot, cnt = carry
        h, l, m = xs
        logits = lm._head(params, h)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        ce = (logz - gold) * m
        return (tot + jnp.sum(ce), cnt + jnp.sum(m)), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def make_loss_fn(lm: LM, *, loss_chunk: int = 512):
    def loss_fn(params, batch):
        extra = {k: batch[k] for k in ("frames", "image_embeds")
                 if k in batch} or None
        hidden, aux = lm.train_hidden(params, batch["tokens"], extra)
        ce = chunked_lm_loss(lm, params, hidden, batch["labels"],
                             batch.get("mask"), chunk=loss_chunk)
        return ce + aux, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(lm: LM, opt_cfg: OPT.AdamWConfig, *,
                    loss_chunk: int = 512):
    """Build ``train_step(params, opt_state, batch) → (params, state, metrics)``.

    batch: {"tokens": [B, S] int32, "labels": [B, S] int32,
            optional "mask": [B, S] f32, optional "frames"/"image_embeds"}.
    """
    loss_fn = make_loss_fn(lm, loss_chunk=loss_chunk)

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = OPT.adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step
