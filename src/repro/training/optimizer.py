"""AdamW + global-norm clipping + LR schedules, from scratch (no optax).

State is a pytree mirroring params (m, v) plus a scalar step — shards
exactly like the params (ZeRO: optimizer state inherits the FSDP specs).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Callable | None = None   # step → lr multiplier


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """→ (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    lr = cfg.lr
    if cfg.schedule is not None:
        lr = lr * cfg.schedule(step)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                     state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        mhat = m_ / bc1
        vhat = v_ / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, {"m": m, "v": v, "step": step}, metrics


def cosine_schedule(warmup: int, total: int, min_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(1.0, float(warmup))
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, float(total - warmup)),
                        0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn
