"""Gradient compression for cross-pod data parallelism.

At 1000+-node scale the pod-level gradient all-reduce crosses the
slowest links (DCN between pods), so the cross-pod sync is the natural
compression point: pods reduce-scatter full-precision *within* the pod
(ICI), then exchange **int8-compressed** gradients *across* pods with
error feedback (the residual of each step's quantization is added back
into the next step's gradient — the standard convergence-preserving
trick from 1-bit SGD / EF-SGD).

Integration: `make_compressed_train_step` wraps a loss the same way as
`train_loop.make_train_step` but inserts compress→(sum across pods)→
decompress at the gradient boundary with `jax.lax.psum` when a "pod"
mesh axis is present (under shard_map/pjit the psum lowers onto the pod
axis); on a pod-less mesh the compression still runs (useful for tests
and for measuring the accuracy impact) and the sum is the identity.

The compression itself is mesh-agnostic and unit-tested directly:
int8 per-tensor symmetric with f32 scale → 4× fewer bytes on the wire
(4 bytes → 1 byte per element), error feedback preserving convergence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.training import optimizer as OPT

__all__ = [
    "compress_tensor",
    "decompress_tensor",
    "compress_grads",
    "init_error_feedback",
    "make_compressed_train_step",
]


def compress_tensor(g: jax.Array):
    """f32 tensor → (int8 payload, f32 scale). Symmetric absmax."""
    g = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_tensor(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_grads(grads, ef_state):
    """Error-feedback int8 compression over a gradient pytree.

    Returns (compressed tree of (int8, scale), new ef_state). The error
    (g + e) − dequant(quant(g + e)) carries to the next step.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_tensor(corrected)
        new_e = corrected - decompress_tensor(q, s)
        return (q, s), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    qs, es = [], []
    for g, e in zip(flat_g, flat_e):
        (q, s), ne = one(g, e)
        qs.append((q, s))
        es.append(ne)
    return jax.tree.unflatten(treedef, qs), jax.tree.unflatten(treedef, es)


def _psum_pod(tree):
    """Mean over the pod axis if present in the ambient mesh, else id."""
    from repro.parallel.sharding import _ambient_mesh
    mesh = _ambient_mesh()
    if mesh is None or "pod" not in getattr(mesh, "axis_names", ()):
        return tree
    # under pjit, gradients are already globally reduced by SPMD; the
    # explicit cross-pod exchange is exercised through shard_map in the
    # launcher. Here the compressed payloads stand in for the wire format.
    return tree


def make_compressed_train_step(lm, opt_cfg: OPT.AdamWConfig, *,
                               loss_chunk: int = 512):
    """train_step with int8+EF gradient compression at the DP boundary.

    Signature: step(params, opt_state, ef_state, batch) →
               (params, opt_state, ef_state, metrics).
    """
    from repro.training.train_loop import make_loss_fn
    loss_fn = make_loss_fn(lm, loss_chunk=loss_chunk)

    def step(params, opt_state, ef_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        compressed, ef_state = compress_grads(grads, ef_state)
        compressed = _psum_pod(compressed)
        grads = jax.tree.map(
            lambda qs: decompress_tensor(*qs), compressed,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and hasattr(x[0], "dtype"))
        params, opt_state, om = OPT.adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, ef_state, metrics

    return step
