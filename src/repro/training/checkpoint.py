"""Fault-tolerant sharded checkpointing (no orbax).

Layout (mesh-agnostic — reshardable on restore to any divisor mesh):

  <dir>/step_<N>/
      manifest.json        tree structure, dtypes, shapes, step, PRNG key
      arr_<idx>.npy        one .npy per leaf (host-gathered logical array)
      _COMPLETE            atomic commit marker (written last)

Design points for 1000+-node operation:
* atomic commit: writers stage into ``step_<N>.tmp`` then ``rename`` —
  a crash mid-save never corrupts the latest valid checkpoint;
* restore scans for the newest ``_COMPLETE``-marked step (auto-recovery
  after preemption);
* async save: ``save_async`` snapshots device arrays then writes on a
  background thread so the train loop is not blocked;
* keep-last-K garbage collection.

On a real multi-host fleet each host writes only its addressable shards;
here (single host) the gather is the identity. The manifest format keeps
per-leaf logical shapes so loading under a different mesh simply applies
the new NamedSharding at ``device_put`` time.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "cleanup"]


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _tree_paths(treedef):
    return str(treedef)


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None):
    """Blocking checkpoint write with atomic commit."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
        if hasattr(jax.tree_util.tree_structure(tree), "serialize_using_proto")
        else None,
        "num_leaves": len(leaves),
        "extra": extra or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), arr)
        manifest["leaves"].append(
            {"dtype": str(arr.dtype), "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMPLETE"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


_ASYNC_THREAD: Optional[threading.Thread] = None


def save_async(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None):
    """Snapshot to host, then write in a background thread."""
    global _ASYNC_THREAD
    wait_async()
    host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
    _ASYNC_THREAD = threading.Thread(
        target=save, args=(ckpt_dir, step, host_tree, extra), daemon=True)
    _ASYNC_THREAD.start()
    return _ASYNC_THREAD


def wait_async():
    global _ASYNC_THREAD
    if _ASYNC_THREAD is not None:
        _ASYNC_THREAD.join()
        _ASYNC_THREAD = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "_COMPLETE")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, template, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``template``.

    ``shardings``: optional matching tree of NamedShardings — arrays are
    device_put with them (elastic resharding across mesh changes).
    Returns (tree, extra_dict, step).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_t, treedef = jax.tree.flatten(template)
    assert len(leaves_t) == manifest["num_leaves"], (
        f"checkpoint has {manifest['num_leaves']} leaves, template "
        f"{len(leaves_t)}")
    sh_leaves = (jax.tree.flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves_t))
    out = []
    for i, (tmpl, sh) in enumerate(zip(leaves_t, sh_leaves)):
        arr = np.load(os.path.join(path, f"arr_{i:05d}.npy"))
        assert list(arr.shape) == list(tmpl.shape), (
            f"leaf {i}: ckpt {arr.shape} vs template {tmpl.shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    tree = jax.tree.unflatten(treedef, out)
    return tree, manifest.get("extra", {}), step


def cleanup(ckpt_dir: str, keep_last: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, n, "_COMPLETE")))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
