"""QLinear — the W4Ax linear layer (COMET's serving-path projection).

Offline: :func:`quantize_linear` turns a fp [K, N] weight into the packed
W4 payload using an FMPQ plan (channel permutation + tail-clustered INT8
blocks). Online: :func:`qlinear_apply` permutes the incoming activation,
quantizes the INT4/INT8 channel ranges on the fly (fused act-quant
kernel), and runs the W4Ax GEMM.

Scan-compatibility: inside `lax.scan` over layers every layer must share
K4, so the model-level serving path uses a *global* int4 fraction
(config knob, default 0.875 ≈ the paper's measured 84–92 % W4A4). The
per-layer calibrated plans are used by the (non-scanned) serving engine
and the accuracy benchmarks.
"""

from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import fmpq
from repro.core import quantizer as Q
from repro.kernels import ops
from repro.layers import common as _common
from repro.layers.common import Annotated

BLOCK_K = fmpq.BLOCK_K

__all__ = [
    "QLinearSpec",
    "QuantRuntime",
    "quant_runtime",
    "quantize_linear",
    "qlinear_apply",
    "quantize_linear_fraction",
]


@dataclasses.dataclass(frozen=True)
class QuantRuntime:
    """Trace-time knobs for dispatched quantized projections."""

    int4_fraction: float = 0.875
    schedule: str = "split"
    impl: str = "auto"
    weight_only: bool = False    # W4A16: dequantize W, keep activations fp


_ACTIVE_RUNTIME = QuantRuntime()


@contextlib.contextmanager
def quant_runtime(rt: QuantRuntime):
    """Set the active runtime while tracing a quantized model."""
    global _ACTIVE_RUNTIME
    prev = _ACTIVE_RUNTIME
    _ACTIVE_RUNTIME = rt
    try:
        yield
    finally:
        _ACTIVE_RUNTIME = prev


@dataclasses.dataclass(frozen=True)
class QLinearSpec:
    """Static metadata for one quantized projection (not traced)."""

    k: int
    n: int
    k4: int                      # leading channels in W4A4 (multiple of 128)
    has_perm: bool = True
    schedule: str = "split"      # split | mixed (paper baseline)
    impl: str = "auto"

    @property
    def k8(self) -> int:
        return self.k - self.k4


def quantize_linear(
    w: jax.Array,
    plan: fmpq.FMPQPlan,
    config: fmpq.FMPQConfig = fmpq.FMPQConfig(),
    *,
    schedule: str = "split",
    impl: str = "auto",
):
    """fp [K, N] weight + calibrated plan → (qparams, spec)."""
    k, n = w.shape
    qt = fmpq.apply_fmpq_to_weight(w, plan, config)
    qparams = {
        "w_packed": Annotated(qt.data, ("embed", "mlp")),
        "w_scale": Annotated(qt.scale, ("embed", "mlp")),
        "perm": Annotated(jnp.asarray(plan.perm, jnp.int32), ("embed",)),
    }
    spec = QLinearSpec(k=k, n=n, k4=plan.k4, has_perm=True,
                       schedule=schedule, impl=impl)
    return qparams, spec


def quantize_linear_fraction(
    w: jax.Array,
    int4_fraction: float = 0.875,
    config: fmpq.FMPQConfig = fmpq.FMPQConfig(),
    *,
    schedule: str = "split",
    impl: str = "auto",
):
    """Plan-free variant with a fixed INT4 fraction (scan-uniform).

    Channels are ordered by a synthetic identity permutation; the INT8
    tail covers the trailing ceil((1-f)·K/128) blocks. Used for the
    scanned dry-run serving path where per-layer calibration data is not
    part of the lowering.
    """
    k, n = w.shape
    nb = k // BLOCK_K
    nb4 = int(round(int4_fraction * nb))
    nb4 = max(0, min(nb, nb4))
    qt = Q.quantize_weight_int4(w, group_size=config.weight_group_size,
                                clip_ratio=config.weight_clip_ratio)
    qparams = {
        "w_packed": Annotated(qt.data, ("embed", "mlp")),
        "w_scale": Annotated(qt.scale, ("embed", "mlp")),
    }
    spec = QLinearSpec(k=k, n=n, k4=nb4 * BLOCK_K, has_perm=False,
                       schedule=schedule, impl=impl)
    return qparams, spec


def qlinear_apply(spec: QLinearSpec, qparams, x: jax.Array,
                  out_dtype=None) -> jax.Array:
    """x: [..., K] float → [..., N] (activation dtype preserved).

    ``out_dtype`` overrides the output cast only — the act-quant still
    sees ``x`` in its own dtype, so the int4/int8 codes are unchanged.
    Tensor-parallel callers use this to keep the f32 partial sum exact
    across the all-reduce before the single bf16 rounding.
    """
    in_dtype = x.dtype
    if spec.has_perm:
        x = jnp.take(x, qparams["perm"], axis=-1)
    x4 = x[..., : spec.k4]
    x8 = x[..., spec.k4 :]

    lead = x.shape[:-1]
    if spec.k4 > 0:
        a4, s4 = ops.act_quant(x4, bits=4, impl=spec.impl)
    else:
        a4 = jnp.zeros((*lead, 0), jnp.uint8)
        s4 = jnp.zeros((*lead, 0), jnp.float32)
    if spec.k8 > 0:
        a8, s8 = ops.act_quant(x8, bits=8, impl=spec.impl)
    else:
        a8 = jnp.zeros((*lead, 0), jnp.int8)
        s8 = jnp.zeros((*lead, 0), jnp.float32)

    out = ops.w4ax_matmul(
        a4, s4, a8, s8,
        qparams["w_packed"], qparams["w_scale"],
        schedule=spec.schedule, impl=spec.impl,
    )
    if "b" in qparams:
        out = out + qparams["b"]
    return out.astype(out_dtype if out_dtype is not None else in_dtype)


# ---------------------------------------------------------------------------
# C.linear dispatch handler: any params dict carrying "w_packed" routes here
# ---------------------------------------------------------------------------

def _dispatch_qlinear(params, x, out_dtype=None):
    rt = _ACTIVE_RUNTIME
    kp = params["w_packed"].shape[-2]
    k = 2 * kp
    if rt.weight_only:
        # W4A16 baseline (AWQ/OmniQuant style): dequantize-to-bf16 GEMM
        w = Q.unpack_int4_interleaved(
            params["w_packed"], axis=-2, block_size=BLOCK_K
        ).astype(jnp.float32)
        n = w.shape[-1]
        scale = jnp.repeat(params["w_scale"], BLOCK_K, axis=-2)
        w = (w * scale).astype(jnp.bfloat16)
        out = x.astype(jnp.bfloat16) @ w
        if "b" in params:
            out = out + params["b"].astype(jnp.bfloat16)
        return out.astype(out_dtype) if out_dtype is not None else out
    nb = k // BLOCK_K
    nb4 = max(0, min(nb, int(round(rt.int4_fraction * nb))))
    spec = QLinearSpec(
        k=k, n=params["w_packed"].shape[-1], k4=nb4 * BLOCK_K,
        has_perm="perm" in params, schedule=rt.schedule, impl=rt.impl,
    )
    return qlinear_apply(spec, params, x, out_dtype=out_dtype)


_common.register_quant_linear(_dispatch_qlinear)
