"""FMPQ — Fine-grained Mixed-Precision Quantization (COMET §3).

The algorithm, faithful to the paper:

1. **Calibration** — run sample prompts through the fp model, collect
   per-channel absolute-maximum statistics of every linear layer's input
   activation (`collect_channel_stats`).
2. **Outlier identification** — channels whose absmax exceeds
   ``outlier_threshold × median(absmax)`` are outliers (§3.1: outliers
   concentrate in a small set of channels, can be 10–100× typical values).
3. **Channel permutation** (§3.2, Fig. 4d) — sort channels so outlier
   channels cluster into the *trailing* K-blocks. The weight matrix rows
   are permuted identically, keeping the GEMM exact. Clustering at the
   tail means the INT8 blocks are contiguous, which the TPU kernel
   exploits by splitting into uniform-precision sub-GEMMs (DESIGN.md §2).
4. **Block precision assignment** — any 128-channel block containing an
   outlier channel → INT8, else INT4. The paper reports ≤20 % INT8 blocks
   after permutation (≥84 % of GEMM compute in W4A4).

The result is a static :class:`FMPQPlan` per linear layer, produced
offline and applied at serving time with zero per-step overhead beyond
the (cheap, fused) activation permute — the paper measures permutation
at 0.7 % of runtime.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantizer as Q

__all__ = [
    "FMPQConfig",
    "FMPQPlan",
    "collect_channel_stats",
    "identify_outlier_channels",
    "make_permutation",
    "assign_block_precision",
    "plan_fmpq",
    "apply_fmpq_to_weight",
    "quantize_activation_mixed",
    "int4_block_fraction",
]

BLOCK_K = 128  # COMET block size k (§3.2): matches MXU/tensor-core granularity


@dataclasses.dataclass(frozen=True)
class FMPQConfig:
    block_size: int = BLOCK_K
    outlier_threshold: float = 8.0   # absmax > thr × median → outlier channel
    act_clip_ratio: float = 1.0
    weight_clip_ratio: float = 1.0
    weight_group_size: int = 128     # OmniQuant-style W4 group quant
    max_int8_fraction: float = 1.0   # optional cap on INT8 block fraction


@dataclasses.dataclass(frozen=True)
class FMPQPlan:
    """Static per-layer quantization plan (offline artifact).

    perm:        [K] int32 — channel permutation (applied to activation
                 columns and weight rows).
    inv_perm:    [K] int32 — inverse permutation.
    block_bits:  [K/block] int8 — 4 or 8 per K-block, after permutation.
                 INT8 blocks are contiguous at the tail.
    num_int4_blocks: static int — blocks [0, num_int4_blocks) are INT4.
    """

    perm: np.ndarray
    inv_perm: np.ndarray
    block_bits: np.ndarray
    num_int4_blocks: int
    block_size: int

    @property
    def k(self) -> int:
        return self.perm.shape[0]

    @property
    def num_blocks(self) -> int:
        return self.block_bits.shape[0]

    @property
    def k4(self) -> int:
        """Number of leading channels quantized to INT4."""
        return self.num_int4_blocks * self.block_size

    @property
    def int4_fraction(self) -> float:
        return self.num_int4_blocks / max(1, self.num_blocks)


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

def collect_channel_stats(activations: jax.Array) -> jax.Array:
    """Per-channel absmax over a calibration batch. activations: [..., K]."""
    flat = activations.reshape(-1, activations.shape[-1])
    return jnp.max(jnp.abs(flat), axis=0)


def identify_outlier_channels(
    channel_absmax: np.ndarray, threshold: float = 8.0
) -> np.ndarray:
    """Boolean mask of outlier channels: absmax > threshold × median."""
    absmax = np.asarray(channel_absmax, dtype=np.float64)
    med = np.median(absmax)
    if med <= 0:
        med = np.mean(absmax) + 1e-12
    return absmax > threshold * med


def make_permutation(outlier_mask: np.ndarray, channel_absmax: np.ndarray) -> np.ndarray:
    """Permutation clustering outlier channels at the tail (Fig. 4d).

    Within each group, order by ascending absmax so that the boundary
    block (the one straddling normal/outlier, if any) contains the least
    extreme channels possible.
    """
    absmax = np.asarray(channel_absmax, dtype=np.float64)
    order = np.argsort(absmax, kind="stable")
    normal = [i for i in order if not outlier_mask[i]]
    outlier = [i for i in order if outlier_mask[i]]
    return np.asarray(normal + outlier, dtype=np.int32)


def assign_block_precision(
    outlier_mask_permuted: np.ndarray, block_size: int
) -> np.ndarray:
    """Per-block bits: 8 if the block contains any outlier channel, else 4."""
    k = outlier_mask_permuted.shape[0]
    if k % block_size != 0:
        raise ValueError(f"K={k} not divisible by block={block_size}")
    blocks = outlier_mask_permuted.reshape(-1, block_size)
    return np.where(blocks.any(axis=1), 8, 4).astype(np.int8)


def plan_fmpq(
    channel_absmax,
    config: FMPQConfig = FMPQConfig(),
) -> FMPQPlan:
    """Build the full offline FMPQ plan from calibration statistics."""
    absmax = np.asarray(channel_absmax)
    k = absmax.shape[0]
    if k % config.block_size != 0:
        raise ValueError(f"K={k} not divisible by block={config.block_size}")
    mask = identify_outlier_channels(absmax, config.outlier_threshold)

    # Optionally cap the INT8 fraction by raising the effective threshold:
    # keep only the most extreme outliers if the cap would be exceeded.
    max_outlier_channels = int(config.max_int8_fraction * k)
    if mask.sum() > max_outlier_channels:
        order = np.argsort(absmax)[::-1]
        keep = order[:max_outlier_channels]
        mask = np.zeros(k, dtype=bool)
        mask[keep] = True

    perm = make_permutation(mask, absmax)
    inv_perm = np.empty_like(perm)
    inv_perm[perm] = np.arange(k, dtype=np.int32)
    block_bits = assign_block_precision(mask[perm], config.block_size)
    # After tail-clustering, bits are monotone: 4,...,4,8,...,8.
    num_int4 = int((block_bits == 4).sum())
    assert (block_bits[:num_int4] == 4).all() and (block_bits[num_int4:] == 8).all(), (
        "permutation must cluster INT8 blocks contiguously at the tail"
    )
    return FMPQPlan(
        perm=perm,
        inv_perm=inv_perm,
        block_bits=block_bits,
        num_int4_blocks=num_int4,
        block_size=config.block_size,
    )


# ---------------------------------------------------------------------------
# Applying a plan
# ---------------------------------------------------------------------------

def apply_fmpq_to_weight(
    w: jax.Array, plan: FMPQPlan, config: FMPQConfig = FMPQConfig()
):
    """Permute weight rows by the plan and quantize to packed int4.

    w: [K, N] → QuantizedTensor with interleaved packed data [K/2, N] and
    group scales [K/group, N]. Weight stays int4 for *all* blocks (W4Ax:
    only activations are mixed-precision).
    """
    w_perm = w[jnp.asarray(plan.perm), :]
    return Q.quantize_weight_int4(
        w_perm,
        group_size=config.weight_group_size,
        clip_ratio=config.weight_clip_ratio,
    )


def quantize_activation_mixed(
    x: jax.Array, plan: FMPQPlan, config: FMPQConfig = FMPQConfig()
):
    """Permute activation columns and quantize blocks to mixed int4/int8.

    x: [M, K] →
      q:     [M, K] int8 — INT4 blocks hold values in [-8, 7], INT8 blocks
             in [-128, 127] (uniform int8 container; the *kernel* consumes
             a packed layout, see kernels/ops.py).
      scale: [M, K/block] float32 per-(row, block) scales.
    The per-block bit-width follows ``plan.block_bits``; because blocks
    are tail-clustered this is a static split at column plan.k4.
    """
    m, k = x.shape
    bs = plan.block_size
    xp = x[:, jnp.asarray(plan.perm)]
    k4 = plan.k4
    parts_q = []
    parts_s = []
    if k4 > 0:
        q4, s4 = Q.quantize_act_groupwise(
            xp[:, :k4], block_size=bs, bits=4, clip_ratio=config.act_clip_ratio
        )
        parts_q.append(q4)
        parts_s.append(s4)
    if k4 < k:
        q8, s8 = Q.quantize_act_groupwise(
            xp[:, k4:], block_size=bs, bits=8, clip_ratio=config.act_clip_ratio
        )
        parts_q.append(q8)
        parts_s.append(s8)
    q = jnp.concatenate(parts_q, axis=1) if len(parts_q) > 1 else parts_q[0]
    s = jnp.concatenate(parts_s, axis=1) if len(parts_s) > 1 else parts_s[0]
    return q, s


def int4_block_fraction(plan: FMPQPlan) -> float:
    """Fraction of K-blocks (== fraction of GEMM MACs) computed in W4A4."""
    return plan.int4_fraction
