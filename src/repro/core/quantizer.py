"""Low-bit quantization primitives for COMET (W4 / A4 / A8 / KV4).

Conventions
-----------
* INT4 values live in [-8, 7]. They are stored *biased* by +8 as unsigned
  nibbles in [0, 15], two per uint8 byte, so that the in-kernel unpack can
  use the paper's zero-extension trick (COMET §4.3): a mask and a logical
  shift produce both nibbles; the -8 bias is folded into either a single
  subtract or, in the optimized GEMM path, into a per-block correction
  term ``-8 * sum_k(a_k)`` applied once per accumulation block.
* INT8 values live in [-128, 127] and are stored as plain int8.
* Scales are float32. Activation/group scales are per-(row, K-block);
  weight scales are per-(output-channel,) or per-(K-block, output-channel)
  for group quantization.

All functions are jittable and differentiable-free (PTQ only).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

INT4_MIN = -8
INT4_MAX = 7
INT4_BIAS = 8  # stored nibble = q + 8  in [0, 15]
INT8_MIN = -128
INT8_MAX = 127

__all__ = [
    "INT4_MIN",
    "INT4_MAX",
    "INT4_BIAS",
    "QuantizedTensor",
    "absmax_scale",
    "asym_scale_zero",
    "quantize_int4",
    "quantize_int8",
    "dequantize_int4",
    "dequantize_int8",
    "pack_int4",
    "unpack_int4",
    "pack_int4_interleaved",
    "unpack_int4_interleaved",
    "quantize_weight_int4",
    "quantize_act_groupwise",
    "quantize_kv_channelwise",
    "dequantize_kv_channelwise",
]


class QuantizedTensor(NamedTuple):
    """A quantized tensor with its dequantization metadata.

    ``data``  packed uint8 (int4, two nibbles/byte) or int8 payload.
    ``scale`` float32 scales, broadcastable against the logical shape.
    ``zero``  float32 zero-points (asymmetric) or None-like zeros.
    ``bits``  4 or 8.
    ``shape`` logical (unpacked) shape.
    """

    data: jax.Array
    scale: jax.Array
    zero: jax.Array
    bits: int
    shape: tuple


# ---------------------------------------------------------------------------
# Scale computation
# ---------------------------------------------------------------------------

def absmax_scale(x: jax.Array, axis, bits: int, clip_ratio: float = 1.0) -> jax.Array:
    """Symmetric scale s.t. clip_ratio*absmax maps to the max quant level."""
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    amax = jnp.maximum(amax * clip_ratio, 1e-8)
    return (amax / qmax).astype(jnp.float32)


def asym_scale_zero(x: jax.Array, axis, bits: int):
    """Asymmetric scale/zero-point: x ≈ (q - zero) * scale, q in [0, 2^b-1]."""
    qmax = float(2**bits - 1)
    xmin = jnp.min(x, axis=axis, keepdims=True)
    xmax = jnp.max(x, axis=axis, keepdims=True)
    scale = jnp.maximum((xmax - xmin) / qmax, 1e-8).astype(jnp.float32)
    zero = jnp.round(-xmin / scale)
    return scale, zero.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Elementwise quant / dequant
# ---------------------------------------------------------------------------

def quantize_int4(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Symmetric int4 quantization → int8 array of values in [-8, 7]."""
    q = jnp.clip(jnp.round(x / scale), INT4_MIN, INT4_MAX)
    return q.astype(jnp.int8)


def quantize_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.clip(jnp.round(x / scale), INT8_MIN, INT8_MAX)
    return q.astype(jnp.int8)


def dequantize_int4(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# INT4 packing — two nibbles per byte, biased storage (zero-extension trick)
# ---------------------------------------------------------------------------

def pack_int4(q: jax.Array, axis: int = -1) -> jax.Array:
    """Pack int4 values (int8 storage, [-8,7]) into uint8 bytes along ``axis``.

    Byte ``j`` holds logical elements ``2j`` (low nibble) and ``2j+1``
    (high nibble), each stored biased by +8 → unsigned [0, 15]. The packed
    axis length must be even.
    """
    axis = axis % q.ndim
    if q.shape[axis] % 2 != 0:
        raise ValueError(f"pack axis length {q.shape[axis]} must be even")
    biased = (q.astype(jnp.int32) + INT4_BIAS).astype(jnp.uint8)
    lo = jax.lax.slice_in_dim(biased, 0, None, stride=2, axis=axis)
    hi = jax.lax.slice_in_dim(biased, 1, None, stride=2, axis=axis)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array, axis: int = -1) -> jax.Array:
    """Inverse of :func:`pack_int4` → int8 values in [-8, 7].

    The cheap path: ``lo = b & 0xF`` , ``hi = b >> 4`` (logical shift on
    uint8), then one bias subtract. This is the COMET §4.3 fast conversion
    adapted to the TPU VPU — 2 vector ops per byte for the nibble
    extraction; the bias is folded away entirely inside the GEMM kernel.
    """
    axis = axis % packed.ndim
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.int8)
    hi = (packed >> jnp.uint8(4)).astype(jnp.int8)
    out = jnp.stack([lo, hi], axis=axis + 1)
    new_shape = list(packed.shape)
    new_shape[axis] = packed.shape[axis] * 2
    out = out.reshape(new_shape)
    return out - jnp.int8(INT4_BIAS)


def unpack_int4_biased(packed: jax.Array, axis: int = -1) -> jax.Array:
    """Unpack to *biased* unsigned nibbles [0,15] as int8 — no bias subtract.

    Used by the optimized GEMM: dot(a, q_biased) - 8*sum(a) == dot(a, q).
    """
    axis = axis % packed.ndim
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.int8)
    hi = (packed >> jnp.uint8(4)).astype(jnp.int8)
    out = jnp.stack([lo, hi], axis=axis + 1)
    new_shape = list(packed.shape)
    new_shape[axis] = packed.shape[axis] * 2
    return out.reshape(new_shape)


def pack_int4_interleaved(
    q: jax.Array, axis: int = 0, block_size: int | None = None
) -> jax.Array:
    """COMET weight-interleave layout (§4.3 Fig. 6) — the *location switch*.

    Within each contiguous block of ``block_size`` elements along ``axis``
    (default: the whole axis), byte ``j`` holds elements ``j`` (low nibble)
    and ``j + block_size/2`` (high nibble) — rather than ``2j``, ``2j+1``.
    After the cheap nibble split the kernel obtains two contiguous
    half-block panels that concatenate back in order with **no**
    element-interleave shuffle — the VPU analogue of the paper's layout
    that avoids `ldmatrix` bank conflicts. Using ``block_size`` equal to
    the quantization block (128) keeps every packed tile self-contained
    so BlockSpec tiling along K never splits a byte's two nibbles across
    tiles.
    """
    axis = axis % q.ndim
    k = q.shape[axis]
    bs = k if block_size is None else block_size
    if bs % 2 != 0 or k % bs != 0:
        raise ValueError(f"axis length {k} must tile into even blocks of {bs}")
    biased = (q.astype(jnp.int32) + INT4_BIAS).astype(jnp.uint8)
    # [pre, k, post] -> [pre, nb, bs, post] -> split halves -> pack
    moved = jnp.moveaxis(biased, axis, 0)
    nb = k // bs
    moved = moved.reshape(nb, bs, *moved.shape[1:])
    lo = moved[:, : bs // 2]
    hi = moved[:, bs // 2 :]
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    packed = packed.reshape(nb * (bs // 2), *packed.shape[2:])
    return jnp.moveaxis(packed, 0, axis)


def unpack_int4_interleaved(
    packed: jax.Array, axis: int = 0, block_size: int | None = None
) -> jax.Array:
    """Inverse of :func:`pack_int4_interleaved` → int8 [-8,7]."""
    axis = axis % packed.ndim
    kp = packed.shape[axis]
    bsh = kp if block_size is None else block_size // 2
    if kp % bsh != 0:
        raise ValueError(f"packed axis {kp} must tile into blocks of {bsh}")
    moved = jnp.moveaxis(packed, axis, 0)
    nb = kp // bsh
    moved = moved.reshape(nb, bsh, *moved.shape[1:])
    lo = (moved & jnp.uint8(0x0F)).astype(jnp.int8) - jnp.int8(INT4_BIAS)
    hi = (moved >> jnp.uint8(4)).astype(jnp.int8) - jnp.int8(INT4_BIAS)
    out = jnp.concatenate([lo, hi], axis=1)
    out = out.reshape(nb * bsh * 2, *out.shape[2:])
    return jnp.moveaxis(out, 0, axis)


# ---------------------------------------------------------------------------
# Weight quantization (W4): per-output-channel or per-(K-group, out-channel)
# ---------------------------------------------------------------------------

def quantize_weight_int4(
    w: jax.Array,
    group_size: int = -1,
    clip_ratio: float = 1.0,
) -> QuantizedTensor:
    """Quantize a [K, N] weight matrix to symmetric int4.

    group_size == -1 → per-output-channel (one scale per column).
    group_size == g  → one scale per (K-group of g, column) — OmniQuant-
    style group quantization.
    Returns packed (interleaved) uint8 data of shape [K/2, N].
    """
    if w.ndim != 2:
        raise ValueError(f"expected [K, N] weight, got {w.shape}")
    k, n = w.shape
    if group_size == -1:
        scale = absmax_scale(w, axis=0, bits=4, clip_ratio=clip_ratio)  # [1, N]
        q = quantize_int4(w, scale)
    else:
        if k % group_size != 0:
            raise ValueError(f"K={k} not divisible by group_size={group_size}")
        wg = w.reshape(k // group_size, group_size, n)
        scale = absmax_scale(wg, axis=1, bits=4, clip_ratio=clip_ratio)  # [K/g,1,N]
        q = quantize_int4(wg, scale).reshape(k, n)
        scale = scale[:, 0, :]  # [K/g, N]
    block = None if group_size == -1 else group_size
    packed = pack_int4_interleaved(q, axis=0, block_size=block)
    zero = jnp.zeros((), jnp.float32)
    return QuantizedTensor(packed, scale, zero, 4, (k, n))


def dequantize_weight_int4(qt: QuantizedTensor, group_size: int = -1) -> jax.Array:
    k, n = qt.shape
    block = None if group_size == -1 else group_size
    q = unpack_int4_interleaved(qt.data, axis=0, block_size=block).astype(jnp.float32)
    if group_size == -1:
        return q * qt.scale
    return (q.reshape(k // group_size, group_size, n) * qt.scale[:, None, :]).reshape(k, n)


# ---------------------------------------------------------------------------
# Activation quantization: per-(token, K-block) group-wise, mixed 4/8-bit
# ---------------------------------------------------------------------------

def quantize_act_groupwise(
    x: jax.Array,
    block_size: int = 128,
    bits: int = 4,
    clip_ratio: float = 1.0,
):
    """Group-wise symmetric quantization of activations [M, K].

    One scale per (row, K-block). Returns (q int8 [M,K], scale [M, K/b]).
    The block size matches the GEMM accumulation granularity so dequant
    happens once per block at the int32→f32 boundary.
    """
    m, k = x.shape
    if k % block_size != 0:
        raise ValueError(f"K={k} not divisible by block={block_size}")
    nb = k // block_size
    xb = x.reshape(m, nb, block_size)
    scale = absmax_scale(xb, axis=2, bits=bits, clip_ratio=clip_ratio)  # [M,nb,1]
    if bits == 4:
        q = quantize_int4(xb, scale)
    elif bits == 8:
        q = quantize_int8(xb, scale)
    else:
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    return q.reshape(m, k), scale[:, :, 0]


# ---------------------------------------------------------------------------
# KV-cache quantization: channel-wise asymmetric int4 (COMET §3.2)
# ---------------------------------------------------------------------------

def quantize_kv_channelwise(kv: jax.Array, axis: int = -1):
    """Asymmetric int4 over the head-dim channel axis.

    kv: [..., T, D] — scales/zeros are per-channel (over all leading axes
    except the channel axis itself, computed along the token axis).
    Returns (packed uint8 [..., T, D/2], scale [..., 1, D], zero [..., 1, D]).
    """
    if axis != -1:
        raise NotImplementedError("channel axis must be last")
    # reduce over the token axis (-2): per-channel statistics
    scale, zero = asym_scale_zero(kv, axis=-2, bits=4)
    q = jnp.clip(jnp.round(kv / scale + zero), 0, 15).astype(jnp.uint8)
    # Location-switch packing along channels: byte j = (ch j, ch j + D/2),
    # so the kernel unpack is mask/shift + in-order concat (no shuffle).
    half = q.shape[-1] // 2
    lo = q[..., :half]
    hi = q[..., half:]
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    return packed, scale, zero


def dequantize_kv_channelwise(packed: jax.Array, scale: jax.Array, zero: jax.Array) -> jax.Array:
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.float32)
    hi = (packed >> jnp.uint8(4)).astype(jnp.float32)
    q = jnp.concatenate([lo, hi], axis=-1)
    return (q - zero) * scale
