"""cometlint rule tests: each of R1–R6 gets a true-positive fixture (a
seeded bad snippet must produce the finding) and a true-negative fixture
(the compliant sibling must stay silent), plus the repo-wide
zero-findings gate — the same invocation CI's ``lint-cpu`` job runs.

Fixtures live under ``fixtures/`` which ``Project.from_paths`` never
descends into — the deliberately-bad snippets must not fail the gate.
"""
import json
import pathlib

import pytest

from repro.analysis.cometlint import main
from repro.analysis.rules import Project, RULES, run_rules

HERE = pathlib.Path(__file__).resolve().parent
FIX = HERE / "fixtures"
REPO = HERE.parents[1]


def findings_for(rule_id, *paths):
    project = Project.from_paths([str(p) for p in paths])
    assert project.files, f"fixture scan found no files in {paths}"
    return run_rules(project, only=[rule_id])


# ------------------------------------------------------------ per rule

def test_r1_true_positive():
    found = findings_for("R1", FIX / "r1_bad.py")
    msgs = " | ".join(f.message for f in found)
    assert len(found) == 2
    assert "Scheduler.dropped" in msgs          # uncovered attr
    assert "ghost" in msgs and "stale" in msgs  # stale allowlist entry


def test_r1_true_negative():
    assert findings_for("R1", FIX / "r1_good.py") == []


def test_r2_true_positive():
    found = findings_for("R2", FIX / "r2_bad.py")
    assert len(found) == 2                      # static + donate kwargs
    assert all(f.rule == "R2" for f in found)


def test_r2_true_negative():
    assert findings_for("R2", FIX / "r2_good.py") == []


def test_r3_true_positive():
    found = findings_for("R3", FIX / "r3_bad")
    msgs = " | ".join(f.message for f in found)
    assert len(found) == 2                      # no check site, no test ref
    assert "'ghost'" in msgs and "instrumentation" in msgs
    assert "never referenced" in msgs


def test_r3_true_negative():
    assert findings_for("R3", FIX / "r3_good") == []


def test_r4_true_positive():
    found = findings_for("R4", FIX / "r4_bad.py")
    msgs = " | ".join(f.message for f in found)
    assert len(found) == 3
    assert "bare except" in msgs
    assert "noqa: BLE001" in msgs
    assert "pass" in msgs


def test_r4_true_negative():
    assert findings_for("R4", FIX / "r4_good.py") == []


def test_r5_true_positive():
    found = findings_for("R5", FIX / "r5_bad")
    msgs = " | ".join(f.message for f in found)
    assert len(found) == 2
    assert "oops_count" in msgs and "never declared" in msgs
    assert "hidden_errors" in msgs and "never surfaced" in msgs


def test_r5_true_negative():
    assert findings_for("R5", FIX / "r5_good") == []


def test_r6_true_positive():
    found = findings_for("R6", FIX / "r6_bad")
    msgs = " | ".join(f.message for f in found)
    assert "imports 'jax'" in msgs              # device import
    assert "hash()" in msgs                     # builtin hash on content
    assert any("jnp" in f.message for f in found)


def test_r6_true_negative():
    assert findings_for("R6", FIX / "r6_good") == []


# ------------------------------------------------------------ the gate

def test_repo_zero_findings_gate():
    """The exact CI gate: cometlint over src/ + tests/ must be clean."""
    project = Project.from_paths([str(REPO / "src"), str(REPO / "tests")])
    found = run_rules(project)
    assert found == [], "\n".join(f.format() for f in found)


def test_fixtures_excluded_from_scans():
    """Directories named ``fixtures`` never leak into a directory scan —
    the bad snippets above must not fail the repo gate."""
    project = Project.from_paths([str(HERE)])
    names = {f.basename for f in project.files}
    assert "test_cometlint.py" in names
    assert not any("r1_bad" in f.path or "r6_bad" in f.path
                   for f in project.files)


# ------------------------------------------------------------- the CLI

def test_cli_exit_codes(capsys):
    assert main([str(FIX / "r4_good.py")]) == 0
    assert main([str(FIX / "r4_bad.py")]) == 1
    out = capsys.readouterr().out
    assert "[R4]" in out and "finding(s)" in out


def test_cli_rule_subset(capsys):
    # r4_bad has only R4 findings; restricting to R1 must be clean
    assert main(["--rules", "R1", str(FIX / "r4_bad.py")]) == 0
    capsys.readouterr()


def test_cli_unknown_rule_rejected(capsys):
    with pytest.raises(SystemExit):
        main(["--rules", "R99", str(FIX / "r4_bad.py")])
    capsys.readouterr()


def test_cli_json_report(capsys):
    assert main(["--json", str(FIX / "r2_bad.py")]) == 1
    report = json.loads(capsys.readouterr().out)
    assert report["files_scanned"] == 1
    assert {f["rule"] for f in report["findings"]} == {"R2"}
    assert all({"rule", "path", "line", "message"} <= set(f)
               for f in report["findings"])


def test_registry_is_complete():
    assert sorted(RULES) == ["R1", "R2", "R3", "R4", "R5", "R6"]


def test_from_sources_matches_from_paths():
    """In-memory projects (how future rule tests can seed multi-file
    trees without fixture dirs) behave like disk scans."""
    text = (FIX / "r2_bad.py").read_text()
    proj = Project.from_sources([("src/repro/serving/x.py", text)])
    assert len(run_rules(proj, only=["R2"])) == 2
