"""R4 true negatives: typed-and-handled, and the sanctioned annotated
backstop."""


def f(op, log):
    try:
        op()
    except ValueError as e:
        log(e)
    try:
        op()
    except Exception as e:  # noqa: BLE001 — serving-loop backstop: count
        log(e)
    return 1
