"""R1 true positive: ``dropped`` never reaches snapshot/restore or the
allowlist, and the allowlist carries a stale name."""


class Scheduler:
    def __init__(self):
        self.waiting = []
        self.dropped = 0            # R1: not snapshotted, not exempt

    def snapshot(self):
        return {"waiting": self.waiting}

    def restore(self, state):
        self.waiting = state["waiting"]


class Engine:
    _SNAPSHOT_EXEMPT = frozenset({"ghost"})   # R1: stale — never assigned

    def __init__(self):
        self.steps = 0

    def snapshot(self):
        return {"steps": self.steps}
