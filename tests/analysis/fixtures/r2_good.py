"""R2 true negative: argnums derived from parameter names."""

STATIC = ("cmax", "schedule")
DONATE = ("k_pool", "v_pool")


def build(jax, argnums_of, fwd, donate):
    return jax.jit(fwd, static_argnums=argnums_of(fwd, *STATIC),
                   donate_argnums=(argnums_of(fwd, *DONATE)
                                   if donate else ()))
