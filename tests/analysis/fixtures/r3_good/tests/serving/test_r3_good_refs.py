def test_all_points():
    spec = "forward:step=3;sample:step=4;crash:step=5"
    assert "forward" in spec
