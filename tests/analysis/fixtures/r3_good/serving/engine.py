def step(faults):
    if faults.check("forward"):
        return None
    if faults.check("sample"):
        return None
    if faults.check("crash"):
        raise SystemExit(1)
    return 1
