"""R3 true negative: every declared point is instrumented and tested."""

ENGINE_FAULT_POINTS = ("forward", "sample")
FAULT_POINTS = ENGINE_FAULT_POINTS + ("crash",)
