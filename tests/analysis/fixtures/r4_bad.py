"""R4 true positives: bare except, unannotated broad catch, and a
typed-but-pass-only swallow."""


def f(op):
    try:
        op()
    except:                         # bare — finding, never sanctionable
        return None
    try:
        op()
    except Exception:               # broad without a rationale — finding
        return None
    try:
        op()
    except ValueError:              # pass-only swallow — finding
        pass
    return 1
