"""R5 true negative: declared in __init__ and surfaced in counters()."""


class Group:
    def __init__(self):
        self.callback_errors = 0

    def deliver(self, cb, ev):
        try:
            cb(ev)
        except ValueError:
            self.callback_errors += 1

    def counters(self):
        return {"callback_errors": self.callback_errors}
