"""R5 true positives: ``oops_count`` is incremented but never declared;
``hidden_errors`` is declared but surfaced nowhere."""


class Group:
    def __init__(self):
        self.hidden_errors = 0

    def deliver(self, cb, ev):
        try:
            cb(ev)
        except ValueError:
            self.hidden_errors += 1
            self.oops_count += 1

    def counters(self):
        return {"steps": 0}
