"""R1 true negative: every __init__ attr is snapshotted (directly, by
dict key, or via the underscore-stripped name) or explicitly exempt."""


class Scheduler:
    _SNAPSHOT_EXEMPT = frozenset({"scratch"})

    def __init__(self, limit):
        self.limit = limit
        self.waiting = []
        self._cursor = 0
        self.scratch = {}           # exempt: rebuilt per step

    def snapshot(self):
        return {"waiting": self.waiting, "cursor": self._cursor}

    @classmethod
    def restore(cls, state, limit):
        sched = cls(limit)
        sched.waiting = state["waiting"]
        sched._cursor = state["cursor"]
        return sched
