"""R6 true negative: pure-host scheduling with hashlib content keys."""

import hashlib


def plan(prompt):
    key = hashlib.sha256(bytes(prompt)).digest()
    return [0] * len(prompt), key
