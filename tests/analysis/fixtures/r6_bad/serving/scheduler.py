"""R6 true positives: device imports/usage in a host-only module and a
builtin hash() on prompt content."""

import jax
import jax.numpy as jnp


def plan(prompt):
    key = hash(tuple(prompt))
    return jnp.zeros((len(prompt),)), key
