"""R2 true positive: integer argnum literals handed to jit."""


def build(jax, fwd, donate):
    return jax.jit(fwd, static_argnums=(0, 1, 2),
                   donate_argnums=(4, 5) if donate else ())
