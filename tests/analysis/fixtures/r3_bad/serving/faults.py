"""R3 true positive: ``ghost`` is declared but has no instrumentation
site and no test reference (two findings)."""

ENGINE_FAULT_POINTS = ("covered",)
FAULT_POINTS = ENGINE_FAULT_POINTS + ("ghost",)
