def step(faults):
    if faults.check("covered"):
        return None
    return 1
