def test_covered_fault():
    assert "covered" in ("covered",)
