import os
import sys
import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)

# Make tests/ importable from test modules in subdirectories so the
# hermetic `_hypothesis_stub` fallback resolves regardless of rootdir.
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
