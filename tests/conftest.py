import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
