"""Sharding rules: every param/cache spec must be valid for the mesh."""
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.launch.specs import shapes_of_init
from repro.parallel import sharding as SH


def fake_mesh(shape, axes):
    """Abstract mesh is enough to validate spec construction."""
    n = int(np.prod(shape))
    devs = jax.devices() * n
    return Mesh(np.array(devs[:n]).reshape(shape), axes)


@pytest.mark.parametrize("arch", ARCH_IDS[:6])
@pytest.mark.parametrize("rules_name", ["train", "serve"])
def test_param_specs_divide_dims(arch, rules_name):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    params, axes = shapes_of_init(lm)
    mesh = fake_mesh((2, 2), ("data", "model"))
    rules = SH.TRAIN_RULES if rules_name == "train" else SH.SERVE_RULES
    specs = SH.tree_pspecs(axes, params, mesh, rules)

    def check(p, s):
        assert isinstance(s, P)
        for dim, ax in zip(p.shape, tuple(s) + (None,) * p.ndim):
            if ax is not None:
                size = mesh.shape[ax] if isinstance(ax, str) else int(
                    np.prod([mesh.shape[a] for a in ax]))
                assert dim % size == 0, (p.shape, s)

    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ["qwen2_72b", "zamba2_2p7b", "rwkv6_1p6b"])
def test_cache_specs_valid(arch):
    cfg = get_smoke_config(arch)
    lm = LM(cfg, quant=QuantConfig(impl="ref"))
    cache = jax.eval_shape(lambda: lm.init_cache(8, 64))
    mesh = fake_mesh((2, 2), ("data", "model"))
    specs = SH.cache_pspecs(cache, mesh)

    def check(p, s):
        for dim, ax in zip(p.shape, tuple(s) + (None,) * p.ndim):
            if ax is not None:
                size = mesh.shape[ax] if isinstance(ax, str) else int(
                    np.prod([mesh.shape[a] for a in ax]))
                assert dim % size == 0, (p.shape, s)

    jax.tree.map(check, cache, specs, is_leaf=lambda x: isinstance(x, P))


def test_spec_for_axes_divisibility_fallback():
    """Any dim not divisible by its mesh axis falls back to replicated
    for THAT dim only — never a lowering failure, never contaminating
    the dims that do divide."""
    mesh = fake_mesh((2, 2), ("data", "model"))
    spec = SH.spec_for_axes(("heads", "mlp"), (5, 8), mesh,
                            SH.SERVE_RULES)     # 5 % 2 != 0 on "model"
    assert spec == P(None, "model")            # heads dim fell back; the
    #                                            "model" axis is then free
    #                                            for the dividing mlp dim
    # fully divisible → both rules resolve
    spec2 = SH.spec_for_axes(("embed", "heads"), (4, 8), mesh,
                             SH.TRAIN_RULES)
    assert spec2 == P("data", "model")
    # an axis already used by an earlier dim is never repeated
    spec3 = SH.spec_for_axes(("heads", "mlp"), (4, 8), mesh,
                             SH.SERVE_RULES)
    assert spec3 == P("model", None)
    # unknown logical names and rules mapping to absent mesh axes → None
    spec4 = SH.spec_for_axes(("nonsense", "vocab"), (4, 8),
                             fake_mesh((4,), ("data",)), SH.SERVE_RULES)
    assert spec4 == P(None, None)


def test_maybe_shard_off_mesh_is_identity():
    """Layers call maybe_shard unconditionally; with no ambient mesh it
    must be a no-op returning the SAME array uncommitted."""
    x = jax.numpy.arange(8.0)
    y = SH.maybe_shard(x, "model")
    assert y is x
    # under an ambient mesh it applies the constraint (divisible dim)
    with fake_mesh((1,), ("model",)):
        z = SH.maybe_shard(x, "model")
        np.testing.assert_array_equal(np.asarray(z), np.asarray(x))


def test_cache_pspecs_paged_pool_layout():
    """The serving engine's live pools: ONLY the kv-head dim shards
    (dim 3 of [L, P, ps, Hkv, D/2]) — pages are a host-global namespace
    — and the static per-channel scales [Hkv, 1, D] shard to match.
    Head counts not dividing the model axis fall back to replicated."""
    mesh = fake_mesh((2, 2), ("data", "model"))
    cache = {
        "k_pool": np.zeros((2, 16, 8, 2, 16), np.uint8),
        "v_pool": np.zeros((2, 16, 8, 2, 16), np.uint8),
        "k_scale": np.zeros((2, 1, 32), np.float32),
        "k_zero": np.zeros((2, 1, 32), np.float32),
        "v_scale": np.zeros((2, 1, 32), np.float32),
        "v_zero": np.zeros((2, 1, 32), np.float32),
    }
    specs = SH.cache_pspecs(cache, mesh)
    assert specs["k_pool"] == P(None, None, None, "model", None)
    assert specs["v_pool"] == P(None, None, None, "model", None)
    for name in ("k_scale", "k_zero", "v_scale", "v_zero"):
        assert specs[name] == P("model", None, None)
    # 3 kv heads on a 2-wide model axis → whole pool replicated
    odd = {"k_pool": np.zeros((2, 16, 8, 3, 16), np.uint8),
           "k_scale": np.zeros((3, 1, 32), np.float32)}
    specs_odd = SH.cache_pspecs(odd, mesh)
    assert specs_odd["k_pool"] == P(None, None, None, None, None)
    assert specs_odd["k_scale"] == P(None, None, None)


def test_batch_spec_pod_axis():
    mesh3 = fake_mesh((2, 2, 2), ("pod", "data", "model"))
    assert SH.batch_spec(mesh3) == P(("pod", "data"))
    mesh2 = fake_mesh((2, 2), ("data", "model"))
    assert SH.batch_spec(mesh2) == P("data")


def test_make_local_mesh_refuses_silent_clamp():
    """Asking for more devices than exist must raise by default — a
    silently clamped mesh serves a different topology than requested
    (--mesh 2x4 on one device would quietly run 1x1)."""
    from repro.launch.mesh import make_local_mesh
    n = len(jax.devices())
    with pytest.raises(ValueError, match="devices"):
        make_local_mesh(n + 1, 1)
    with pytest.raises(ValueError, match="devices"):
        make_local_mesh(2, n)               # 2*n > n for any n >= 1
    # a fitting request is honored exactly
    mesh = make_local_mesh(1, 1)
    assert (int(mesh.shape["data"]), int(mesh.shape["model"])) == (1, 1)


def test_make_local_mesh_allow_shrink_warns_with_effective_mesh():
    """allow_shrink=True restores the best-effort clamp, but loudly: a
    UserWarning names the effective mesh actually built."""
    from repro.launch.mesh import make_local_mesh
    n = len(jax.devices())
    with pytest.warns(UserWarning, match="effective mesh"):
        mesh = make_local_mesh(n + 1, n + 1, allow_shrink=True)
    assert int(mesh.shape["data"]) * int(mesh.shape["model"]) <= n


def test_make_replica_meshes_disjoint_slices():
    """Per-replica meshes carve disjoint device slices (data axis as N
    independent engines) and refuse to oversubscribe."""
    from repro.launch.mesh import make_replica_meshes
    n = len(jax.devices())
    meshes = make_replica_meshes(n, model=1)
    assert len(meshes) == n
    seen = set()
    for m in meshes:
        assert (int(m.shape["data"]), int(m.shape["model"])) == (1, 1)
        ids = {d.id for d in m.devices.flat}
        assert not ids & seen               # disjoint
        seen |= ids
    with pytest.raises(ValueError, match="devices"):
        make_replica_meshes(n + 1, model=1)


def test_dryrun_smoke_subprocess():
    """Lower+compile one smoke cell on 8 fake devices in a subprocess
    (isolates the XLA device-count env from this process)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import numpy as np, jax
from jax.sharding import Mesh
import repro.configs.base as CB
CB.get_config = CB.get_smoke_config
CB.SHAPES = {"train_4k": CB.ShapeConfig("train_4k", 64, 8, "train"),
             "decode_32k": CB.ShapeConfig("decode_32k", 128, 8, "decode")}
import repro.launch.specs as SP
SP.SHAPES = CB.SHAPES
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
for shape in ("train_4k", "decode_32k"):
    cell = SP.build_cell("llama3_8b", shape, mesh)
    with mesh:
        c = jax.jit(cell.step_fn, in_shardings=cell.in_shardings).lower(
            *cell.args).compile()
    assert SP.cost_analysis_dict(c).get("flops", 0) >= 0
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert "OK" in out.stdout, out.stderr[-2000:]
