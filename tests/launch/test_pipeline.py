"""Pipeline parallelism: GPipe schedule == sequential layer stack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.pipeline import (pipeline_apply,
                                     pipeline_bubble_fraction, stage_params)


def make_stack(l, d, key):
    ks = jax.random.split(key, l)
    return {"w": jnp.stack([
        jax.random.normal(k, (d, d)) * 0.2 for k in ks])}


def block_fn(bp, x):
    return jnp.tanh(x @ bp["w"])


@pytest.mark.parametrize("l,s,m", [(8, 4, 6), (6, 2, 3), (4, 4, 8)])
def test_pipeline_matches_sequential(l, s, m):
    d, mb = 16, 4
    key = jax.random.PRNGKey(0)
    params = make_stack(l, d, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))

    # sequential reference
    def seq(x1):
        h = x1
        for i in range(l):
            h = block_fn(jax.tree.map(lambda a: a[i], params), h)
        return h
    ref = jnp.stack([seq(x[i]) for i in range(m)])

    staged = stage_params(params, s)
    out = jax.jit(lambda p, xm: pipeline_apply(block_fn, p, xm))(staged, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_pipeline_grads_flow():
    l, s, m, d, mb = 4, 2, 4, 8, 2
    params = make_stack(l, d, jax.random.PRNGKey(0))
    staged = stage_params(params, s)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))

    def loss(p):
        return jnp.sum(pipeline_apply(block_fn, p, x) ** 2)

    g = jax.grad(loss)(staged)
    assert float(jnp.abs(g["w"]).max()) > 0
    assert np.isfinite(np.asarray(g["w"])).all()


def test_bubble_fraction():
    assert pipeline_bubble_fraction(4, 12) == 3 / 15
    assert pipeline_bubble_fraction(1, 8) == 0.0


def test_pipeline_compiles_sharded_subprocess():
    """Stage-axis sharded compile on 8 fake devices: the activation shift
    lowers to a cross-stage collective (the PP wire pattern)."""
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.parallel.pipeline import pipeline_apply, stage_params

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("stage", "model"))
l, s, m, mb, d = 8, 4, 6, 4, 32
params = {"w": jax.random.normal(jax.random.PRNGKey(0), (l, d, d)) * 0.2}
staged = stage_params(params, s)
x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))
block = lambda bp, h: jnp.tanh(h @ bp["w"])
with mesh:
    fn = jax.jit(
        lambda p, xm: pipeline_apply(block, p, xm),
        in_shardings=({"w": NamedSharding(mesh, P("stage", None, None, "model"))},
                      NamedSharding(mesh, P())))
    compiled = fn.lower(staged, x).compile()
hlo = compiled.as_text()
assert ("collective-permute" in hlo or "all-gather" in hlo or
        "all-to-all" in hlo), "expected a cross-stage collective"
out = fn(staged, x)
assert np.isfinite(np.asarray(out)).all()
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert "OK" in out.stdout, out.stderr[-1500:]
