"""KV4 decode attention kernel vs oracle and vs fp attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantizer as Q
from repro.kernels import ops, ref


def make_kv(rng, b, hq, hkv, t, d):
    q = rng.normal(size=(b, hq, d)).astype(np.float32)
    k = rng.normal(size=(b, hkv, t, d)).astype(np.float32)
    v = rng.normal(size=(b, hkv, t, d)).astype(np.float32)
    kp, ks, kz = Q.quantize_kv_channelwise(jnp.asarray(k))
    vp, vs, vz = Q.quantize_kv_channelwise(jnp.asarray(v))
    return q, k, v, kp, ks, kz, vp, vs, vz


CASES = [
    (1, 4, 1, 128, 64),     # MQA
    (2, 8, 2, 256, 64),     # GQA 4
    (2, 8, 8, 128, 128),    # MHA
    (3, 4, 2, 500, 32),     # T not multiple of chunk
]


@pytest.mark.parametrize("b,hq,hkv,t,d", CASES)
def test_pallas_matches_oracle(rng, b, hq, hkv, t, d):
    q, k, v, kp, ks, kz, vp, vs, vz = make_kv(rng, b, hq, hkv, t, d)
    length = jnp.asarray(rng.integers(t // 2, t + 1, size=b), jnp.int32)
    o_ref = ref.kv4_decode_attention_ref(
        jnp.asarray(q), kp, ks, kz, vp, vs, vz, length)
    bt = 128 if t % 128 == 0 else t  # pallas path needs t % bt == 0
    o_pal = ops.kv4_decode_attention(
        jnp.asarray(q), kp, ks, kz, vp, vs, vz, length,
        impl="pallas", bt=bt)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=3e-4, atol=3e-4)


def test_quantized_attention_approximates_fp(rng):
    b, hq, hkv, t, d = 2, 8, 2, 256, 64
    q, k, v, kp, ks, kz, vp, vs, vz = make_kv(rng, b, hq, hkv, t, d)
    o_q = np.asarray(ref.kv4_decode_attention_ref(
        jnp.asarray(q), kp, ks, kz, vp, vs, vz))
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    sc = np.einsum("bhgd,bhtd->bhgt", qg, k) / np.sqrt(d)
    p = np.asarray(jax.nn.softmax(jnp.asarray(sc), -1))
    o_fp = np.einsum("bhgt,bhtd->bhgd", p, v).reshape(b, hq, d)
    assert np.abs(o_q - o_fp).max() < 0.15   # int4 KV error bound
