"""W4Ax GEMM kernels vs the pure-jnp oracle, swept over shapes/schedules."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantizer as Q
from repro.kernels import ops, ref
from repro.kernels import w4ax_matmul as WK


def make_operands(rng, m, k4, k8, n):
    k = k4 + k8
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = (rng.normal(size=(k, n)) * 0.05).astype(np.float32)
    parts_q, parts_s = [], []
    if k4:
        q4, s4 = Q.quantize_act_groupwise(jnp.asarray(x[:, :k4]), 128, bits=4)
        a4 = Q.pack_int4_interleaved(q4, axis=1, block_size=128)
    else:
        a4 = jnp.zeros((m, 0), jnp.uint8)
        s4 = jnp.zeros((m, 0), jnp.float32)
    if k8:
        a8, s8 = Q.quantize_act_groupwise(jnp.asarray(x[:, k4:]), 128, bits=8)
    else:
        a8 = jnp.zeros((m, 0), jnp.int8)
        s8 = jnp.zeros((m, 0), jnp.float32)
    wq = Q.quantize_weight_int4(jnp.asarray(w), group_size=128)
    return x, w, a4, s4, a8, s8, wq


SHAPES = [
    (8, 128, 0, 64),      # pure W4A4, tiny N
    (8, 0, 128, 64),      # pure W4A8
    (16, 256, 128, 128),  # mixed
    (64, 384, 128, 256),  # mixed, larger
    (130, 128, 256, 192), # M not multiple of tile, N not of 128
]


@pytest.mark.parametrize("m,k4,k8,n", SHAPES)
@pytest.mark.parametrize("schedule", ["split", "mixed"])
def test_pallas_matches_oracle(rng, m, k4, k8, n, schedule):
    x, w, a4, s4, a8, s8, wq = make_operands(rng, m, k4, k8, n)
    nb4 = k4 // 128
    oracle = ref.w4ax_matmul_ref(
        a4, s4, a8, s8,
        wq.data[: k4 // 2], wq.scale[:nb4],
        wq.data[k4 // 2:], wq.scale[nb4:])
    out = ops.w4ax_matmul(a4, s4, a8, s8, wq.data, wq.scale,
                          schedule=schedule, impl="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("conversion", ["zeroext", "signext"])
def test_conversion_paths_agree(rng, conversion):
    x, w, a4, s4, a8, s8, wq = make_operands(rng, 16, 256, 128, 128)
    out = WK.w4ax_matmul_split(
        a4, s4, a8, s8, wq.data, wq.scale,
        conversion=conversion, interpret=True)
    oracle = ref.w4ax_matmul_ref(
        a4, s4, a8, s8, wq.data[:128], wq.scale[:2],
        wq.data[128:], wq.scale[2:])
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-4)


def test_quantized_gemm_approximates_float(rng):
    x, w, a4, s4, a8, s8, wq = make_operands(rng, 64, 512, 0, 128)
    out = np.asarray(ops.w4ax_matmul(a4, s4, a8, s8, wq.data, wq.scale,
                                     impl="ref"))
    exact = x @ w
    rel = np.abs(out - exact) / (np.abs(exact) + 1e-2)
    assert np.median(rel) < 0.25


def test_3d_leading_dims(rng):
    x, w, a4, s4, a8, s8, wq = make_operands(rng, 24, 128, 128, 64)
    a4r = a4.reshape(2, 12, -1); s4r = s4.reshape(2, 12, -1)
    a8r = a8.reshape(2, 12, -1); s8r = s8.reshape(2, 12, -1)
    out3 = ops.w4ax_matmul(a4r, s4r, a8r, s8r, wq.data, wq.scale, impl="ref")
    out2 = ops.w4ax_matmul(a4, s4, a8, s8, wq.data, wq.scale, impl="ref")
    assert out3.shape == (2, 12, 64)
    np.testing.assert_allclose(np.asarray(out3).reshape(24, 64),
                               np.asarray(out2), rtol=1e-6)
