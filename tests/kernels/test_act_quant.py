"""On-the-fly activation quantization kernel vs oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("m,k", [(8, 128), (16, 512), (33, 256), (256, 1024)])
@pytest.mark.parametrize("bits", [4, 8])
def test_pallas_matches_oracle(rng, m, k, bits):
    x = jnp.asarray(rng.normal(size=(m, k)) * 3, jnp.float32)
    p_ref, s_ref = ref.act_quant_ref(x, bits=bits)
    p_pal, s_pal = ops.act_quant(x, bits=bits, impl="pallas")
    np.testing.assert_array_equal(np.asarray(p_pal), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref),
                               rtol=1e-6)


def test_roundtrip_error_bound(rng):
    from repro.core import quantizer as Q
    x = jnp.asarray(rng.normal(size=(16, 256)), jnp.float32)
    q, s = Q.quantize_act_groupwise(x, 128, bits=4)
    deq = np.asarray(q, np.float32).reshape(16, 2, 128) * \
        np.asarray(s)[:, :, None]
    err = np.abs(deq.reshape(16, 256) - np.asarray(x))
    bound = np.repeat(np.asarray(s), 128, axis=1) * 0.5 + 1e-6
    assert (err <= bound).all()
