"""Property tests for the §4.3 zero-extension correction algebra.

Per 128-channel block with biased nibbles a' = a+8, w' = w+8:

    dot(a, w) = dot(a', w') − 8·Σa' − 8·Σw' + 8·8·128      (+8192)

Randomized over shapes and nb4/nb8 splits via hypothesis (or the
hermetic fixed-seed stub when hypothesis isn't installed).
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # hermetic env — fixed-seed sampled fallback
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import quantizer as Q
from repro.kernels import ref
from repro.kernels import w4ax_matmul as WK

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

BK = WK.BLOCK_K


@given(st.integers(1, 16), st.integers(1, 32), st.integers(0, 2**31 - 1))
def test_zeroext_block_identity(m, n, seed):
    """The raw integer identity the kernels rely on, one 128-block."""
    rng = np.random.default_rng(seed)
    a = rng.integers(-8, 8, size=(m, BK)).astype(np.int32)
    w = rng.integers(-8, 8, size=(BK, n)).astype(np.int32)
    ab, wb = a + 8, w + 8                      # biased, as stored
    corrected = (ab @ wb
                 - 8 * ab.sum(axis=1, keepdims=True)
                 - 8 * wb.sum(axis=0, keepdims=True)
                 + 8 * 8 * BK)
    np.testing.assert_array_equal(corrected, a @ w)
    assert 8 * 8 * BK == 8192                  # the constant in the docs


@given(st.integers(1, 8), st.integers(0, 3), st.integers(0, 3),
       st.integers(1, 3), st.integers(0, 2**31 - 1))
def test_zeroext_ref_gemm_random_splits(m, nb4, nb8, nblk_n, seed):
    """w4ax ref GEMM over random nb4/nb8 splits == exact fp math on the
    dequantized operands (the correction algebra is exact, not approx)."""
    if nb4 + nb8 == 0:
        nb4 = 1
    rng = np.random.default_rng(seed)
    k4, k8, n = nb4 * BK, nb8 * BK, nblk_n * 64
    if k4:
        a4i = rng.integers(-8, 8, size=(m, k4)).astype(np.int8)
        s4 = rng.uniform(0.01, 0.1, size=(m, nb4)).astype(np.float32)
        a4 = Q.pack_int4_interleaved(jnp.asarray(a4i), axis=1, block_size=BK)
    else:
        a4i = np.zeros((m, 0), np.int8)
        a4 = jnp.zeros((m, 0), jnp.uint8)
        s4 = np.zeros((m, 0), np.float32)
    if k8:
        a8 = rng.integers(-128, 128, size=(m, k8)).astype(np.int8)
        s8 = rng.uniform(0.01, 0.1, size=(m, nb8)).astype(np.float32)
    else:
        a8 = np.zeros((m, 0), np.int8)
        s8 = np.zeros((m, 0), np.float32)
    wi = rng.integers(-8, 8, size=(k4 + k8, n)).astype(np.int8)
    ws = rng.uniform(0.01, 0.1, size=(nb4 + nb8, n)).astype(np.float32)
    wp = Q.pack_int4_interleaved(jnp.asarray(wi), axis=0, block_size=BK)

    out = np.asarray(ref.w4ax_matmul_ref(
        a4, jnp.asarray(s4), jnp.asarray(a8), jnp.asarray(s8),
        wp[: k4 // 2], jnp.asarray(ws[:nb4]),
        wp[k4 // 2:], jnp.asarray(ws[nb4:])))

    ad = np.concatenate(
        [a4i.reshape(m, -1, BK) * s4[:, :, None],
         a8.reshape(m, -1, BK) * s8[:, :, None]] if k4 and k8 else
        ([a4i.reshape(m, -1, BK) * s4[:, :, None]] if k4 else
         [a8.reshape(m, -1, BK) * s8[:, :, None]]), axis=1).reshape(m, -1)
    wd = (wi.reshape(-1, BK, n) * ws[:, None, :]).reshape(-1, n)
    np.testing.assert_allclose(out, ad @ wd, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("nb4,nb8", [(1, 0), (0, 1), (2, 1), (1, 2)])
def test_zeroext_kernel_matches_signext(rng, nb4, nb8):
    """Pallas split schedule: corrected zero-extension == explicit
    sign-extension unpack, across nb4/nb8 splits (interpret mode)."""
    m, n = 16, 128
    k4, k8 = nb4 * BK, nb8 * BK
    x = rng.normal(size=(m, k4 + k8)).astype(np.float32)
    w = (rng.normal(size=(k4 + k8, n)) * 0.05).astype(np.float32)
    if k4:
        q4, s4 = Q.quantize_act_groupwise(jnp.asarray(x[:, :k4]), BK, bits=4)
        a4 = Q.pack_int4_interleaved(q4, axis=1, block_size=BK)
    else:
        a4 = jnp.zeros((m, 0), jnp.uint8)
        s4 = jnp.zeros((m, 0), jnp.float32)
    if k8:
        a8, s8 = Q.quantize_act_groupwise(jnp.asarray(x[:, k4:]), BK, bits=8)
    else:
        a8 = jnp.zeros((m, 0), jnp.int8)
        s8 = jnp.zeros((m, 0), jnp.float32)
    wq = Q.quantize_weight_int4(jnp.asarray(w), group_size=BK)
    outs = {
        conv: np.asarray(WK.w4ax_matmul_split(
            a4, s4, a8, s8, wq.data, wq.scale,
            conversion=conv, interpret=True))
        for conv in ("zeroext", "signext")
    }
    np.testing.assert_allclose(outs["zeroext"], outs["signext"],
                               rtol=1e-5, atol=1e-5)
