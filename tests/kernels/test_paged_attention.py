"""Paged KV4 attention (decode + chunked prefill) vs oracle and gather.

Sweeps page sizes, ragged lengths (incl. len < one page and len not a
multiple of page_size), GQA head ratios, and batch > 1 — the contract
the gather-free serving hot path depends on. The prefill sweeps add
ragged chunk lengths, zero-history sequences, and the fp-chunk/int4-
history boundary the chunked prompt path relies on.

The work-queue sweeps re-run every dense case through the flat
Stream-K descriptor schedule (``build_work_queue`` → ``*_wq`` kernels
→ split-KV combine) and require the result to match the DENSE oracle —
the two grid schedules must be numerically interchangeable up to float
reassociation, including the ragged dominant-long-row and qlen-0
pad-row cases the unified engine's bucketed batches produce.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.kernels import ops, ref
from repro.layers.attention import flash_attention
from repro.serving.kv_cache import (PagedKV4Cache, PagedKV4Config,
                                    build_work_queue)


def make_paged(rng, b, hkv, d, ps, lengths, num_pages=None):
    """Random pools + a shuffled (non-identity) block table per seq."""
    npages = max((int(l) + ps - 1) // ps for l in lengths)
    need = sum((int(l) + ps - 1) // ps for l in lengths)
    num_pages = num_pages or need + 3
    k_pool = jnp.asarray(
        rng.integers(0, 256, size=(num_pages, ps, hkv, d // 2)), jnp.uint8)
    v_pool = jnp.asarray(
        rng.integers(0, 256, size=(num_pages, ps, hkv, d // 2)), jnp.uint8)
    tbl = np.full((b, npages), -1, np.int32)
    perm = rng.permutation(num_pages)
    i = 0
    for bi, l in enumerate(lengths):
        n = (int(l) + ps - 1) // ps
        tbl[bi, :n] = perm[i:i + n]
        i += n
    stats = lambda: (
        jnp.asarray(rng.uniform(0.05, 0.2, size=(hkv, 1, d)), jnp.float32),
        jnp.asarray(rng.uniform(6.0, 9.0, size=(hkv, 1, d)), jnp.float32))
    ks, kz = stats()
    vs, vz = stats()
    return (k_pool, ks, kz, v_pool, vs, vz,
            jnp.asarray(tbl), jnp.asarray(lengths, jnp.int32))


CASES = [
    # (b, hq, hkv, d, ps, lengths)
    (1, 4, 1, 64, 32, [7]),              # MQA, len < one page
    (2, 8, 2, 64, 32, [33, 64]),         # GQA 4, ragged + page-aligned
    (2, 8, 8, 128, 64, [100, 17]),       # MHA, len % ps != 0
    (4, 8, 2, 64, 128, [5, 130, 256, 200]),   # batch 4, big pages
    (3, 16, 4, 64, 64, [64, 1, 190]),    # GQA 4, len == 1 edge
    (4, 8, 2, 64, 32, [300, 3, 2, 1]),   # one dominant long-context row
]


@pytest.mark.parametrize("b,hq,hkv,d,ps,lengths", CASES)
@pytest.mark.parametrize("impl", ["pallas", "ref"])
def test_paged_matches_oracle(rng, b, hq, hkv, d, ps, lengths, impl):
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    kp, ks, kz, vp, vs, vz, tbl, lens = make_paged(
        rng, b, hkv, d, ps, lengths)
    o_ref = ref.paged_kv4_decode_attention_ref(
        q, kp, ks, kz, vp, vs, vz, tbl, lens)
    o = ops.paged_kv4_decode_attention(
        q, kp, ks, kz, vp, vs, vz, tbl, lens, impl=impl)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("ps", [32, 64, 128])
def test_page_size_sweep(rng, ps):
    b, hq, hkv, d = 3, 8, 2, 64
    lengths = [ps - 1, ps, 2 * ps + 3]   # below / exact / across pages
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    kp, ks, kz, vp, vs, vz, tbl, lens = make_paged(
        rng, b, hkv, d, ps, lengths)
    o_ref = ref.paged_kv4_decode_attention_ref(
        q, kp, ks, kz, vp, vs, vz, tbl, lens)
    o_pal = ops.paged_kv4_decode_attention(
        q, kp, ks, kz, vp, vs, vz, tbl, lens, impl="pallas")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_paged_matches_gather_on_cache(rng):
    """Through the real cache: paged kernel on the pools == contiguous
    kernel on gather_kv's materialization (both Pallas, f32)."""
    cfg = get_smoke_config("llama3_8b")
    ps = 4
    cache = PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=32, page_size=ps, max_seqs=4,
                            max_pages_per_seq=16), 1)
    hkv, d = cfg.num_kv_heads, cfg.head_dim
    lengths = [10, 3, 17]
    for sid, t in enumerate(lengths):
        k = jnp.asarray(rng.normal(size=(1, t, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, t, hkv, d)), jnp.float32)
        assert cache.allocate_seq(sid, t)
        cache.write_prompt(0, sid, k, v)
        cache.seq_len[sid] = t
    slots = [0, 1, 2]
    # page-multiple so the contiguous kernel's chunking divides evenly
    max_len = -(-max(lengths) // ps) * ps
    lens = cache.lengths_device(slots)
    tbl = cache.block_tables_device(slots, max_len)
    q = jnp.asarray(rng.normal(size=(3, cfg.num_heads, d)), jnp.float32)

    o_paged = ops.paged_kv4_decode_attention(
        q, cache.k_pool[0], cache.k_scale, cache.k_zero,
        cache.v_pool[0], cache.v_scale, cache.v_zero,
        tbl, lens, impl="pallas")

    kp, vp, _ = cache.gather_kv(0, slots, max_len)
    bcast = lambda s: jnp.broadcast_to(s[None], (3, *s.shape))
    o_gather = ops.kv4_decode_attention(
        q, kp, bcast(cache.k_scale), bcast(cache.k_zero),
        vp, bcast(cache.v_scale), bcast(cache.v_zero),
        lens, impl="pallas", bt=ps)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_gather),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- prefill

PREFILL_CASES = [
    # (b, hq, hkv, d, ps, ctx_lens, q_lens, C)
    (1, 4, 1, 64, 32, [40], [16], 16),            # MQA, ragged history
    (2, 8, 2, 64, 32, [0, 33], [8, 3], 8),        # zero-history + ragged
    (2, 8, 8, 128, 64, [100, 17], [16, 16], 16),  # MHA, len % ps != 0
    (3, 16, 4, 64, 64, [64, 1, 190], [1, 7, 16], 16),  # GQA, len-1 edges
    # the unified engine's union batch: a decode row (qlen 1, long int4
    # history), a first-chunk row, a mid-prefill row, and a zero-qlen
    # bucket-padding row — one kernel call serves all four
    (4, 8, 2, 64, 32, [150, 0, 33, 64], [1, 8, 3, 0], 8),
]


def make_prefill(rng, b, hq, hkv, d, ps, ctx_lens, q_lens, c):
    kp, ks, kz, vp, vs, vz, tbl, _ = make_paged(
        rng, b, hkv, d, ps, [max(l, 1) for l in ctx_lens])
    q = jnp.asarray(rng.normal(size=(b, c, hq, d)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, c, hkv, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(b, c, hkv, d)), jnp.float32)
    return (q, kn, vn, kp, ks, kz, vp, vs, vz, tbl,
            jnp.asarray(ctx_lens, jnp.int32), jnp.asarray(q_lens, jnp.int32))


@pytest.mark.parametrize("b,hq,hkv,d,ps,ctx_lens,q_lens,c", PREFILL_CASES)
@pytest.mark.parametrize("impl", ["pallas", "ref"])
def test_prefill_matches_oracle(rng, b, hq, hkv, d, ps, ctx_lens, q_lens, c,
                                impl):
    args = make_prefill(rng, b, hq, hkv, d, ps, ctx_lens, q_lens, c)
    o_ref = ref.paged_kv4_prefill_attention_ref(*args)
    o = ops.paged_kv4_prefill_attention(*args, impl=impl)
    # rows past q_lens are padding garbage — compare valid rows only
    for bi, ql in enumerate(q_lens):
        np.testing.assert_allclose(
            np.asarray(o)[bi, :ql], np.asarray(o_ref)[bi, :ql],
            rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("impl", ["pallas", "ref"])
def test_prefill_zero_history_is_causal_flash(rng, impl):
    """ctx = 0 → the kernel is plain fp causal attention over the chunk
    (the whole-prompt-in-one-chunk case must match the fp prefill path)."""
    b, hq, hkv, d, ps, c = 2, 8, 2, 64, 32, 16
    q = jnp.asarray(rng.normal(size=(b, c, hq, d)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, c, hkv, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(b, c, hkv, d)), jnp.float32)
    kp = jnp.zeros((1, ps, hkv, d // 2), jnp.uint8)
    ks = jnp.ones((hkv, 1, d), jnp.float32)
    kz = jnp.zeros((hkv, 1, d), jnp.float32)
    o = ops.paged_kv4_prefill_attention(
        q, kn, vn, kp, ks, kz, kp, ks, kz,
        jnp.zeros((b, 0), jnp.int32), jnp.zeros((b,), jnp.int32),
        jnp.full((b,), c, jnp.int32), impl=impl)
    o_flash = flash_attention(q, kn, vn, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_flash),
                               rtol=1e-4, atol=1e-4)


def test_prefill_last_row_matches_decode(rng):
    """A single-query chunk over history of length L equals the DECODE
    kernel attending over the same pages with the new token's KV written
    at L — the prefill/decode seam is seamless. The new token's KV is
    placed exactly on the int4 grid so fp-chunk attention (prefill) and
    int4-pool attention (decode) see identical values."""
    b, hq, hkv, d, ps = 2, 8, 2, 64, 32
    lengths = [40, 17]
    kp, ks, kz, vp, vs, vz, tbl, _ = make_paged(
        rng, b, hkv, d, ps, [l + 1 for l in lengths])
    q = jnp.asarray(rng.normal(size=(b, 1, hq, d)), jnp.float32)
    # grid-exact new token: nibbles → dequantize → fp chunk values
    nk = rng.integers(0, 16, size=(b, hkv, d)).astype(np.float32)
    nv = rng.integers(0, 16, size=(b, hkv, d)).astype(np.float32)
    kn = ((nk - np.asarray(kz)[None, :, 0]) * np.asarray(ks)[None, :, 0])
    vn = ((nv - np.asarray(vz)[None, :, 0]) * np.asarray(vs)[None, :, 0])
    kn = jnp.asarray(kn[:, None], jnp.float32)     # [B, 1, Hkv, D]
    vn = jnp.asarray(vn[:, None], jnp.float32)
    o_pre = ops.paged_kv4_prefill_attention(
        q, kn, vn, kp, ks, kz, vp, vs, vz, tbl,
        jnp.asarray(lengths, jnp.int32), jnp.ones((b,), jnp.int32),
        impl="pallas")
    # write the same token (packed nibbles) into the pools at position L
    half = d // 2
    pk = (nk[..., :half].astype(np.uint8)
          | (nk[..., half:].astype(np.uint8) << 4))
    pv = (nv[..., :half].astype(np.uint8)
          | (nv[..., half:].astype(np.uint8) << 4))
    kp_np, vp_np = np.asarray(kp).copy(), np.asarray(vp).copy()
    tbl_np = np.asarray(tbl)
    for bi, l in enumerate(lengths):
        page, off = tbl_np[bi, l // ps], l % ps
        kp_np[page, off] = pk[bi]
        vp_np[page, off] = pv[bi]
    o_dec = ops.paged_kv4_decode_attention(
        q[:, 0], jnp.asarray(kp_np), ks, kz, jnp.asarray(vp_np), vs, vz,
        tbl, jnp.asarray([l + 1 for l in lengths], jnp.int32),
        impl="pallas")
    np.testing.assert_allclose(np.asarray(o_pre)[:, 0], np.asarray(o_dec),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- work queue

@pytest.mark.parametrize("b,hq,hkv,d,ps,lengths", CASES)
@pytest.mark.parametrize("impl", ["pallas", "ref"])
def test_wq_decode_matches_dense_oracle(rng, b, hq, hkv, d, ps, lengths,
                                        impl):
    """The flat work-queue schedule == the dense oracle, and its work
    count covers only real pages (≈ Σ pages, not B·max_npages)."""
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    kp, ks, kz, vp, vs, vz, tbl, lens = make_paged(
        rng, b, hkv, d, ps, lengths)
    o_dense = ref.paged_kv4_decode_attention_ref(
        q, kp, ks, kz, vp, vs, vz, tbl, lens)
    desc = build_work_queue(np.asarray(tbl), lengths, ps, hkv)
    o = ops.paged_kv4_decode_attention_wq(
        q, kp, ks, kz, vp, vs, vz, jnp.asarray(desc), impl=impl)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_dense),
                               rtol=1e-4, atol=1e-4)
    real = int((desc[:, 2] > 0).sum())
    assert real == hkv * sum(-(-int(l) // ps) for l in lengths)
    # pow-2 padded, never the dense rectangle's worth of extra lanes
    assert real <= desc.shape[0] < 2 * max(real, 4) + 8


@pytest.mark.parametrize("b,hq,hkv,d,ps,ctx_lens,q_lens,c", PREFILL_CASES)
@pytest.mark.parametrize("impl", ["pallas", "ref"])
def test_wq_prefill_matches_dense_oracle(rng, b, hq, hkv, d, ps, ctx_lens,
                                         q_lens, c, impl):
    """Work-queue prefill (history page items + causal chunk items) ==
    the dense oracle on every valid row — including the union-batch case
    with a decode row, a first-chunk row, and a qlen-0 pad row."""
    args = make_prefill(rng, b, hq, hkv, d, ps, ctx_lens, q_lens, c)
    q, kn, vn, kp, ks, kz, vp, vs, vz, tbl, ctx, qls = args
    o_dense = ref.paged_kv4_prefill_attention_ref(*args)
    desc = build_work_queue(np.asarray(tbl), ctx_lens, ps, hkv, q_lens)
    o = ops.paged_kv4_prefill_attention_wq(
        q, kn, vn, kp, ks, kz, vp, vs, vz, jnp.asarray(desc), impl=impl)
    for bi, ql in enumerate(q_lens):
        np.testing.assert_allclose(
            np.asarray(o)[bi, :ql], np.asarray(o_dense)[bi, :ql],
            rtol=1e-4, atol=1e-4)
    # qlen-0 rows contribute no chunk item; ctx-0 rows no page items
    real = int((desc[:, 2] > 0).sum())
    assert real == hkv * (sum(-(-int(l) // ps) for l in ctx_lens)
                          + sum(1 for l in q_lens if l > 0))


@pytest.mark.parametrize("impl", ["pallas", "ref"])
def test_wq_prefill_zero_history_is_causal_flash(rng, impl):
    """ctx = 0 everywhere → only chunk items exist and the work-queue
    kernel reduces to plain fp causal attention over the chunk."""
    b, hq, hkv, d, ps, c = 2, 8, 2, 64, 32, 16
    q = jnp.asarray(rng.normal(size=(b, c, hq, d)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, c, hkv, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(b, c, hkv, d)), jnp.float32)
    kp = jnp.zeros((1, ps, hkv, d // 2), jnp.uint8)
    ks = jnp.ones((hkv, 1, d), jnp.float32)
    kz = jnp.zeros((hkv, 1, d), jnp.float32)
    desc = build_work_queue(np.zeros((b, 1), np.int32), [0, 0], ps, hkv,
                            [c, c])
    o = ops.paged_kv4_prefill_attention_wq(
        q, kn, vn, kp, ks, kz, kp, ks, kz, jnp.asarray(desc), impl=impl)
    o_flash = flash_attention(q, kn, vn, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_flash),
                               rtol=1e-4, atol=1e-4)


def test_build_work_queue_layout():
    """Descriptor contract: row-major item order, real-page coverage,
    per-page token counts, pow-2 padding with the sentinel row."""
    tbl = np.asarray([[5, 3, 7, -1], [2, -1, -1, -1]])
    desc = build_work_queue(tbl, [70, 9], page_size=32, num_kv_heads=2,
                            q_lens=[4, 0])
    # seq 0: 3 pages (32+32+6) + chunk, per head; seq 1: 1 page (9 tok)
    real = desc[desc[:, 2] > 0]
    assert len(real) == 2 * (3 + 1) + 2 * 1
    np.testing.assert_array_equal(
        real[:4], [[0, 5, 32, 0], [0, 3, 32, 0], [0, 7, 6, 0],
                   [0, 0, 4, 1]])                    # head 0 of seq 0
    np.testing.assert_array_equal(real[8], [2, 2, 9, 0])   # seq 1, head 0
    assert desc.shape[0] == 16                       # pow-2 padded
    assert (desc[len(real):, 0] == 4).all()          # sentinel row B·Hkv
    assert (desc[len(real):, 2] == 0).all()
    # bucketed batches override the sentinel so it clears the padded
    # row count (rows [B, Nb) are live qlen-0 segments in the combine)
    desc8 = build_work_queue(tbl, [70, 9], 32, 2, [4, 0], pad_row=8 * 2)
    assert (desc8[len(real):, 0] == 16).all()
    np.testing.assert_array_equal(desc8[:len(real)], real)
    with pytest.raises(IndexError):
        build_work_queue(tbl, [70, 40], 32, 2)       # unmapped page hit


def test_batched_append_matches_per_seq(rng):
    """append_tokens (one scatter) == per-sequence append_token loop."""
    cfg = get_smoke_config("llama3_8b")
    hkv, d = cfg.num_kv_heads, cfg.head_dim
    pcfg = PagedKV4Config(num_pages=16, page_size=4, max_seqs=4,
                          max_pages_per_seq=8)
    a = PagedKV4Cache(cfg, pcfg, 1)
    b_ = PagedKV4Cache(cfg, pcfg, 1)
    lengths = [3, 4, 9]                  # mid-page / page-boundary cases
    for sid, t in enumerate(lengths):
        for c in (a, b_):
            assert c.allocate_seq(sid, t + 1)
            c.seq_len[sid] = t
    k = jnp.asarray(rng.normal(size=(3, 1, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(3, 1, hkv, d)), jnp.float32)
    a.append_tokens(0, [0, 1, 2], k, v)
    for bi in range(3):
        b_.append_token(0, bi, k[bi:bi + 1], v[bi:bi + 1])
    np.testing.assert_array_equal(np.asarray(a.k_pool), np.asarray(b_.k_pool))
    np.testing.assert_array_equal(np.asarray(a.v_pool), np.asarray(b_.v_pool))
