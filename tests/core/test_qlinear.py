"""QLinear: quantized projection vs explicit dequantized matmul."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fmpq, qlinear as QL
from repro.core import quantizer as Q


def test_qlinear_fraction_matches_manual(rng):
    k, n, m = 512, 128, 32
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    qp, spec = QL.quantize_linear_fraction(w, int4_fraction=0.5, impl="ref")
    qp = {k_: (v.value if hasattr(v, "value") else v) for k_, v in qp.items()}
    out = QL.qlinear_apply(spec, qp, x)
    # manual: quantize acts per spec, dequantize everything, matmul
    wd = np.asarray(Q.dequantize_weight_int4(
        Q.QuantizedTensor(qp["w_packed"], qp["w_scale"], 0, 4, (k, n)), 128))
    q4, s4 = Q.quantize_act_groupwise(x[:, :spec.k4], 128, bits=4)
    q8, s8 = Q.quantize_act_groupwise(x[:, spec.k4:], 128, bits=8)
    a4 = np.asarray(q4, np.float32).reshape(m, -1, 128) * \
        np.asarray(s4)[:, :, None]
    a8 = np.asarray(q8, np.float32).reshape(m, -1, 128) * \
        np.asarray(s8)[:, :, None]
    ad = np.concatenate([a4.reshape(m, -1), a8.reshape(m, -1)], axis=1)
    expected = ad @ wd
    np.testing.assert_allclose(np.asarray(out), expected, rtol=2e-3,
                               atol=2e-3)


def test_qlinear_with_plan_permutation(rng):
    k, n, m = 384, 64, 16
    x = rng.normal(size=(m, k)).astype(np.float32)
    x[:, rng.choice(k, 9, replace=False)] *= 30
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
    plan = fmpq.plan_fmpq(np.abs(x).max(0))
    qp, spec = QL.quantize_linear(w, plan, impl="ref")
    qp = {k_: (v.value if hasattr(v, "value") else v) for k_, v in qp.items()}
    out = np.asarray(QL.qlinear_apply(spec, qp, jnp.asarray(x)))
    exact = x @ np.asarray(w)
    rel = np.abs(out - exact) / (np.abs(exact) + 1e-2)
    assert np.median(rel) < 0.15
