"""Hypothesis property tests for the packing/quantization primitives."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # hermetic env — fixed-seed sampled fallback
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import quantizer as Q

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.integers(1, 8).map(lambda i: i * 2), st.integers(1, 6),
       st.integers(0, 2**31 - 1))
def test_pack_unpack_sequential_roundtrip(rows2, cols, seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(-8, 8, size=(cols, rows2 * 2)).astype(np.int8)
    packed = Q.pack_int4(jnp.asarray(q), axis=1)
    out = Q.unpack_int4(packed, axis=1)
    np.testing.assert_array_equal(np.asarray(out), q)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_pack_unpack_interleaved_roundtrip(nblocks, cols, seed):
    rng = np.random.default_rng(seed)
    k = nblocks * 128
    q = rng.integers(-8, 8, size=(k, cols)).astype(np.int8)
    packed = Q.pack_int4_interleaved(jnp.asarray(q), axis=0, block_size=128)
    assert packed.shape == (k // 2, cols)
    out = Q.unpack_int4_interleaved(packed, axis=0, block_size=128)
    np.testing.assert_array_equal(np.asarray(out), q)


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8]))
def test_symmetric_quant_error_bound(seed, bits):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(4, 128)) * 10 ** rng.uniform(-2, 2)).astype(
        np.float32)
    q, s = Q.quantize_act_groupwise(jnp.asarray(x), 128, bits=bits)
    deq = np.asarray(q, np.float32) * np.repeat(np.asarray(s), 128, axis=1)
    err = np.abs(deq - x)
    # |err| ≤ scale/2 everywhere (absmax scaling never clips)
    bound = np.repeat(np.asarray(s), 128, axis=1) * 0.5 * 1.0001 + 1e-7
    assert (err <= bound).all()


@given(st.integers(0, 2**31 - 1))
def test_kv_roundtrip_error_bound(seed):
    rng = np.random.default_rng(seed)
    kv = rng.normal(size=(2, 2, 16, 64)).astype(np.float32)
    p, s, z = Q.quantize_kv_channelwise(jnp.asarray(kv))
    deq = np.asarray(Q.dequantize_kv_channelwise(p, s, z))
    err = np.abs(deq - kv)
    bound = np.broadcast_to(np.asarray(s) * 0.5 * 1.0001 + 1e-7, kv.shape)
    assert (err <= bound).all()


@given(st.integers(0, 2**31 - 1))
def test_biased_unpack_identity(seed):
    """dot(a, w) == dot(a, unpack_biased(w)) − 8·Σa (the §4.3 fold)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, size=(4, 128)).astype(np.int32)
    w = rng.integers(-8, 8, size=(128, 8)).astype(np.int8)
    packed = Q.pack_int4_interleaved(jnp.asarray(w), axis=0, block_size=128)
    lo = (np.asarray(packed) & 0x0F).astype(np.int32)
    hi = (np.asarray(packed) >> 4).astype(np.int32)
    w_biased = np.concatenate([lo, hi], axis=0)       # w + 8, zero-extended
    d_biased = a @ w_biased
    correction = 8 * a.sum(axis=1, keepdims=True)
    np.testing.assert_array_equal(d_biased - correction, a @ w.astype(np.int32))
