"""FMPQ algorithm invariants (hypothesis) + GEMM equivalence."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # hermetic env — fixed-seed sampled fallback
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import fmpq
from repro.core import quantizer as Q

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@given(st.integers(2, 8), st.integers(0, 40), st.integers(0, 2**31 - 1))
def test_plan_invariants(nblocks, n_outliers, seed):
    rng = np.random.default_rng(seed)
    k = nblocks * 128
    n_outliers = min(n_outliers, k)
    absmax = rng.uniform(0.5, 1.5, size=k)
    idx = rng.choice(k, n_outliers, replace=False)
    absmax[idx] *= 100.0
    plan = fmpq.plan_fmpq(absmax)
    # permutation is a bijection
    assert sorted(plan.perm.tolist()) == list(range(k))
    np.testing.assert_array_equal(plan.perm[plan.inv_perm], np.arange(k))
    # int8 blocks are the tail and exactly cover the outliers
    bits = plan.block_bits
    assert (bits[: plan.num_int4_blocks] == 4).all()
    assert (bits[plan.num_int4_blocks:] == 8).all()
    expected_int8 = int(np.ceil(n_outliers / 128)) if n_outliers else 0
    assert plan.num_blocks - plan.num_int4_blocks == expected_int8


@given(st.integers(0, 2**31 - 1))
def test_permutation_gemm_equivalence(seed):
    """x @ w == x[:, perm] @ w[perm, :] (up to fp summation order)."""
    rng = np.random.default_rng(seed)
    k = 256
    x = rng.normal(size=(8, k)).astype(np.float64)
    w = rng.normal(size=(k, 16)).astype(np.float64)
    absmax = np.abs(x).max(0)
    plan = fmpq.plan_fmpq(absmax, fmpq.FMPQConfig(outlier_threshold=2.0))
    np.testing.assert_allclose(
        x @ w, x[:, plan.perm] @ w[plan.perm, :], rtol=1e-9, atol=1e-9)


def test_outlier_ratio_beats_unpermuted():
    """Clustering outliers reduces INT8 blocks vs no permutation (§3.2)."""
    rng = np.random.default_rng(7)
    k = 1024
    absmax = rng.uniform(0.5, 1.5, size=k)
    outliers = rng.choice(k, 30, replace=False)  # spread over many blocks
    absmax[outliers] *= 50
    mask = fmpq.identify_outlier_channels(absmax)
    unpermuted_int8 = int(
        (mask.reshape(-1, 128).any(1)).sum())
    plan = fmpq.plan_fmpq(absmax)
    permuted_int8 = plan.num_blocks - plan.num_int4_blocks
    assert permuted_int8 <= unpermuted_int8
    assert permuted_int8 == 1            # 30 outliers fit one block
    assert plan.int4_fraction >= 0.8     # paper: >84% W4A4


def test_mixed_quant_better_than_naive_w4a4():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(256, 512)).astype(np.float32)
    ch = rng.choice(512, 12, replace=False)
    x[:, ch] *= 40
    w = (rng.normal(size=(512, 128)) * 0.05).astype(np.float32)
    exact = x @ w
    plan = fmpq.plan_fmpq(np.abs(x).max(0))
    cfg = fmpq.FMPQConfig()
    wq = fmpq.apply_fmpq_to_weight(jnp.asarray(w), plan, cfg)
    aq, asc = fmpq.quantize_activation_mixed(jnp.asarray(x), plan, cfg)
    wd = Q.dequantize_weight_int4(wq, 128)
    k4 = plan.k4
    ad4 = np.asarray(aq[:, :k4], np.float32).reshape(256, -1, 128) * \
        np.asarray(asc[:, :k4 // 128])[:, :, None]
    ad8 = np.asarray(aq[:, k4:], np.float32).reshape(256, -1, 128) * \
        np.asarray(asc[:, k4 // 128:])[:, :, None]
    ad = np.concatenate([ad4.reshape(256, -1), ad8.reshape(256, -1)], 1)
    out_fmpq = ad @ np.asarray(wd)
    # naive: all int4, no permutation
    qn, sn = Q.quantize_act_groupwise(jnp.asarray(x), 128, bits=4)
    adn = np.asarray(qn, np.float32).reshape(256, -1, 128) * \
        np.asarray(sn)[:, :, None]
    wqn = Q.quantize_weight_int4(jnp.asarray(w), group_size=128)
    out_naive = adn.reshape(256, -1) @ np.asarray(
        Q.dequantize_weight_int4(wqn, 128))
    err_fmpq = np.abs(out_fmpq - exact).mean()
    err_naive = np.abs(out_naive - exact).mean()
    assert err_fmpq < err_naive * 0.8    # FMPQ clearly better on outliers
