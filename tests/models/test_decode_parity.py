"""Prefill/decode must reproduce the full-sequence forward exactly (fp)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.lm import LM

ARCHS = ["qwen2_72b", "starcoder2_15b", "rwkv6_1p6b", "zamba2_2p7b",
         "qwen3_moe_235b_a22b", "llama3p2_vision_90b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_train_forward(arch):
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        # capacity-dropping depends on the total token count, so exact
        # train/prefill parity needs drop-free capacity
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    lm = LM(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = lm.init(key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S + 2), 0, cfg.vocab_size)
    extra = None
    if cfg.family == "vlm":
        extra = {"image_embeds": jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model))}
    full, _ = jax.jit(lambda p, t: lm.train_logits(p, t, extra))(
        params, tokens)
    cache = lm.init_cache(B, S + 8)
    lg, cache = jax.jit(lambda p, t, c: lm.prefill(p, t, c, extra))(
        params, tokens[:, :S], cache)
    tol = 0.05 * float(jnp.abs(full).max())
    assert float(jnp.abs(lg[:, 0] - full[:, S - 1]).max()) < tol
    for i in range(2):
        lg, cache = jax.jit(lm.decode)(
            params, tokens[:, S + i:S + i + 1], cache)
        assert float(jnp.abs(lg[:, 0] - full[:, S + i]).max()) < tol
