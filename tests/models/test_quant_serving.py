"""Quantized W4AxKV4 serving vs fp serving: high logit correlation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig

ARCHS = ["qwen2_72b", "qwen3_moe_235b_a22b", "rwkv6_1p6b", "zamba2_2p7b"]


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("schedule", ["split", "mixed"])
def test_quant_decode_correlates(arch, schedule):
    cfg = get_smoke_config(arch)
    qc = QuantConfig(int4_fraction=0.5, schedule=schedule, impl="ref")
    lm_fp, lm_q = LM(cfg), LM(cfg, quant=qc)
    key = jax.random.PRNGKey(0)
    params, axes = lm_fp.init(key)
    qparams, _ = lm_q.quantize(params, axes)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    c_fp = lm_fp.init_cache(B, S + 4)
    c_q = lm_q.init_cache(B, S + 4)
    lg_fp, c_fp = jax.jit(lm_fp.prefill)(params, tokens, c_fp)
    lg_q, c_q = jax.jit(lm_q.prefill)(qparams, tokens, c_q)
    nt = jnp.argmax(lg_fp[:, -1], -1)[:, None].astype(jnp.int32)
    d_fp, _ = jax.jit(lm_fp.decode)(params, nt, c_fp)
    d_q, _ = jax.jit(lm_q.decode)(qparams, nt, c_q)
    assert np.isfinite(np.asarray(d_q)).all()
    corr = np.corrcoef(np.asarray(d_fp).ravel(),
                       np.asarray(d_q).ravel())[0, 1]
    # MoE: quantized router logits can flip expert choices on a tiny
    # 8-expert model, so the bar is lower there
    assert corr > (0.75 if cfg.family == "moe" else 0.9)


def test_int4_fraction_monotone_quality():
    """Higher INT4 fraction → more quant error (sanity direction check)."""
    cfg = get_smoke_config("llama3_8b")
    key = jax.random.PRNGKey(0)
    params, axes = LM(cfg).init(key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    lg_fp, _ = jax.jit(LM(cfg).train_logits)(params, tokens)
    errs = []
    for frac in (0.0, 1.0):
        qc = QuantConfig(int4_fraction=frac, impl="ref", kv4=False)
        lmq = LM(cfg, quant=qc)
        qparams, _ = lmq.quantize(params, axes)
        lg_q, _ = jax.jit(lmq.train_logits)(qparams, tokens)
        errs.append(float(jnp.mean(jnp.abs(lg_q - lg_fp))))
    assert errs[0] < errs[1]   # all-A8 beats all-A4
