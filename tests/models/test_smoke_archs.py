"""Per-arch reduced-config smoke: one forward + one train step on CPU,
asserting output shapes and finiteness (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models.lm import LM
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step

B, S = 2, 32


def make_batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    key = jax.random.PRNGKey(0)
    params, axes = lm.init(key)
    batch = make_batch(cfg, key)
    extra = {k: batch[k] for k in ("frames", "image_embeds") if k in batch}
    logits, aux = jax.jit(
        lambda p, t: lm.train_logits(p, t, extra or None))(
        params, batch["tokens"])
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    key = jax.random.PRNGKey(1)
    params, axes = lm.init(key)
    opt_state = OPT.adamw_init(params)
    step = jax.jit(make_train_step(lm, OPT.AdamWConfig(lr=1e-3)))
    batch = make_batch(cfg, key)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    delta = sum(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0
