"""AdamW from-scratch: convergence, clipping, schedule shape."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.training import optimizer as OPT


def test_adamw_minimizes_quadratic():
    cfg = OPT.AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = OPT.adamw_init(params)
    target = jnp.asarray([1.0, 2.0])
    loss = lambda p: jnp.sum((p["x"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = OPT.adamw_update(cfg, params, g, state)
    np.testing.assert_allclose(np.asarray(params["x"]),
                               np.asarray(target), atol=1e-2)


def test_grad_clipping_bounds_update():
    cfg = OPT.AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros(4)}
    state = OPT.adamw_init(params)
    huge = {"x": jnp.full(4, 1e6)}
    _, state, m = OPT.adamw_update(cfg, params, huge, state)
    assert float(m["grad_norm"]) > 1e6          # reported pre-clip
    assert float(jnp.abs(state["m"]["x"]).max()) <= 0.2  # post-clip moment


def test_cosine_schedule_shape():
    sched = OPT.cosine_schedule(warmup=10, total=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 0.01
    assert float(sched(jnp.asarray(100))) <= 0.12
    assert float(sched(jnp.asarray(5))) == 0.5
