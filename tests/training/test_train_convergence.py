"""Integration: a tiny LM learns the synthetic markov data (loss drops)."""
import jax

from repro.configs.base import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.lm import LM
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step


def test_loss_decreases():
    cfg = get_smoke_config("llama3_8b")
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    opt_state = OPT.adamw_init(params)
    step = jax.jit(make_train_step(
        lm, OPT.AdamWConfig(lr=2e-3, weight_decay=0.0)))
    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=0))
    losses = []
    for i in range(30):
        params, opt_state, m = step(params, opt_state, data.batch_for_step(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
