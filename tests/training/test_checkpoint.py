"""Checkpoint atomicity, roundtrip, resume, GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as CKPT


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    tree = make_tree()
    CKPT.save(str(tmp_path), 7, tree, extra={"note": "x"})
    restored, extra, step = CKPT.restore(str(tmp_path), tree)
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_ignores_incomplete(tmp_path):
    tree = make_tree()
    CKPT.save(str(tmp_path), 1, tree)
    # simulate a crashed save: directory without _COMPLETE
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert CKPT.latest_step(str(tmp_path)) == 1


def test_async_save_then_restore(tmp_path):
    tree = make_tree(3)
    CKPT.save_async(str(tmp_path), 5, tree)
    CKPT.wait_async()
    restored, _, step = CKPT.restore(str(tmp_path), tree)
    assert step == 5


def test_cleanup_keeps_last(tmp_path):
    tree = make_tree()
    for s in (1, 2, 3, 4):
        CKPT.save(str(tmp_path), s, tree)
    CKPT.cleanup(str(tmp_path), keep_last=2)
    assert CKPT.latest_step(str(tmp_path)) == 4
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000003", "step_00000004"]


def test_restore_shape_mismatch_raises(tmp_path):
    CKPT.save(str(tmp_path), 1, make_tree())
    bad_template = {"a": jnp.zeros((2, 2)),
                    "nested": {"b": jnp.zeros(6, jnp.int32)}}
    with pytest.raises(AssertionError):
        CKPT.restore(str(tmp_path), bad_template)
