"""Gradient compression: roundtrip bound, error feedback, convergence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.training import compression as GC
from repro.training import optimizer as OPT


def test_compress_roundtrip_bound(rng):
    g = jnp.asarray(rng.normal(size=(64, 32)) * 3, jnp.float32)
    q, s = GC.compress_tensor(g)
    err = np.abs(np.asarray(GC.decompress_tensor(q, s)) - np.asarray(g))
    assert err.max() <= float(s) * 0.5 + 1e-7
    assert q.dtype == jnp.int8           # 4× wire reduction vs f32


def test_error_feedback_accumulates():
    grads = {"w": jnp.full((8,), 0.001, jnp.float32)}
    ef = GC.init_error_feedback(grads)
    # one tiny gradient quantizes to ~0 but the error carries forward
    total = jnp.zeros((8,))
    for _ in range(200):
        comp, ef = GC.compress_grads(grads, ef)
        (q, s) = comp["w"]
        total = total + GC.decompress_tensor(q, s)
    # long-run mean of the decompressed stream matches the true gradient
    np.testing.assert_allclose(np.asarray(total) / 200, 0.001, rtol=0.05)


def test_compressed_training_converges():
    from repro.configs.base import get_smoke_config
    from repro.data.pipeline import DataConfig, SyntheticLMData
    from repro.models.lm import LM
    cfg = get_smoke_config("llama3_8b")
    lm = LM(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    opt_state = OPT.adamw_init(params)
    ef = GC.init_error_feedback(params)
    step = jax.jit(GC.make_compressed_train_step(
        lm, OPT.AdamWConfig(lr=2e-3, weight_decay=0.0)))
    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=8))
    losses = []
    for i in range(25):
        params, opt_state, ef, m = step(params, opt_state, ef,
                                        data.batch_for_step(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.4, losses[::6]
