"""Data pipeline determinism + host sharding."""
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLMData


def test_batches_deterministic():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=4, seed=11)
    a = SyntheticLMData(cfg).batch_for_step(9)
    b = SyntheticLMData(cfg).batch_for_step(9)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_steps_differ():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=4)
    a = SyntheticLMData(cfg).batch_for_step(1)
    b = SyntheticLMData(cfg).batch_for_step(2)
    assert (np.asarray(a["tokens"]) != np.asarray(b["tokens"])).any()


def test_hosts_get_disjoint_streams():
    base = dict(vocab_size=512, seq_len=16, global_batch=8, num_hosts=2)
    a = SyntheticLMData(DataConfig(**base, host_id=0)).batch_for_step(0)
    b = SyntheticLMData(DataConfig(**base, host_id=1)).batch_for_step(0)
    assert a["tokens"].shape[0] == 4
    assert (np.asarray(a["tokens"]) != np.asarray(b["tokens"])).any()


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=2)
    batch = SyntheticLMData(cfg).batch_for_step(0)
    np.testing.assert_array_equal(np.asarray(batch["tokens"][:, 1:]),
                                  np.asarray(batch["labels"][:, :-1]))
