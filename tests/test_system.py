"""End-to-end behaviour tests for the COMET reproduction."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig
from repro.training import checkpoint as CKPT
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step


def test_train_quantize_serve_pipeline(tmp_path):
    """The full paper workflow: train (fp) → PTQ (FMPQ W4AxKV4) → serve."""
    cfg = get_smoke_config("llama3_8b")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    opt_state = OPT.adamw_init(params)
    step = jax.jit(make_train_step(lm, OPT.AdamWConfig(lr=2e-3)))
    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=48, global_batch=4))
    for i in range(10):
        params, opt_state, metrics = step(params, opt_state,
                                          data.batch_for_step(i))
    assert np.isfinite(float(metrics["loss"]))

    # checkpoint → restart → identical state
    CKPT.save(str(tmp_path), 10, (params, opt_state))
    (params2, _), _, _ = CKPT.restore(str(tmp_path), (params, opt_state))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # quantize + serve
    qc = QuantConfig(int4_fraction=0.75, impl="ref")
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    eng = Engine(cfg, qparams, qc,
                 EngineConfig(max_batch=2, num_pages=32, page_size=16))
    eng.add_request(0, [1, 2, 3, 4], 5)
    done = eng.run()
    assert len(done) == 1 and len(done[0].generated) == 5


def test_quantization_preserves_trained_behaviour():
    """After brief training, quantized logits still track fp logits."""
    cfg = get_smoke_config("llama3_8b")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(1))
    opt_state = OPT.adamw_init(params)
    step = jax.jit(make_train_step(lm, OPT.AdamWConfig(lr=2e-3)))
    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=48, global_batch=4, seed=5))
    for i in range(8):
        params, opt_state, _ = step(params, opt_state, data.batch_for_step(i))
    tokens = data.batch_for_step(99)["tokens"][:2, :24]
    lg_fp, _ = jax.jit(lm.train_logits)(params, tokens)
    qc = QuantConfig(int4_fraction=0.875, impl="ref")
    lmq = LM(cfg, quant=qc)
    qparams, _ = lmq.quantize(params, axes)
    lg_q, _ = jax.jit(lmq.train_logits)(qparams, tokens)
    corr = np.corrcoef(np.asarray(lg_fp).ravel(),
                       np.asarray(lg_q).ravel())[0, 1]
    assert corr > 0.95
