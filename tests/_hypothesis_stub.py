"""Fixed-seed stand-in for `hypothesis` so tier-1 runs hermetically.

Implements the small strategy surface the suite uses (``integers``,
``sampled_from``, ``floats``, ``.map``) plus ``given``/``settings``. Each
``@given`` test runs ``max_examples`` times over samples drawn from a
fixed-seed generator — deterministic, no shrinking, no database, no
network. When the real `hypothesis` is installed the test modules import
it instead and this file is inert.
"""

from __future__ import annotations

import numpy as np

__all__ = ["given", "settings", "strategies", "st"]

_SEED = 0x5EED


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


st = strategies


class settings:
    """Profile registry mirroring hypothesis.settings' classmethod API."""

    _profiles: dict = {}
    _max_examples: int = 20

    def __init__(self, **kwargs):
        self.max_examples = kwargs.get("max_examples",
                                       type(self)._max_examples)

    @classmethod
    def register_profile(cls, name, max_examples=20, **kwargs):
        cls._profiles[name] = max_examples

    @classmethod
    def load_profile(cls, name):
        cls._max_examples = cls._profiles.get(name, cls._max_examples)


def given(*arg_strategies, **kw_strategies):
    def decorator(fn):
        def wrapper(*args, **kwargs):
            rng = np.random.default_rng(_SEED)
            for _ in range(settings._max_examples):
                drawn = [s.draw(rng) for s in arg_strategies]
                kdrawn = {k: s.draw(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **kdrawn)
        # Deliberately no functools.wraps: pytest must see the wrapper's
        # empty signature, not the strategy params (they aren't fixtures).
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return decorator
