"""Work-queue vs dense attention schedule through the serving engine.

The two schedules are the SAME math reassociated (per-page partial
softmax + split-KV combine vs one online-softmax walk), so greedy
output must be token-identical — each scenario pins a workload seed
with healthy argmax margins, the same practice as the unified-vs-split
and chunked-vs-whole parity suites (bf16 reassociation noise is
O(1e-2) on logits and flips argmax only on near-ties).

Also pinned here: the schedule's accounting (identical real work,
strictly smaller launched grid, strictly less padding waste than the
dense rectangle), the one-forward-per-step invariant and trace plateau
under work-item bucketing (the jit-cache dimension that replaces
npages), and parity across mid-decode snapshot/restore. The smoke
config is GQA (4 query heads over 2 kv heads), so every sweep
exercises grouped heads; bucketed batches (nseq rounded up to pow-2)
exercise qlen-0 pad rows on every non-pow-2 workload.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    return cfg, qc, qparams


def make_engine(setup, sched, **kw):
    cfg, qc, qparams = setup
    defaults = dict(max_batch=6, num_pages=128, page_size=8,
                    max_pages_per_seq=32, prefill_chunk_tokens=24,
                    kv_range=4.0, attention_schedule=sched)
    defaults.update(kw)
    return Engine(cfg, qparams, qc, EngineConfig(**defaults))


def run_tokens(eng, prompts, max_new, max_steps=400):
    for i, p in enumerate(prompts):
        eng.add_request(i, p, max_new)
    done = eng.run(max_steps=max_steps)
    assert sorted(r.request_id for r in done) == list(range(len(prompts)))
    return {r.request_id: list(r.generated) for r in done}


def ragged_prompts(lens, vocab, seed):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, n).tolist() for n in lens]


MIXES = {
    # (prompt lens, max_new, pinned workload seed)
    # one dominant long-context row serializing the dense grid while
    # short rows pad to its page count — the Fig. 8 imbalance
    "dominant_long_row": ((96, 6, 9, 5, 12, 7), 16, 1),
    # ragged steady-state mix (prefill chunks + decode rows united)
    "ragged_mix": ((40, 7, 23, 64, 13, 29), 8, 1),
    # batch of one: a single row still combines across its page items
    "batch_one": ((50,), 12, 1),
    # 5 rows bucket to nseq=8 → three qlen-0 pad rows in every forward
    "pad_rows": ((9, 17, 5, 26, 11), 6, 1),
}


@pytest.mark.parametrize("mix", list(MIXES))
def test_wq_matches_dense_greedy(setup, mix):
    cfg = setup[0]
    lens, max_new, seed = MIXES[mix]
    prompts = ragged_prompts(lens, cfg.vocab_size, seed)
    dense = run_tokens(make_engine(setup, "dense"), prompts, max_new)
    wq = run_tokens(make_engine(setup, "work_queue"), prompts, max_new)
    assert wq == dense


def test_wq_matches_dense_split_step_decode(setup):
    """The split-step baseline's separate decode forward also honors the
    schedule knob (work-queue decode kernel), token-identically."""
    cfg = setup[0]
    prompts = ragged_prompts((24, 7, 13), cfg.vocab_size, seed=1)
    dense = run_tokens(make_engine(setup, "dense", unified_step=False),
                       prompts, 8)
    wq = run_tokens(make_engine(setup, "work_queue", unified_step=False),
                    prompts, 8)
    assert wq == dense


def test_wq_counters_fewer_grid_items(setup):
    """Same real work, strictly smaller launched grid, strictly less
    padding waste — the measured Stream-K claim, as counters."""
    cfg = setup[0]
    lens, max_new, seed = MIXES["dominant_long_row"]
    prompts = ragged_prompts(lens, cfg.vocab_size, seed)
    dn = make_engine(setup, "dense")
    run_tokens(dn, prompts, max_new)
    wq = make_engine(setup, "work_queue")
    run_tokens(wq, prompts, max_new)
    assert wq.attn_work_items == dn.attn_work_items > 0
    assert wq.attn_grid_items < dn.attn_grid_items
    assert dn.attn_grid_items == dn.attn_dense_grid_items
    assert wq.attn_dense_grid_items == dn.attn_dense_grid_items
    wq_waste = wq.attn_grid_items - wq.attn_work_items
    dn_waste = dn.attn_grid_items - dn.attn_work_items
    assert wq_waste < dn_waste
    # the wq grid is the pow-2 bucketed work count (min 8 per forward)
    assert wq.attn_grid_items < 2 * wq.attn_work_items + 8 * wq.attn_forwards


def test_wq_trace_plateau_and_one_forward_per_step(setup):
    """The work-item bucket replaces npages as the attention dimension
    of the jit-cache key: steady-state decode reuses the compiled
    forward (trace plateau) and the one-forward-per-step invariant
    survives the schedule swap."""
    cfg = setup[0]
    prompts = ragged_prompts((5, 3, 7, 4, 6, 2), cfg.vocab_size, seed=1)
    eng = make_engine(setup, "work_queue", page_size=64, num_pages=16,
                      max_pages_per_seq=4, prefill_chunk_tokens=32)
    for i, p in enumerate(prompts):
        eng.add_request(i, p, 24)
    eng.step()                          # prefill forward
    eng.step()                          # first decode forward
    warm = eng.trace_count
    assert warm >= 1
    eng.run(max_steps=400)
    assert eng.trace_count == warm      # plateau: no steady-state retrace
    assert eng.forward_calls == eng.steps
    assert all(len(r.generated) == 24 for r in eng.sched.finished)


def test_wq_matches_dense_mid_decode_snapshot_restore(setup):
    """Snapshot mid-decode (multi-page block tables live), restore, and
    drain — both schedules walk the identical restore path, so their
    final text must match each other token for token."""
    cfg, qc, qparams = setup
    prompts = ragged_prompts((11, 19, 7), cfg.vocab_size, seed=2)
    out = {}
    for sched in ("dense", "work_queue"):
        ecfg = EngineConfig(max_batch=3, num_pages=64, page_size=4,
                            kv_range=4.0, attention_schedule=sched)
        eng = Engine(cfg, qparams, qc, ecfg)
        for i, p in enumerate(prompts):
            eng.add_request(i, p, 7)
        for _ in range(4):
            eng.step()
        assert any((eng.cache.block_table[r.seq_slot] >= 0).sum() >= 2
                   for r in eng.sched.running)
        blob = eng.snapshot()
        del eng                          # crash
        eng2 = Engine.restore(blob, cfg, qparams, qc, ecfg)
        done = eng2.run()
        assert sorted(r.request_id for r in done) == [0, 1, 2]
        out[sched] = {r.request_id: (list(r.prompt), list(r.generated))
                      for r in done}
    assert out["work_queue"] == out["dense"]


def test_wq_unmapped_page_error_names_caller_seq_ids():
    """The unmapped-page guard names the CALLER's sequences. Raw
    ``build_work_queue`` only knows positional batch rows; with
    ``seq_ids`` (what ``work_queue_np`` threads through) it reports
    cache slots instead — the batch is usually a non-contiguous slot
    subset, so positional rows point at the wrong sequence
    (regression: the message used to call the row index a "seq")."""
    from repro.configs.base import get_smoke_config
    from repro.serving.kv_cache import (PagedKV4Cache, PagedKV4Config,
                                        build_work_queue)
    tables = np.asarray([[3, 7], [5, -1]], np.int32)   # row 1 unmapped
    ctx = np.asarray([8, 8])                           # 2 pages @ ps=4
    with pytest.raises(IndexError, match=r"batch row\(s\) \[1\]"):
        build_work_queue(tables, ctx, page_size=4, num_kv_heads=2)
    with pytest.raises(IndexError, match=r"seq slot\(s\) \[9\]"):
        build_work_queue(tables, ctx, page_size=4, num_kv_heads=2,
                         seq_ids=[4, 9])
    # through the cache wrapper: slots (0, 2) are a non-contiguous
    # subset — the error must name slot 2, not batch row 1
    cfg = get_smoke_config("llama3_8b")
    cache = PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=8, page_size=4, max_seqs=4,
                            max_pages_per_seq=4), 1)
    assert cache.allocate_seq(0, 8) and cache.allocate_seq(2, 4)
    with pytest.raises(IndexError, match=r"seq slot\(s\) \[2\]"):
        cache.work_queue_np([0, 2], np.asarray([8, 8]))


def test_wq_temperature_sampling_deterministic(setup):
    """(request_id, position)-keyed sampling reproduces stochastic text
    under the work-queue schedule too."""
    cfg = setup[0]
    prompts = ragged_prompts((9, 17, 5), cfg.vocab_size, seed=1)
    kw = dict(temperature=0.8, top_k=8)
    a = run_tokens(make_engine(setup, "work_queue", **kw), prompts, 6)
    b = run_tokens(make_engine(setup, "work_queue", **kw), prompts, 6)
    assert a == b
    assert any(len(set(t)) > 1 for t in a.values())   # actually sampled
