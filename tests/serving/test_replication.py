"""Replica groups: health-checked failover with exactly-once migration.

The acceptance contract for ``serving/replication.py``: for every kill
point (mid-prefill, mid-decode, mid-snapshot-gap) and both failover
policies, every client stream is greedy-token-identical to the
no-failure group run, each request's terminal event is delivered
exactly once, the surviving replicas' pools drain back to baseline, and
no step ever escapes into ``internal_errors``. Plus the control plane:
least-loaded routing, bounded-queue backpressure under halved capacity,
heartbeat-deadline deaths, and standby promotion health states.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.api import SamplingParams
from repro.serving.engine import EngineConfig
from repro.serving.faults import Fault, FaultInjector
from repro.serving.replication import ReplicaGroup

# small chunk so prefill spans several steps — a step-2 kill lands
# genuinely mid-prefill
ECFG = dict(max_batch=4, num_pages=64, page_size=8, max_pages_per_seq=16,
            prefill_chunk_tokens=8, kv_range=4.0,
            # every replica engine (and every failover-resumed one — the
            # ecfg is shared) runs the step-boundary runtime sanitizers
            sanitize=True)
SNAP = 4                        # checkpoint cadence: gap kills at 6/7
MAX_NEW = 6


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    return cfg, qc, qparams


def _prompts(n=3, seed=41):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 100, int(rng.integers(12, 18))).tolist()
            for _ in range(n)]


def make_group(setup, **kw):
    cfg, qc, qparams = setup
    ecfg = EngineConfig(**dict(ECFG, **kw.pop("ecfg", {})))
    kw.setdefault("replicas", 2)
    kw.setdefault("snapshot_every", SNAP)
    return ReplicaGroup(cfg, qparams, qc, ecfg, **kw)


def _drive(group, prompts, max_new=MAX_NEW):
    rids = [group.submit(p, SamplingParams(max_new_tokens=max_new))
            for p in prompts]
    group.run()
    return rids


@pytest.fixture(scope="module")
def reference(setup):
    """The no-failure group run every chaos case is compared against."""
    group = make_group(setup)
    rids = _drive(group, _prompts())
    assert group.failovers == 0 and group.internal_errors == 0
    return {rid: (group.tokens_for(rid), group.terminal_for(rid))
            for rid in rids}


# ------------------------------------------------------------- chaos sweep


@pytest.mark.parametrize("failover", ["standby", "migrate"])
@pytest.mark.parametrize("kill_step,phase",
                         [(2, "mid_prefill"), (6, "mid_decode"),
                          (7, "mid_snapshot_gap")])
def test_kill_sweep_streams_identical(setup, reference, failover,
                                      kill_step, phase):
    """Kill replica 0 deterministically at each phase: the group's
    delivered streams equal the no-failure run bitwise, one terminal
    each, survivors drain to pool baseline, internal_errors == 0."""
    faults = [FaultInjector([Fault("crash", step=kill_step)]),
              FaultInjector()]
    group = make_group(setup, failover=failover, faults=faults)
    rids = _drive(group, _prompts())

    assert group.failovers == 1
    assert group.deaths and group.deaths[0][1] == "crash"
    assert group.internal_errors == 0
    for rid in rids:
        toks, term = reference[rid]
        assert group.tokens_for(rid) == toks, phase
        got = group.terminal_for(rid)
        assert got is not None and got.state == term.state
    # exactly-once is structural (terminals is a dict) — also prove no
    # duplicate slipped through the suppression counter unnoticed:
    # every suppressed duplicate is counted, never delivered
    assert len(group.terminals) == len(rids)
    for rep in group.replicas:
        if rep.alive:
            assert rep.engine.cache.pages_free == ECFG["num_pages"]
            assert rep.engine.internal_errors == 0
    if failover == "standby":
        assert group.health[0] == "promoted"
        assert all(r.alive for r in group.replicas)
    else:
        assert group.health[0] == "dead:crash"
        assert group.migrated_requests >= 0


def test_migrate_moves_in_flight_requests(setup, reference):
    """A mid-decode kill in migrate mode actually moves work: the dead
    replica owned requests, they complete on the survivor, and the
    owner map points at the survivor afterwards."""
    faults = [FaultInjector([Fault("crash", step=6)]), FaultInjector()]
    group = make_group(setup, failover="migrate", faults=faults)
    rids = _drive(group, _prompts())
    assert group.migrated_requests > 0
    assert all(group.owner[rid] == 1 for rid in rids)
    for rid in rids:
        assert group.tokens_for(rid) == reference[rid][0]


# ------------------------------------------------------- health + routing


def test_heartbeat_deadline_kills_slow_replica(setup, reference):
    """A replica whose step overruns the heartbeat deadline is marked
    dead and its slow step's events are discarded — the survivor
    regenerates them, so streams still match the no-failure run."""
    t = {"now": 0.0}
    group = make_group(setup, failover="migrate", heartbeat_s=1.0,
                       clock=lambda: t["now"])
    rep = group.replicas[0]
    orig = rep.log.step

    def slow_step():
        out = orig()
        if rep.engine.steps >= 3:
            t["now"] += 5.0              # blows the 1s deadline
        return out

    rep.log.step = slow_step
    rids = _drive(group, _prompts())
    assert group.health[0] == "dead:heartbeat"
    assert group.failovers == 1
    assert group.internal_errors == 0
    for rid in rids:
        assert group.tokens_for(rid) == reference[rid][0]
        assert group.terminal_for(rid) is not None


def test_least_loaded_routing_spreads_requests(setup):
    """Submits spread over the replicas by in-flight load — with equal
    loads the tie breaks by index, so alternating submits alternate."""
    group = make_group(setup)
    rids = [group.submit(p, SamplingParams(max_new_tokens=2))
            for p in _prompts(n=4, seed=43)]
    owners = [group.owner[rid] for rid in rids]
    assert owners == [0, 1, 0, 1]
    group.run()
    assert len(group.terminals) == 4


def test_backpressure_rejects_when_all_replicas_full(setup):
    """Per-replica admission backpressure: with bounded waiting queues
    saturated everywhere, extra submits land on the least-loaded
    replica and its engine rejects them (FAILED queue_full) — explicit,
    counted outcomes instead of unbounded queues."""
    group = make_group(setup, ecfg=dict(max_batch=1, max_waiting=1))
    rids = [group.submit(p, SamplingParams(max_new_tokens=2))
            for p in _prompts(n=8, seed=47)]
    group.run()
    assert len(group.terminals) == 8             # one terminal each
    rejected = [rid for rid in rids
                if group.terminal_for(rid).stop_reason == "queue_full"]
    served = [rid for rid in rids
              if group.terminal_for(rid).state.value == "finished"]
    assert rejected and served
    assert sum(r.engine.rejected_count for r in group.replicas) \
        == len(rejected)


def test_shed_on_halved_capacity(setup):
    """When a kill halves capacity, migrated load beyond the survivor's
    bounded queue degrades through the existing reject/shed path — every
    request still gets exactly one terminal."""
    faults = [FaultInjector([Fault("crash", step=6)]), FaultInjector()]
    group = make_group(setup, failover="migrate", faults=faults,
                       ecfg=dict(max_batch=2, max_waiting=2))
    rids = [group.submit(p, SamplingParams(max_new_tokens=4))
            for p in _prompts(n=8, seed=53)]
    group.run()
    assert group.failovers == 1
    assert len(group.terminals) == len(rids)     # exactly-once, all of them
    reasons = {group.terminal_for(rid).stop_reason for rid in rids}
    # at least some requests were degraded explicitly (rejected at
    # submit or shed by preemption) rather than silently queued forever
    survivor = group.replicas[1].engine
    assert survivor.rejected_count + survivor.shed_count > 0 \
        or "queue_full" in reasons or "shed" in reasons
    assert survivor.cache.pages_free == ECFG["num_pages"]


def test_replica_lost_without_survivors_fails_terminally(setup):
    """Total loss (single replica, migrate, no survivors): every
    in-flight request gets exactly one synthesized FAILED
    replica_lost terminal — streams end, they don't hang."""
    faults = [FaultInjector([Fault("crash", step=3)])]
    group = make_group(setup, replicas=1, failover="migrate",
                       faults=faults)
    rids = [group.submit(p, SamplingParams(max_new_tokens=MAX_NEW))
            for p in _prompts(n=2, seed=59)]
    group.run()
    assert not group.has_work
    for rid in rids:
        term = group.terminal_for(rid)
        assert term is not None
        assert term.stop_reason == "replica_lost"
        assert term.state.value == "failed"


def test_group_validates_arguments(setup):
    cfg, qc, qparams = setup
    ecfg = EngineConfig(**ECFG)
    with pytest.raises(ValueError, match="replicas"):
        ReplicaGroup(cfg, qparams, qc, ecfg, replicas=0)
    with pytest.raises(ValueError, match="failover"):
        ReplicaGroup(cfg, qparams, qc, ecfg, failover="bogus")
    with pytest.raises(ValueError, match="one injector per replica"):
        ReplicaGroup(cfg, qparams, qc, ecfg, replicas=2,
                     faults=[FaultInjector()])
