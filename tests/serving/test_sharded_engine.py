"""Tensor-parallel sharded engine: greedy parity with single-device.

These tests need >= 2 JAX devices; CI runs them in a dedicated job with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (on a stock
single-device CPU host they skip). Parity is asserted bitwise on greedy
tokens: ``int4_fraction=1.0`` keeps every act-quant block shard-local
(q_dim and d_ff split on 128-channel boundaries) and the wo / w_down
all-reduce seams keep f32 partials until one final bf16 rounding, so
the sharded forward reproduces the single-device forward exactly.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")

# smoke configs keep q_dim=128 — one act-quant block, unsplittable;
# head_dim=64 gives q_dim=256 and d_ff=256: two blocks, shardable by 2
CFG = dataclasses.replace(get_smoke_config("llama3_8b"), head_dim=64)
QC = QuantConfig(int4_fraction=1.0, impl="ref")
TP = 2


@pytest.fixture(scope="module")
def model():
    lm = LM(CFG)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, qaxes = LM(CFG, quant=QC).quantize(params, axes)
    return qparams, qaxes


def _engine(model, mesh, **ecfg):
    qparams, qaxes = model
    cfg = EngineConfig(max_batch=4, num_pages=64, page_size=8,
                       kv_range=4.0, **ecfg)
    return Engine(CFG, qparams, QC, cfg, mesh=mesh,
                  param_axes=qaxes if mesh is not None else None)


def _run_pair(model, prompts, max_new, **ecfg):
    out = []
    for mesh in (None, make_local_mesh(1, TP)):
        eng = _engine(model, mesh, **ecfg)
        for i, p in enumerate(prompts):
            eng.add_request(i, p, max_new)
        done = eng.run(max_steps=300)
        toks = {r.request_id: list(r.generated) for r in done}
        out.append((eng, toks))
    return out


def _prompts(lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, CFG.vocab_size, n).tolist() for n in lens]


def test_sharded_parity_mixed(model):
    """Mixed ragged prefill + decode: tokens bitwise-identical, the
    one-forward-per-step seam survives shard_map, traces don't grow,
    and the per-shard work counters tile the total evenly."""
    (e1, t1), (e2, t2) = _run_pair(model, _prompts((11, 19, 7, 26)), 8)
    assert e2.tp_size == TP
    assert t2 == t1
    assert e2.forward_calls == e2.steps
    assert e2.trace_count <= e1.trace_count
    assert len(e2.attn_work_items_per_shard) == TP
    assert sum(e2.attn_work_items_per_shard) == e2.attn_work_items
    assert max(e2.attn_work_items_per_shard) == min(
        e2.attn_work_items_per_shard)
    # head-sharding changes per-shard work, not the global accounting
    assert e2.attn_work_items == e1.attn_work_items


def test_sharded_parity_decode_only(model):
    """Near-trivial prefill, long decode: the paged int4 read path (the
    work-queue kernel over sharded pools) dominates every step."""
    (e1, t1), (e2, t2) = _run_pair(model, _prompts((1, 2, 1, 3)), 12)
    assert t2 == t1
    assert e2.forward_calls == e2.steps
    assert sum(e2.attn_work_items_per_shard) == e2.attn_work_items


def test_sharded_parity_prefix_cache(model):
    """Published prefix pages are a host-global namespace: cache hits
    skip the same prefill tokens under TP and decode from pages written
    by a DIFFERENT request's sharded forward."""
    rng = np.random.default_rng(7)
    prefix = rng.integers(1, CFG.vocab_size, 16).tolist()
    sfx = [rng.integers(1, CFG.vocab_size, n).tolist() for n in (5, 9)]
    out = []
    for mesh in (None, make_local_mesh(1, TP)):
        eng = _engine(model, mesh, prefix_cache=True,
                      max_pages_per_seq=16)
        eng.add_request(0, prefix + sfx[0], 6)
        eng.run(max_steps=200)            # publisher completes
        eng.add_request(1, prefix + sfx[1], 6)
        eng.run(max_steps=200)
        toks = {r.request_id: list(r.generated)
                for r in eng.sched.finished}
        out.append((eng, toks))
    (e1, t1), (e2, t2) = out
    assert t2 == t1
    assert e2.prefix_hit_tokens == e1.prefix_hit_tokens
    assert e2.prefix_hit_tokens > 0
    assert e2.forward_calls == e2.steps


def test_sharded_dense_schedule_parity(model):
    """The fig10-ablated dense grid (no work queue) shards the same
    way — parity must not depend on the descriptor path."""
    (e1, t1), (e2, t2) = _run_pair(
        model, _prompts((9, 14)), 6, attention_schedule="dense")
    assert t2 == t1
    assert e2.forward_calls == e2.steps


def test_sharded_speculation_parity(model):
    """Speculative decode under TP: the verify chunk (qlen 1+k decode
    row) rides the sharded forward, and greedy output stays bitwise
    identical to single-device spec-off — drafts change the step
    count, never the tokens."""
    from repro.serving.engine import SamplingParams
    # greedy decode on THIS model (head_dim=64 reshapes the random
    # weights) takes a few tokens to fall into its absorbing cycles,
    # so give it cycle-prone prompts and enough output length
    prompts = [[188] * 12, [49] * 8, [188] * 10]
    out = []
    for mesh, k in ((None, 0), (make_local_mesh(1, TP), 0),
                    (make_local_mesh(1, TP), 4)):
        eng = _engine(model, mesh, sanitize=True)
        for i, p in enumerate(prompts):
            eng.submit(p, SamplingParams(max_new_tokens=20,
                                         temperature=0.0, speculation=k),
                       request_id=i)
        done = eng.run(max_steps=300)
        toks = {r.request_id: list(r.generated) for r in done}
        out.append((eng, toks))
    (e1, t1), (e2, t2), (e3, t3) = out
    assert e3.tp_size == TP
    assert t2 == t1 and t3 == t1
    assert e3.spec_draft_tokens > 0 and e3.spec_accepted_tokens > 0
    assert e3.spec_draft_tokens == \
        e3.spec_accepted_tokens + e3.spec_rollback_tokens
    assert e3.steps < e2.steps          # drafts actually shrank the run
    for eng in (e1, e2, e3):
        assert eng.internal_errors == 0


def test_sharded_requires_param_axes(model):
    """mesh without param_axes cannot place weights — loud error, not
    a silently replicated (wrong-counter) engine."""
    qparams, _ = model
    with pytest.raises(ValueError, match="param_axes"):
        Engine(CFG, qparams, QC,
               EngineConfig(max_batch=2, num_pages=32, page_size=8),
               mesh=make_local_mesh(1, TP), param_axes=None)


def test_sharded_rejects_indivisible_heads():
    """num_kv_heads % tp != 0 must fail fast at construction."""
    bad = dataclasses.replace(CFG, num_heads=3, num_kv_heads=3)
    lm = LM(bad)
    params, axes = lm.init(jax.random.PRNGKey(1))
    qp, qa = LM(bad, quant=QC).quantize(params, axes)
    with pytest.raises(ValueError, match="num_kv_heads|num_heads"):
        Engine(bad, qp, QC,
               EngineConfig(max_batch=2, num_pages=32, page_size=8),
               mesh=make_local_mesh(1, TP), param_axes=qa)


def test_sharded_fault_isolation_and_invariants(model):
    """Chaos under TP: a NaN-logits fault quarantines one request while
    the survivors keep decoding bitwise-identically to the single-device
    engine under the SAME schedule, pages return to baseline, and
    step() never raises on either side."""
    from repro.serving.api import RequestState
    from repro.serving.faults import Fault, FaultInjector
    qparams, qaxes = model
    prompts = _prompts((9, 12, 7), seed=13)
    out = []
    for mesh in (None, make_local_mesh(1, TP)):
        eng = Engine(CFG, qparams, QC,
                     EngineConfig(max_batch=4, num_pages=64, page_size=8,
                                  kv_range=4.0),
                     mesh=mesh, param_axes=qaxes if mesh else None,
                     faults=FaultInjector([Fault("forward", step=3,
                                                 action="nan", row=0)]))
        for i, p in enumerate(prompts):
            eng.add_request(i, p, 6)
        eng.run(max_steps=300)
        out.append(eng)
    e1, e2 = out
    assert e2.tp_size == TP
    for eng in out:
        assert eng.internal_errors == 0
        assert eng.failed_count == 1
        assert eng.cache.pages_free == 64
        assert (eng.cache.ref == 0).all()
    by_state = lambda e, s: sorted(
        r.request_id for r in e.sched.finished if r.state == s)
    assert by_state(e2, RequestState.FAILED) == \
        by_state(e1, RequestState.FAILED)
    # survivors' tokens stay bitwise equal to single-device
    assert {r.request_id: list(r.generated)
            for r in e2.sched.finished
            if r.state == RequestState.FINISHED} == \
        {r.request_id: list(r.generated)
         for r in e1.sched.finished if r.state == RequestState.FINISHED}


def test_sharded_full_snapshot_resumes_bitwise(model):
    """snapshot(full=True)/restore under TP: restore re-lays the int4
    pools over the mesh, and the continuation equals the uninterrupted
    sharded run token-for-token."""
    qparams, qaxes = model
    mesh = make_local_mesh(1, TP)
    ecfg = EngineConfig(max_batch=4, num_pages=64, page_size=8,
                        kv_range=4.0)
    prompts = _prompts((10, 15), seed=17)

    ref_eng = Engine(CFG, qparams, QC, ecfg, mesh=mesh, param_axes=qaxes)
    for i, p in enumerate(prompts):
        ref_eng.add_request(i, p, 8)
    ref = {r.request_id: list(r.generated)
           for r in ref_eng.run(max_steps=300)}

    eng = Engine(CFG, qparams, QC, ecfg, mesh=mesh, param_axes=qaxes)
    for i, p in enumerate(prompts):
        eng.add_request(i, p, 8)
    for _ in range(4):
        eng.step()                         # mid-decode "crash"
    blob = eng.snapshot(full=True)
    eng2 = Engine.restore(blob, CFG, qparams, QC, ecfg, mesh=mesh,
                          param_axes=qaxes)
    eng2.run(max_steps=300)
    assert {r.request_id: list(r.generated)
            for r in eng2.sched.finished} == ref
    assert eng2.cache.pages_free == 64
