"""Step-boundary runtime sanitizer tests (EngineConfig.sanitize).

The checks must be LIVE, not vacuous: each case deliberately corrupts an
invariant the serving core guarantees — a page refcount, the exactly-
one-terminal event contract — and asserts ``sanitize=True`` raises
``SanitizerError`` NAMING the violated invariant on the very next
``step()``, while an identically-corrupted ``sanitize=False`` engine
steps on silently (the production default trades the check for a few µs
of host work per step).
"""
import jax
import pytest

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.api import SamplingParams
from repro.serving.engine import Engine, EngineConfig
from repro.serving.sanitize import SanitizerError, check_cache, check_events


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    return cfg, qc, qparams


def make_engine(setup, sanitize=True, **kw):
    cfg, qc, qparams = setup
    defaults = dict(max_batch=4, num_pages=64, page_size=8,
                    max_pages_per_seq=16, prefill_chunk_tokens=24,
                    kv_range=4.0, sanitize=sanitize)
    defaults.update(kw)
    return Engine(cfg, qparams, qc, EngineConfig(**defaults))


def submit(eng, n=2, plen=12, max_new=4):
    sp = SamplingParams(max_new_tokens=max_new)
    return [eng.submit(list(range(3, 3 + plen)), sp) for _ in range(n)]


def mapped_page(eng) -> int:
    """A physical page some active sequence currently maps."""
    sid = next(iter(eng.cache.active))
    return int(eng.cache.block_table[sid, 0])


# ----------------------------------------------------- corrupted refcount

def test_refcount_corruption_raises(setup):
    eng = make_engine(setup)
    submit(eng)
    eng.step()                                  # maps prompt pages
    eng.cache.ref[mapped_page(eng)] += 1        # the deliberate corruption
    with pytest.raises(SanitizerError, match="page-refcount conservation"):
        eng.step()


def test_refcount_corruption_silent_when_off(setup):
    eng = make_engine(setup, sanitize=False)
    submit(eng)
    eng.step()
    eng.cache.ref[mapped_page(eng)] += 1
    eng.step()                                  # same corruption: no raise
    assert eng.internal_errors == 0             # and not via the backstop
    assert eng.sanitize_checks == 0


def test_freelist_double_entry_raises(setup):
    eng = make_engine(setup)
    submit(eng)
    eng.step()
    eng.cache.free_pages.append(mapped_page(eng))   # free a mapped page
    with pytest.raises(SanitizerError, match="page-refcount conservation"):
        eng.step()


# ------------------------------------------------------- double terminal

def test_double_terminal_raises(setup):
    eng = make_engine(setup)
    handles = submit(eng, max_new=2)
    while eng.sched.has_work:
        eng.step()
    req = eng._by_id[handles[0].request_id]
    assert req.terminal_emitted
    req.terminal_emitted = False                # defeat the _emit guard
    eng._emit(req)                              # the duplicated terminal
    with pytest.raises(SanitizerError, match="exactly-one-terminal"):
        eng.step()


def test_double_terminal_silent_when_off(setup):
    eng = make_engine(setup, sanitize=False)
    handles = submit(eng, max_new=2)
    while eng.sched.has_work:
        eng.step()
    req = eng._by_id[handles[0].request_id]
    req.terminal_emitted = False
    eng._emit(req)
    eng.step()                                  # no raise
    assert eng.internal_errors == 0


def test_token_after_terminal_raises(setup):
    eng = make_engine(setup)
    handles = submit(eng, max_new=2)
    while eng.sched.has_work:
        eng.step()
    req = eng._by_id[handles[0].request_id]
    # forge a token event AFTER the terminal (bypassing _record_token's
    # terminal-state guard, which is exactly what the sanitizer backstops)
    saved = req.state
    req.state = type(saved).DECODING
    eng._emit(req, token=7)
    req.state = saved
    with pytest.raises(SanitizerError, match="no-token-after-terminal"):
        eng.step()


# ------------------------------------------------------------ clean runs

def test_clean_run_counts_checks(setup):
    eng = make_engine(setup)
    submit(eng)
    while eng.sched.has_work:
        eng.step()
    assert eng.sanitize_checks == eng.steps > 0
    assert eng.internal_errors == 0
    assert check_cache(eng.cache) == []
    assert check_events(eng) == []


def test_sanitizer_not_swallowed_by_backstop(setup):
    """SanitizerError must escape step() even though step() swallows
    everything else — corrupt state means stop, not internal_errors."""
    eng = make_engine(setup)
    submit(eng)
    eng.step()
    eng.cache.ref[mapped_page(eng)] += 1
    before = eng.internal_errors
    with pytest.raises(SanitizerError):
        eng.step()
    assert eng.internal_errors == before
