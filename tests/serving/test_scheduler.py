"""Scheduler + paged-cache allocator invariants."""
import numpy as np

from repro.configs.base import get_smoke_config
from repro.serving.kv_cache import PagedKV4Cache, PagedKV4Config
from repro.serving.scheduler import Request, Scheduler


def make_cache(num_pages=16, page_size=8, max_seqs=8):
    cfg = get_smoke_config("llama3_8b")
    return PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=num_pages, page_size=page_size,
                            max_seqs=max_seqs, max_pages_per_seq=8), 1)


def test_alloc_free_conserves_pages():
    cache = make_cache()
    total = cache.pages_free
    assert cache.allocate_seq(0, 20)      # 3 pages
    assert cache.allocate_seq(1, 8)       # 1 page
    assert cache.pages_free == total - 4
    cache.free_seq(0)
    cache.free_seq(1)
    assert cache.pages_free == total
    assert (cache.block_table == -1).all()


def test_admission_respects_capacity():
    cache = make_cache(num_pages=4, page_size=8)
    sched = Scheduler(max_batch=8, max_seqs=8)
    for i in range(5):
        sched.submit(Request(i, list(range(8)), 4, arrived_at=i))
    admitted = sched.admit(cache)
    # each 8-token prompt = 1 page; admission requires prompt+1 headroom
    # page free, so 3 fit on 4 pages (1+1, 2+1, 3+1≤4) and the 4th does not
    assert len(admitted) == 3
    assert len(sched.waiting) == 2
    assert cache.pages_free == 1


def test_preemption_requeues_with_progress():
    cache = make_cache()
    sched = Scheduler(max_batch=4, max_seqs=8)
    sched.submit(Request(0, [1, 2, 3], 10, arrived_at=0.0))
    sched.submit(Request(1, [4, 5, 6], 10, arrived_at=1.0))
    sched.admit(cache)
    for r in sched.running:
        r.generated = [7, 8]
        r.prefilled = True
    victim = sched.preempt_one(cache)
    assert victim.request_id == 1          # youngest
    assert victim.prompt == [4, 5, 6, 7, 8]  # keeps generated progress
    assert victim.max_new_tokens == 8
    assert sched.preemptions == 1


def test_snapshot_restore_roundtrip():
    cache = make_cache()
    sched = Scheduler(max_batch=4, max_seqs=8)
    sched.submit(Request(0, [1, 2], 5, arrived_at=0.0))
    sched.submit(Request(1, [3], 5, arrived_at=1.0))
    sched.admit(cache)
    sched.running[0].generated = [9]
    blob = sched.snapshot()
    s2 = Scheduler.restore(blob, 4, 8)
    assert len(s2.waiting) == 2
    first = s2.waiting[0]
    assert first.prompt == [1, 2, 9] and first.max_new_tokens == 4
