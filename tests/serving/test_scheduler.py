"""Scheduler + paged-cache allocator invariants."""
import numpy as np

from repro.configs.base import get_smoke_config
from repro.serving.kv_cache import PagedKV4Cache, PagedKV4Config
from repro.serving.api import RequestState
from repro.serving.scheduler import Request, Scheduler


def make_cache(num_pages=16, page_size=8, max_seqs=8):
    cfg = get_smoke_config("llama3_8b")
    return PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=num_pages, page_size=page_size,
                            max_seqs=max_seqs, max_pages_per_seq=8), 1)


def test_alloc_free_conserves_pages():
    cache = make_cache()
    total = cache.pages_free
    assert cache.allocate_seq(0, 20)      # 3 pages
    assert cache.allocate_seq(1, 8)       # 1 page
    assert cache.pages_free == total - 4
    cache.free_seq(0)
    cache.free_seq(1)
    assert cache.pages_free == total
    assert (cache.block_table == -1).all()


def test_admission_respects_capacity():
    cache = make_cache(num_pages=4, page_size=8)
    sched = Scheduler(max_batch=8, max_seqs=8)
    for i in range(5):
        sched.submit(Request(i, list(range(8)), 4, arrived_at=i))
    admitted = sched.admit(cache)
    # each 8-token prompt = 1 page; admission requires prompt+1 headroom
    # page free, so 3 fit on 4 pages (1+1, 2+1, 3+1≤4) and the 4th does not
    assert len(admitted) == 3
    assert len(sched.waiting) == 2
    assert cache.pages_free == 1


def test_chunked_admission_reserves_first_chunk_only():
    """With first_chunk_tokens, admission needs pages for one chunk — a
    prompt that whole-prompt admission can't fit under transient pool
    pressure still gets in and acquires later pages via grow_to."""
    cache = make_cache(num_pages=4, page_size=8)
    assert cache.allocate_seq(7, 8)          # another seq holds 1 page
    sched = Scheduler(max_batch=4, max_seqs=8)
    sched.submit(Request(0, list(range(24)), 4, arrived_at=0.0))  # 3 pages
    assert sched.admit(cache) == []          # whole: needs 3+1 > 3 free
    admitted = sched.admit(cache, first_chunk_tokens=8)
    assert len(admitted) == 1
    assert int(cache.page_count[admitted[0].seq_slot]) == 1
    # remaining pages arrive chunk-by-chunk (as the other seq drains)
    cache.free_seq(7)
    assert cache.grow_to(admitted[0].seq_slot, 24) == 24


def test_admission_rejects_uncappable_prompt():
    """Prompts that exceed max_pages_per_seq fail fast with a
    stop_reason instead of being admitted into a livelock."""
    cache = make_cache(num_pages=16, page_size=8)     # cap = 8*8 = 64 tok
    sched = Scheduler(max_batch=4, max_seqs=8)
    sched.submit(Request(0, list(range(100)), 4, arrived_at=0.0))
    sched.submit(Request(1, list(range(8)), 4, arrived_at=1.0))
    admitted = sched.admit(cache)
    assert [r.request_id for r in admitted] == [1]
    assert sched.finished[0].request_id == 0
    assert sched.finished[0].stop_reason == "prompt_too_long"


def test_admission_rejects_prompt_bigger_than_pool():
    """A prompt within the per-seq cap but bigger than the WHOLE pool
    (+1 decode headroom) also fails fast — chunked prefill would stream
    until the pool is exhausted, self-preempt, and restart forever."""
    cfg = get_smoke_config("llama3_8b")
    cache = PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=8, page_size=8, max_seqs=8,
                            max_pages_per_seq=16), 1)   # cap 128 > pool 64
    sched = Scheduler(max_batch=4, max_seqs=8)
    sched.submit(Request(0, list(range(100)), 4, arrived_at=0.0))  # 13 pages
    assert sched.admit(cache, first_chunk_tokens=16) == []
    assert sched.finished[0].stop_reason == "prompt_too_long"
    # boundary: exactly pool-sized (+1 headroom) prompts stay admissible
    sched.submit(Request(1, list(range(56)), 4, arrived_at=1.0))   # 7+1 = 8
    assert [r.request_id for r in sched.admit(cache, first_chunk_tokens=16)
            ] == [1]


def test_preempt_one_skips_finished_requests():
    """A request that is done (but not yet swept out of running) must
    never be preempted — that would fold its generated text back into
    the prompt and silently destroy its output."""
    cache = make_cache()
    sched = Scheduler(max_batch=4, max_seqs=8)
    sched.submit(Request(0, [1, 2, 3], 10, arrived_at=0.0))
    sched.submit(Request(1, [4, 5, 6], 2, arrived_at=1.0))   # youngest
    sched.admit(cache)
    done_req = sched.running[1]
    done_req.generated = [7, 8]                              # done (2/2)
    assert done_req.done
    victim = sched.preempt_one(cache)
    assert victim.request_id == 0                # skipped the finished one
    assert done_req.generated == [7, 8]          # output intact
    assert sched.preempt_one(cache) is None      # only done_req left


def test_mid_prefill_preemption_resets_progress():
    cache = make_cache()
    sched = Scheduler(max_batch=4, max_seqs=8)
    sched.submit(Request(0, list(range(20)), 4, arrived_at=0.0))
    sched.admit(cache, first_chunk_tokens=8)
    req = sched.running[0]
    req.prefill_pos = 8                      # mid-prefill
    victim = sched.preempt_one(cache)
    assert victim is req
    assert victim.prefill_pos == 0 and not victim.prefilled
    assert victim.prompt == list(range(20))  # prompt untouched
    assert victim.max_new_tokens == 4


def test_preemption_requeues_with_progress():
    cache = make_cache()
    sched = Scheduler(max_batch=4, max_seqs=8)
    sched.submit(Request(0, [1, 2, 3], 10, arrived_at=0.0))
    sched.submit(Request(1, [4, 5, 6], 10, arrived_at=1.0))
    sched.admit(cache)
    for r in sched.running:
        r.generated = [7, 8]
        r.prefilled = True
    victim = sched.preempt_one(cache)
    assert victim.request_id == 1          # youngest
    assert victim.prompt == [4, 5, 6, 7, 8]  # keeps generated progress
    assert victim.max_new_tokens == 8
    assert sched.preemptions == 1


def test_snapshot_restore_roundtrip():
    cache = make_cache()
    sched = Scheduler(max_batch=4, max_seqs=8)
    sched.submit(Request(0, [1, 2], 5, arrived_at=0.0))
    sched.submit(Request(1, [3], 5, arrived_at=1.0))
    sched.admit(cache)
    sched.running[0].generated = [9]
    # accounting state that must survive the crash: TTFT stamp and
    # prefix-hit counts (regression: these used to be dropped, so a
    # restarted server re-measured TTFT against the recomputed prefill
    # and lost its hit-rate history)
    sched.running[0].first_token_at = 123.5
    sched.running[0].cached_tokens = 8
    done = Request(2, [7, 7], 1, arrived_at=0.25)
    done.generated = [42]
    done.first_token_at = 0.75
    done.cached_tokens = 2
    done.stop_reason = "max_tokens"
    done.state = RequestState.FINISHED
    sched.finished.append(done)
    blob = sched.snapshot()
    s2 = Scheduler.restore(blob, 4, 8)
    assert len(s2.waiting) == 2
    first = s2.waiting[0]
    assert first.prompt == [1, 2, 9] and first.max_new_tokens == 4
    assert first.first_token_at == 123.5
    assert first.cached_tokens == 8
    second = s2.waiting[1]
    assert second.first_token_at == 0.0 and second.cached_tokens == 0
    fin = s2.finished[0]
    assert fin.arrived_at == 0.25              # was restored as 0.0
    assert fin.first_token_at == 0.75 and fin.cached_tokens == 2
    assert fin.generated == [42] and fin.stop_reason == "max_tokens"


def test_admit_charges_only_uncached_pages():
    """Prefix-aware admission: a prompt whose prefix is published by a
    STILL-ACTIVE sequence adopts those pages and is charged only its
    un-cached suffix — the same prompt is unadmittable without the
    cache."""
    cache = make_cache(num_pages=4, page_size=8)
    shared = list(range(1, 17))                   # 2 full pages
    assert cache.allocate_seq(7, 17)              # publisher holds 3 pages
    cache.seq_len[7] = 17
    cache.publish_prefix(7, shared + [77])
    assert cache.pages_free == 1

    sched = Scheduler(max_batch=4, max_seqs=4)
    prompt = shared + [30, 31, 32, 33]            # 20 tokens, needs 3 pages
    sched.submit(Request(0, prompt, 4, arrived_at=0.0))
    # whole-prompt reserve isolates the charging arithmetic: cache off
    # needs 3 pages (incl. +1 headroom) > 1 free → blocked
    assert sched.admit(cache) == []
    # cache on: 2 shared pages adopted, only 1 new page charged
    admitted = sched.admit(cache, prefix_cache=True)
    assert len(admitted) == 1
    req = admitted[0]
    assert req.prefill_pos == 16 and req.cached_tokens == 16
    assert req.state.value == "prefilling"
    np.testing.assert_array_equal(cache.block_table[req.seq_slot, :2],
                                  cache.block_table[7, :2])
    assert (cache.ref[cache.block_table[7, :2]] == 2).all()
    assert cache.pages_free == 0


def test_abort_releases_running_and_queued():
    cache = make_cache()
    sched = Scheduler(max_batch=1, max_seqs=8)
    sched.submit(Request(0, [1, 2, 3], 5, arrived_at=0.0))
    sched.submit(Request(1, [4, 5, 6], 5, arrived_at=1.0))
    sched.admit(cache)
    running, queued = sched.running[0], sched.waiting[0]
    free_before_admit = cache.pages_free
    assert sched.abort(queued, cache)
    assert queued.state.value == "aborted"
    assert queued.stop_reason == "aborted" and not sched.waiting
    assert sched.abort(running, cache)
    assert not sched.running and cache.pages_free == 16
    assert not sched.abort(running, cache)        # terminal → no-op
    assert {r.request_id for r in sched.finished} == {0, 1}
    assert free_before_admit < 16                 # it really held pages


# ------------------------------------------------ robustness: release/reject


def test_release_is_membership_checked():
    """Double-release is explicit, not silent: the second call returns
    False and does not bump released_count (the old code swallowed the
    ValueError from list.remove)."""
    cache = make_cache()
    sched = Scheduler(max_batch=4, max_seqs=8)
    req = Request(0, [1, 2], 1, arrived_at=0.0)
    sched.submit(req)
    sched.admit(cache)
    req.generated = [9]
    sched.complete(req, cache)
    assert sched.release(req) is True
    assert sched.released_count == 1
    assert sched.release(req) is False           # already gone
    assert sched.released_count == 1
    never_finished = Request(1, [3], 1, arrived_at=1.0)
    assert sched.release(never_finished) is False


def test_reject_and_waiting_full():
    """Bounded waiting queue: waiting_full flips at max_waiting, and
    reject() sends a request straight to FAILED("queue_full") without it
    ever entering the queue."""
    sched = Scheduler(max_batch=4, max_seqs=8, max_waiting=2)
    assert not sched.waiting_full
    sched.submit(Request(0, [1], 2, arrived_at=0.0))
    sched.submit(Request(1, [2], 2, arrived_at=1.0))
    assert sched.waiting_full
    late = Request(2, [3], 2, arrived_at=2.0)
    sched.reject(late)
    assert late.state == RequestState.FAILED
    assert late.stop_reason == "queue_full"
    assert late in sched.finished and len(sched.waiting) == 2
    # unbounded queue never reports full
    assert not Scheduler(max_batch=4, max_seqs=8).waiting_full


def test_preempt_sheds_victim_when_waiting_full():
    """A preemption victim that cannot re-queue without overflowing the
    bounded waiting queue is shed terminally (FAILED "shed") with its
    partial output kept and its pages freed — not re-queued, not lost
    silently."""
    cache = make_cache()
    sched = Scheduler(max_batch=4, max_seqs=8, max_waiting=1)
    sched.submit(Request(0, [1, 2, 3], 10, arrived_at=0.0))
    sched.submit(Request(1, [4, 5, 6], 10, arrived_at=1.0))
    sched.admit(cache)
    sched.submit(Request(2, [7, 8], 4, arrived_at=2.0))  # queue now full
    free_before = cache.pages_free
    for r in sched.running:
        r.generated = [9]
        r.prefilled = True
    victim = sched.preempt_one(cache)
    assert victim.request_id == 1                # youngest
    assert victim.state == RequestState.FAILED
    assert victim.stop_reason == "shed"
    assert victim.generated == [9]               # partial output retained
    assert victim in sched.finished and victim not in sched.waiting
    assert cache.pages_free == free_before + 1   # its page came back
    # with queue headroom the same preemption re-queues instead
    sched2 = Scheduler(max_batch=4, max_seqs=8, max_waiting=5)
    cache2 = make_cache()
    sched2.submit(Request(0, [1, 2, 3], 10, arrived_at=0.0))
    sched2.admit(cache2)
    v2 = sched2.preempt_one(cache2)
    assert v2.state == RequestState.QUEUED and v2 in sched2.waiting


def test_expire_deadlines_running_and_waiting():
    """expire_deadlines sweeps BOTH queues: running requests free their
    pages refcount-exactly, waiting ones just leave the queue; requests
    within budget (or without params) are untouched."""
    from repro.serving.api import SamplingParams
    cache = make_cache()
    sched = Scheduler(max_batch=1, max_seqs=8)
    doomed = Request(0, [1, 2, 3], 5, arrived_at=0.0,
                     params=SamplingParams(max_new_tokens=5,
                                           deadline_ms=10.0))
    safe = Request(1, [4, 5], 5, arrived_at=0.0,
                   params=SamplingParams(max_new_tokens=5,
                                         deadline_ms=10_000.0))
    queued_doomed = Request(2, [6], 5, arrived_at=0.0,
                            params=SamplingParams(max_new_tokens=5,
                                                  ttft_ms=10.0))
    no_params = Request(3, [7], 5, arrived_at=0.0)
    for r in (doomed, safe, queued_doomed, no_params):
        sched.submit(r)
    sched.admit(cache)                           # max_batch=1 → doomed runs
    assert doomed in sched.running
    doomed.generated = [8]
    baseline = cache.pages_free
    expired = sched.expire_deadlines(cache, now=0.020)   # 20ms elapsed
    assert {r.request_id for r in expired} == {0, 2}
    assert doomed.state == RequestState.TIMED_OUT
    assert doomed.stop_reason == "deadline"
    assert doomed.generated == [8]               # partial output retained
    assert queued_doomed.stop_reason == "ttft_budget"
    assert cache.pages_free == baseline + 1      # doomed's page freed
    assert safe in sched.waiting and no_params in sched.waiting
    # a request that already produced its first token is immune to TTFT
    safe.first_token_at = 0.001
    assert sched.expire_deadlines(cache, now=0.021) == []


def test_full_snapshot_restore_keeps_exact_split():
    """full=True keeps the waiting/running split, slots, prefill
    cursors, free-slot order, and lifetime emitted counts — nothing is
    demoted or folded (the bitwise-recovery contract)."""
    cache = make_cache()
    sched = Scheduler(max_batch=4, max_seqs=8, max_waiting=3)
    sched.submit(Request(0, list(range(20)), 4, arrived_at=0.0))
    sched.submit(Request(1, [1, 2, 3], 6, arrived_at=1.0))
    sched.admit(cache, first_chunk_tokens=8)
    run0 = sched.running[0]
    run0.prefill_pos = 8                         # mid-prefill
    run1 = sched.running[1]
    run1.generated = [7, 9]
    run1.prefilled = True
    run1.emitted = 2
    run1.state = RequestState.DECODING
    sched.submit(Request(2, [4, 5], 3, arrived_at=2.0))   # stays waiting
    sched._plan_cursor = 5

    s2 = Scheduler.restore(sched.snapshot(full=True), 4, 8, max_waiting=3)
    assert [r.request_id for r in s2.running] == [0, 1]
    assert [r.request_id for r in s2.waiting] == [2]
    r0, r1 = s2.running
    assert (r0.seq_slot, r0.prefill_pos) == (run0.seq_slot, 8)
    assert r0.prompt == list(range(20)) and r0.generated == []
    assert (r1.seq_slot, r1.generated, r1.emitted) == \
        (run1.seq_slot, [7, 9], 2)
    assert r1.state == RequestState.DECODING
    assert r1.max_new_tokens == 6                # NOT folded
    assert s2._free_slots == sched._free_slots
    assert s2._plan_cursor == 5
    assert s2.max_waiting == 3
    # legacy mode on the same state still demotes/folds (unchanged)
    legacy = Scheduler.restore(sched.snapshot(), 4, 8)
    lr1 = [r for r in legacy.waiting if r.request_id == 1][0]
    assert lr1.prompt == [1, 2, 3, 7, 9] and lr1.max_new_tokens == 4
