"""Journaled crash recovery: full snapshots resume bit-identically, the
event journal redelivers exactly once, and a diverging replay is caught.

The legacy scheduler snapshot (PR-4) survives a crash by re-prefilling —
correct but not bitwise (fp re-prefill vs int4 decode numerics). The
``full=True`` snapshot captures the int4 pool bytes and every allocator/
scheduler cursor, so the restored engine's next step is the SAME step
the crashed engine would have run: these tests pin

* kill-and-restore greedy-identical continuation (the CI chaos-cpu
  assert): tokens after the restore equal the uninterrupted run's,
* exactly-once delivery across the crash: the union of events delivered
  before the kill and after the resume is duplicate-free and complete,
  with the replayed gap verified against the journal,
* ``ReplayMismatch`` on a tampered journal (a resume that does NOT
  continue the crashed run must refuse to pass for one that does),
* directory-backed snapshots/journals surviving a real process-style
  reload (``open_dir``), and
* pool-shape validation on restore (a blob from a differently-sized
  engine must be rejected, not silently mis-read).
"""
import json

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.api import RequestState, SamplingParams
from repro.serving.engine import Engine, EngineConfig
from repro.serving.recovery import RecoveryLog, ReplayMismatch

ECFG = dict(max_batch=4, num_pages=64, page_size=8, max_pages_per_seq=16,
            prefill_chunk_tokens=24, kv_range=4.0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    return cfg, qc, qparams


def make_engine(setup, **kw):
    cfg, qc, qparams = setup
    defaults = dict(ECFG)
    defaults.update(kw)
    return Engine(cfg, qparams, qc, EngineConfig(**defaults))


def _prompts(n=2, seed=5):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 100, int(rng.integers(10, 18))).tolist()
            for _ in range(n)]


def _submit_all(eng, prompts, max_new=8):
    return [eng.submit(p, SamplingParams(max_new_tokens=max_new))
            for p in prompts]


def _reference(setup, prompts, max_new=8):
    eng = make_engine(setup)
    _submit_all(eng, prompts, max_new)
    eng.run()
    return {r.request_id: list(r.generated) for r in eng.sched.finished}


# --------------------------------------------------------- full snapshots


def test_full_snapshot_resumes_bitwise(setup):
    """Kill mid-decode, restore from snapshot(full=True): the restored
    engine's continuation is token-identical to the uninterrupted run —
    nothing re-prefills, the int4 pages come back byte-exact."""
    cfg, qc, qparams = setup
    prompts = _prompts()
    ref = _reference(setup, prompts)

    eng = make_engine(setup)
    _submit_all(eng, prompts)
    for _ in range(4):
        eng.step()                       # mid-flight: prompts resident,
    blob = eng.snapshot(full=True)       # some tokens already out
    mid = {r.request_id: len(r.generated) for r in eng.sched.running}
    assert any(n > 0 for n in mid.values())      # genuinely mid-decode
    assert any(n < 8 for n in mid.values())

    eng2 = Engine.restore(blob, cfg, qparams, qc, EngineConfig(**ECFG))
    assert eng2.steps == eng.steps               # counters survive
    eng2.run()
    got = {r.request_id: list(r.generated) for r in eng2.sched.finished}
    assert got == ref
    assert eng2.cache.pages_free == 64
    # and the abandoned original still completes identically (snapshot
    # is a pure copy, not a move)
    eng.run()
    assert {r.request_id: list(r.generated)
            for r in eng.sched.finished} == ref


def test_full_snapshot_preserves_split_and_cursors(setup):
    """The full blob keeps the exact waiting/running split (nothing is
    demoted), slots, prefill cursors, and the free-slot order."""
    cfg, qc, qparams = setup
    eng = make_engine(setup, max_batch=1)
    prompts = _prompts(n=3, seed=9)
    _submit_all(eng, prompts, max_new=4)
    for _ in range(2):
        eng.step()
    assert len(eng.sched.running) == 1 and len(eng.sched.waiting) == 2
    blob = eng.snapshot(full=True)

    eng2 = Engine.restore(blob, cfg, qparams, qc,
                          EngineConfig(**dict(ECFG, max_batch=1)))
    assert [r.request_id for r in eng2.sched.running] == \
        [r.request_id for r in eng.sched.running]
    assert [r.request_id for r in eng2.sched.waiting] == \
        [r.request_id for r in eng.sched.waiting]
    r, r2 = eng.sched.running[0], eng2.sched.running[0]
    assert (r2.seq_slot, r2.prefill_pos, r2.state, r2.emitted) == \
        (r.seq_slot, r.prefill_pos, r.state, r.emitted)
    assert eng2.sched._free_slots == eng.sched._free_slots
    assert eng2.sched._plan_cursor == eng.sched._plan_cursor
    np.testing.assert_array_equal(eng2.cache.block_table,
                                  eng.cache.block_table)
    assert eng2.cache.free_pages == eng.cache.free_pages
    np.testing.assert_array_equal(np.asarray(eng2.cache.k_pool),
                                  np.asarray(eng.cache.k_pool))


def test_restore_rejects_mismatched_pool_shape(setup):
    """A full blob from a differently-sized pool must be rejected —
    silently reshaping int4 bytes would corrupt every sequence."""
    cfg, qc, qparams = setup
    eng = make_engine(setup)
    blob = eng.snapshot(full=True)
    with pytest.raises(ValueError, match="pool shape"):
        Engine.restore(blob, cfg, qparams, qc,
                       EngineConfig(**dict(ECFG, num_pages=32)))


# ----------------------------------------------------------- recovery log


def test_recovery_log_exactly_once_across_crash(setup):
    """Crash between snapshots: the resumed log re-runs the gap, verifies
    every replayed event bitwise against the journal, suppresses them
    from delivery, and the union of (pre-crash, post-resume) deliveries
    equals the uninterrupted run with no duplicates."""
    cfg, qc, qparams = setup
    prompts = _prompts(seed=13)
    ref = _reference(setup, prompts)

    eng = make_engine(setup)
    log = RecoveryLog(eng, snapshot_every=4)
    _submit_all(eng, prompts)
    delivered = []
    for _ in range(6):                   # snapshot at step 4; crash at 6
        delivered.extend(log.step())
    journaled_at_crash = len(log.journal)
    assert journaled_at_crash > 0

    log2 = RecoveryLog.resume(log.snapshot_blob, log.journal, cfg,
                              qparams, qc, EngineConfig(**ECFG),
                              snapshot_every=4)
    delivered2 = log2.run()
    # the 2-step gap re-ran: its events were journaled pre-crash, so
    # they replay (verified) instead of redelivering
    assert log2.replayed > 0
    assert all(ev not in delivered for ev in delivered2)
    keys = [(ev.request_id, ev.token, ev.num_generated)
            for ev in delivered + delivered2 if ev.token is not None]
    assert len(keys) == len(set(keys))           # exactly-once
    # per-request delivered streams reassemble the reference output,
    # with exactly one terminal each (the journal itself compacts at
    # checkpoints, so the DELIVERED record is the lifetime history)
    for rid, toks in ref.items():
        got = [ev.token for ev in delivered + delivered2
               if ev.request_id == rid and ev.token is not None]
        assert got == toks
        terms = [ev for ev in delivered + delivered2
                 if ev.request_id == rid and ev.finished]
        assert len(terms) == 1 and terms[0].state.value == "finished"
    # compaction kept the journal bounded by one snapshot interval
    assert log2.compacted_total > 0
    assert len(log2.journal) < log2.journaled_total


def test_replay_mismatch_is_detected(setup):
    """A tampered journal token makes the resumed run raise
    ReplayMismatch — the bitwise-continuation check has teeth."""
    cfg, qc, qparams = setup
    eng = make_engine(setup)
    log = RecoveryLog(eng, snapshot_every=4)
    _submit_all(eng, _prompts(seed=17))
    for _ in range(6):
        log.step()
    # tamper an event journaled AFTER the step-4 snapshot (the gap that
    # will re-run on resume)
    tampered = [dict(e) for e in log.journal]
    gap = [e for e in tampered if e["ord"] != -1][-1]
    gap["token"] = gap["token"] + 1
    log2 = RecoveryLog.resume(log.snapshot_blob, tampered, cfg, qparams,
                              qc, EngineConfig(**ECFG), snapshot_every=4)
    with pytest.raises(ReplayMismatch):
        log2.run()


def test_dir_backed_recovery_survives_reload(setup, tmp_path):
    """Directory mode: snapshot.json + journal.jsonl on disk, reopened
    with open_dir after a process-style kill — the continuation matches
    the uninterrupted reference and the journal is complete."""
    cfg, qc, qparams = setup
    d = str(tmp_path / "rlog")
    prompts = _prompts(seed=21)
    ref = _reference(setup, prompts)

    eng = make_engine(setup)
    log = RecoveryLog(eng, snapshot_every=3, dir=d)
    _submit_all(eng, prompts)
    for _ in range(5):
        log.step()
    del eng, log                         # the "kill"

    log2 = RecoveryLog.open_dir(d, cfg, qparams, qc,
                                EngineConfig(**ECFG), snapshot_every=3)
    log2.run()
    got = {r.request_id: list(r.generated)
           for r in log2.engine.sched.finished}
    assert got == ref
    assert all(r.state == RequestState.FINISHED
               for r in log2.engine.sched.finished)
    # the on-disk journal matches the in-memory one (appends since the
    # last atomic rotate), and compaction kept it bounded
    with open(tmp_path / "rlog" / "journal.jsonl") as f:
        on_disk = [json.loads(line) for line in f if line.strip()]
    assert on_disk == log2.journal
    assert len(on_disk) < log2.journaled_total
    assert (tmp_path / "rlog" / "snapshot.json").exists()


def test_journal_keys_survive_request_id_reuse(setup):
    """Regression (incarnation ids): after ``release()`` a request_id is
    reusable — a new request under the recycled id must journal under
    fresh ``(uid, ord)`` keys. With the old ``(rid, ord)`` keys its
    tokens collided with the dead request's entries and were either
    silently suppressed as replays or flagged ReplayMismatch."""
    cfg, qc, qparams = setup
    eng = make_engine(setup)
    log = RecoveryLog(eng, snapshot_every=100)   # no checkpoint: the
    #                                              keys alone must hold
    p1, p2 = _prompts(seed=29)
    h1 = eng.submit(p1, SamplingParams(max_new_tokens=4), request_id=7)
    evs = []
    while not eng.result(h1).state.terminal:
        evs.extend(log.step())
    toks1 = [e.token for e in evs
             if e.request_id == 7 and e.token is not None]
    assert len(toks1) == 4
    assert eng.release(h1)

    eng.submit(p2, SamplingParams(max_new_tokens=4), request_id=7)
    evs2 = log.run()
    toks2 = [e.token for e in evs2
             if e.request_id == 7 and e.token is not None]
    # the recycled id's fresh tokens are DELIVERED, not swallowed as
    # replays of the first incarnation
    assert len(toks2) == 4
    assert log.replayed == 0
    # and the two incarnations are distinguishable in the journal
    assert len({e["uid"] for e in log.journal if e["rid"] == 7}) == 2


def test_journal_compacts_at_checkpoint(setup, tmp_path):
    """At every checkpoint the journal drops its unreplayable prefix —
    in memory it resets to the new (empty) gap, and dir-mode
    journal.jsonl is atomically rewritten to match — so both stay
    bounded by one snapshot interval of traffic."""
    cfg, qc, qparams = setup
    d = str(tmp_path / "rlog")
    eng = make_engine(setup)
    log = RecoveryLog(eng, snapshot_every=2, dir=d)
    _submit_all(eng, _prompts(seed=33), max_new=10)
    sizes = []
    while eng.sched.has_work:
        log.step()
        sizes.append(len(log.journal))
    assert log.compacted_total > 0
    assert log.journaled_total == log.compacted_total + len(log.journal)
    # checkpoint steps reset the gap to empty — lifetime traffic never
    # accumulates
    assert min(sizes) == 0
    assert max(sizes) < log.journaled_total
    with open(tmp_path / "rlog" / "journal.jsonl") as f:
        on_disk = [json.loads(line) for line in f if line.strip()]
    assert on_disk == log.journal


def test_torn_snapshot_write_keeps_last_good(setup, tmp_path):
    """snapshot_write fault: a kill mid-``_write_snapshot`` tears only
    the temp file — the atomic rename never ran, so snapshot.json keeps
    the last good blob and ``open_dir`` still restores a continuation
    identical to the uninterrupted run."""
    from repro.serving.faults import Fault, FaultInjector, InjectedFault
    cfg, qc, qparams = setup
    d = str(tmp_path / "rlog")
    prompts = _prompts(seed=37)
    ref = _reference(setup, prompts)

    # consultation #1 is the construction-time write; #2 the step-2
    # checkpoint; #3 tears the step-4 checkpoint mid-write
    inj = FaultInjector([Fault("snapshot_write", nth=3)])
    eng = Engine(cfg, qparams, qc, EngineConfig(**ECFG), faults=inj)
    log = RecoveryLog(eng, snapshot_every=2, dir=d)
    _submit_all(eng, prompts)
    with pytest.raises(InjectedFault):
        while eng.sched.has_work:
            log.step()
    assert eng.steps == 4                        # died at the checkpoint
    # the temp file is torn; snapshot.json is the intact step-2 blob
    assert (tmp_path / "rlog" / "snapshot.json.tmp").exists()
    with open(tmp_path / "rlog" / "snapshot.json") as f:
        good = json.loads(f.read())
    assert good["steps"] == 2

    log2 = RecoveryLog.open_dir(d, cfg, qparams, qc,
                                EngineConfig(**ECFG), snapshot_every=2)
    assert log2.engine.steps == 2                # resumed from last good
    log2.run()
    got = {r.request_id: list(r.generated)
           for r in log2.engine.sched.finished}
    assert got == ref
    assert log2.engine.cache.pages_free == 64


def test_recovery_log_validates_snapshot_every():
    with pytest.raises(ValueError, match="snapshot_every"):
        RecoveryLog.__new__(RecoveryLog).__init__(None, snapshot_every=0)


def test_recovery_under_failure_outcome_is_stable(setup):
    """A request that FAILED before the crash stays failed after the
    resume — terminal outcomes are part of the journaled contract, and
    the terminal event is never redelivered."""
    from repro.serving.faults import Fault, FaultInjector
    cfg, qc, qparams = setup
    eng = Engine(cfg, qparams, qc, EngineConfig(**ECFG),
                 faults=FaultInjector([Fault("forward", step=3,
                                             action="nan", row=0)]))
    log = RecoveryLog(eng, snapshot_every=2)
    hs = _submit_all(eng, _prompts(seed=25), max_new=6)
    delivered = []
    for _ in range(5):
        delivered.extend(log.step())
    failed = [rid for rid, r in eng._by_id.items()
              if r.state == RequestState.FAILED]
    assert failed                        # the NaN quarantine landed
    log2 = RecoveryLog.resume(log.snapshot_blob, log.journal, cfg,
                              qparams, qc, EngineConfig(**ECFG),
                              snapshot_every=2)
    delivered2 = log2.run()
    for rid in failed:
        assert log2.engine._by_id[rid].state == RequestState.FAILED
        if any(e.request_id == rid and e.finished for e in delivered):
            # terminal already delivered pre-crash → never redelivered
            assert not any(ev.request_id == rid and ev.finished
                           for ev in delivered2)
    assert log2.engine.cache.pages_free == 64
