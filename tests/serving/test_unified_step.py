"""Unified one-forward-per-step engine: parity with the split step,
one-forward-per-step invariant, bucketed-shape trace plateau, and the
round-robin prefill plan (no chunk-budget starvation).

Parity uses weight-only quantization + calibrated ``kv_range`` (the
same regime as the chunked-vs-whole sweeps): int4 KV error then stays
below greedy argmax margins, and decode rows fake-quantize their
in-flight KV (``qdq_kv``) so self-attention sees the same values the
split decode path reads back from its int4 page. The residual
difference between the paths is bf16 rounding from XLA fusing the
jitted unified forward differently than the split path's eager ops —
O(1e-2) logit noise that flips greedy argmax only on near-ties, so
each scenario pins a workload seed with healthy margins (the same
practice as the chunked-vs-whole and engine-vs-LM.decode tests).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    return cfg, qc, qparams


def make_engine(setup, unified, **kw):
    cfg, qc, qparams = setup
    defaults = dict(max_batch=6, num_pages=128, page_size=8,
                    max_pages_per_seq=32, prefill_chunk_tokens=24,
                    kv_range=4.0, unified_step=unified)
    defaults.update(kw)
    return Engine(cfg, qparams, qc, EngineConfig(**defaults))


def run_tokens(eng, prompts, max_new, max_steps=400):
    for i, p in enumerate(prompts):
        eng.add_request(i, p, max_new)
    done = eng.run(max_steps=max_steps)
    assert sorted(r.request_id for r in done) == list(range(len(prompts)))
    return {r.request_id: list(r.generated) for r in done}


def ragged_prompts(lens, vocab, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, n).tolist() for n in lens]


MIXES = {
    # (prompt lens, max_new, workload seed)
    # decode-only steady state: every prompt prefills in the first step,
    # then the workload is pure decode rows
    "decode_only": (( 5, 3, 7, 4), 12, 2),
    # prefill-only: long prompts, a single sampled token each
    "prefill_only": ((40, 64, 23, 56), 1, 1),
    # bucket boundaries: lengths straddling the power-of-two buckets the
    # unified forward pads to (and chunk == budget edge cases)
    "bucket_boundary": ((15, 16, 17, 31, 32, 33), 4, 1),
}
MIXED_LENS, MIXED_NEW = (40, 7, 23, 64, 13, 29), 8


@pytest.fixture(scope="module")
def mixed_run(setup):
    """One unified + one split run of the flagship mixed workload
    (ragged prompts streaming while earlier requests decode), shared by
    the parity / forward-count / trace-count assertions."""
    cfg = setup[0]
    prompts = ragged_prompts(MIXED_LENS, cfg.vocab_size)
    uni = make_engine(setup, True)
    a = run_tokens(uni, prompts, MIXED_NEW)
    spl = make_engine(setup, False)
    b = run_tokens(spl, prompts, MIXED_NEW)
    return uni, a, spl, b


@pytest.mark.parametrize("mix", list(MIXES))
def test_unified_matches_split_greedy(setup, mix):
    cfg = setup[0]
    lens, max_new, seed = MIXES[mix]
    prompts = ragged_prompts(lens, cfg.vocab_size, seed)
    split = run_tokens(make_engine(setup, False), prompts, max_new)
    unified = run_tokens(make_engine(setup, True), prompts, max_new)
    assert unified == split


def test_unified_matches_split_greedy_mixed(mixed_run):
    _, a, _, b = mixed_run
    assert a == b


def test_unified_matches_split_mid_prefill_preemption(setup):
    """Preempt the same mid-prefill victim at the same point in both
    engines: restart + re-admission must stay token-identical. The long
    prompt arrives last (youngest), so after step 1 it is mid-prefill
    AND the eviction victim."""
    cfg = setup[0]
    prompts = ragged_prompts((6, 48), cfg.vocab_size, seed=2)
    out = {}
    for unified in (False, True):
        eng = make_engine(setup, unified, prefill_chunk_tokens=8)
        for i, p in enumerate(prompts):
            eng.add_request(i, p, 4)
        eng.step()                      # long prompt now mid-prefill
        victim = next(r for r in eng.sched.running
                      if 0 < r.prefill_pos < len(r.prompt))
        assert victim.request_id == 1
        assert eng.sched.preempt_one(eng.cache) is victim
        assert victim.prefill_pos == 0  # restarts from scratch
        done = eng.run(max_steps=300)
        out[unified] = {r.request_id: list(r.generated) for r in done}
        assert all(len(t) == 4 for t in out[unified].values())
    assert out[True] == out[False]


def test_one_forward_per_step(mixed_run):
    """Steady-state mixed workload issues exactly ONE forward per step
    (the split baseline issues up to two)."""
    uni, _, spl, _ = mixed_run
    # ample pages: every step had work, and every step = one forward
    assert uni.sched.preemptions == 0
    assert uni.forward_calls == uni.steps
    assert spl.forward_calls > spl.steps    # interleaved steps pay twice


def test_trace_count_plateaus(setup):
    """Bucketed shapes: after warmup, steady-state decode steps reuse
    the compiled forward — trace_count stops growing."""
    cfg = setup[0]
    # page_size 64 keeps every sequence on one page for the whole run, so
    # the only shape-bucket changes are the prefill→decode transition
    prompts = ragged_prompts((5, 3, 7, 4, 6, 2), cfg.vocab_size)
    eng = make_engine(setup, True, page_size=64, num_pages=16,
                      max_pages_per_seq=4, prefill_chunk_tokens=32)
    for i, p in enumerate(prompts):
        eng.add_request(i, p, 24)
    eng.step()                          # prefill forward (trace 1)
    eng.step()                          # first decode forward (trace 2)
    warm = eng.trace_count
    assert warm >= 1
    eng.run(max_steps=400)
    assert eng.trace_count == warm      # plateau: no steady-state retrace
    assert eng.forward_calls == eng.steps
    # all requests ran to completion through the cached forward
    assert all(len(r.generated) == 24 for r in eng.sched.finished)


def test_unified_fewer_traces_than_split(mixed_run):
    """The bucketed unified forward compiles strictly fewer variants
    than the split step's per-(nseq, cmax, ttot) eager churn."""
    uni, _, spl, _ = mixed_run
    assert uni.trace_count < spl.trace_count


def test_round_robin_prefill_no_starvation(setup):
    """Regression: with the plan start pinned to the head of
    ``sched.running``, a long prompt monopolizes the chunk budget and a
    short prompt behind two long ones waits ~16 steps for its first
    token; round-robin hands each candidate the budget in turn."""
    cfg = setup[0]
    prompts = ragged_prompts((64, 64, 8), cfg.vocab_size)
    eng = make_engine(setup, True, prefill_chunk_tokens=8,
                      num_pages=256, max_batch=4)
    for i, p in enumerate(prompts):
        eng.add_request(i, p, 4)
    steps_to_first = None
    for step in range(1, 7):
        eng.step()
        short = next(r for r in (eng.sched.running + eng.sched.finished)
                     if r.request_id == 2)
        if short.generated:
            steps_to_first = step
            break
    assert steps_to_first is not None and steps_to_first <= 4, (
        "short prompt starved behind long prompts")
    # the long prompts still complete
    done = eng.run(max_steps=400)
    assert all(len(r.generated) == 4 for r in done)


def test_unified_temperature_sampling_deterministic(setup):
    """The vectorized sampler is keyed by (request_id, position): two
    runs of the same engine reproduce the same stochastic text. (Cross-
    path identity is NOT asserted at temperature > 0 — categorical
    sampling amplifies the jit-vs-eager bf16 noise that greedy argmax
    absorbs.)"""
    cfg = setup[0]
    prompts = ragged_prompts((9, 17, 5), cfg.vocab_size)
    kw = dict(temperature=0.8, top_k=8)
    a = run_tokens(make_engine(setup, True, **kw), prompts, 6)
    b = run_tokens(make_engine(setup, True, **kw), prompts, 6)
    assert a == b
    assert any(len(set(t)) > 1 for t in a.values())   # actually sampled
