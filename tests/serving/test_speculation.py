"""Speculative multi-token decode on the unified paged path.

The contract under test, end to end:

* **Exactness** — greedy output with ``SamplingParams.speculation=k``
  is BITWISE identical to speculation-off, across decode-only, mixed
  prefill+decode, preempt-resume, and both attention schedules, with
  the step-boundary sanitizers on and ``internal_errors == 0``. The
  draft source only decides how many forwards the run takes.
* **Prompt-lookup drafting** — the host-side n-gram source proposes
  continuations of the trailing context n-gram, preferring the most
  recent match with a FULL k-token continuation (a most-recent-only
  rule clips to the context tail and starves acceptance).
* **Budget + validation** — drafts debit the step's prefill token
  budget, ``speculation < 0`` and drafts that could never fit a step
  are rejected up front, and ``max_new_tokens=1`` silently no-ops
  (counter, not error).
* **Fault isolation** — the ``draft`` point degrades to plain decode
  (``draft_errors`` counted, output unchanged); the ``verify`` point
  quarantines exactly the speculating request; seeded chaos sweeps
  over ``ENGINE_FAULT_POINTS + SPEC_FAULT_POINTS`` uphold every
  serving invariant.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.api import RequestState, SamplingParams
from repro.serving.engine import Engine, EngineConfig
from repro.serving.faults import (ENGINE_FAULT_POINTS, SPEC_FAULT_POINTS,
                                  Fault, FaultInjector)
from repro.serving.speculation import DraftSource, PromptLookupDraft


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    return cfg, qc, qparams


def make_engine(setup, faults=None, **kw):
    cfg, qc, qparams = setup
    defaults = dict(max_batch=6, num_pages=128, page_size=8,
                    max_pages_per_seq=32, prefill_chunk_tokens=24,
                    kv_range=4.0, unified_step=True, sanitize=True)
    defaults.update(kw)
    ekw = {"faults": faults} if faults is not None else {}
    return Engine(cfg, qparams, qc, EngineConfig(**defaults), **ekw)


def run_spec(setup, prompts, max_new, k, faults=None, **kw):
    eng = make_engine(setup, faults=faults, **kw)
    for i, p in enumerate(prompts):
        eng.submit(p, SamplingParams(max_new_tokens=max_new,
                                     temperature=0.0, speculation=k),
                   request_id=i)
    done = eng.run(max_steps=500)
    # compare the EMITTED token stream, not req.generated: a preemption
    # folds generated tokens into the prompt, so the post-fold tail is
    # all `generated` retains — the event log is the lifetime output
    return eng, {r.request_id: [e.token for e in r.events
                                if e.token is not None] for r in done}


# repetitive prompts: the smoke model's greedy decode cycles, so
# prompt-lookup acceptance is high and the verify path commits real
# multi-token runs
REPETITIVE = [[188] * 8, [139, 133, 188, 188] * 2, [188] * 12]


# ------------------------------------------------ prompt-lookup draft source


def test_pld_full_continuation():
    d = PromptLookupDraft()
    # trailing [3] matched at index 1 with a full 3-token continuation
    assert d.draft([1, 3, 4, 5, 6], [3], 3) == [4, 5, 6]


def test_pld_prefers_full_continuation_over_recent_clip():
    """A run of repeats: the most RECENT match of the trailing n-gram
    sits at the context tail with a clipped continuation; the draft
    must reach back to the match that yields all k tokens."""
    d = PromptLookupDraft()
    ctx = [7] * 10
    assert d.draft(ctx, [], 4) == [7, 7, 7, 7]


def test_pld_falls_back_to_longest_partial():
    d = PromptLookupDraft()
    # only match of trailing [2] is near the end: 2-token continuation
    assert d.draft([1, 2, 8, 9], [2], 4) == [8, 9, 2]


def test_pld_no_match_and_k0():
    d = PromptLookupDraft()
    assert d.draft([1, 2, 3, 4], [5], 3) == []
    assert d.draft([1, 2, 1, 2], [1], 0) == []


def test_pld_ngram_backoff():
    """No 3- or 2-gram match → backs off to the unigram match."""
    d = PromptLookupDraft(max_ngram=3, min_ngram=1)
    assert d.draft([9, 4, 1, 2, 3], [9], 2) == [4, 1]


def test_pld_is_host_only():
    import repro.serving.speculation as spec
    assert "jax" not in dir(spec) and "jnp" not in dir(spec)


# --------------------------------------------------------- exact-greedy parity


@pytest.mark.parametrize("sched", ["work_queue", "dense"])
def test_spec_greedy_parity_repetitive(setup, sched):
    """The favorable workload: high acceptance, several tokens per
    forward — and bitwise-identical greedy output."""
    e0, o0 = run_spec(setup, REPETITIVE, 24, 0, attention_schedule=sched)
    e4, o4 = run_spec(setup, REPETITIVE, 24, 4, attention_schedule=sched)
    assert o4 == o0
    assert e0.internal_errors == 0 and e4.internal_errors == 0
    assert e4.forward_calls < e0.forward_calls
    assert e4.spec_accepted_tokens > e4.steps          # >1 accepted/step
    assert e4.spec_draft_tokens == (e4.spec_accepted_tokens
                                    + e4.spec_rollback_tokens)


def test_spec_greedy_parity_mixed_prefill_decode(setup):
    """Random ragged prompts stream in while repetitive rows decode with
    drafts: spec rows, plain decode rows, and prefill chunks share the
    forward, and the output must not move."""
    cfg = setup[0]
    rng = np.random.default_rng(1)
    prompts = REPETITIVE + [rng.integers(1, cfg.vocab_size, n).tolist()
                            for n in (40, 23)]
    e0, o0 = run_spec(setup, prompts, 8, 0)
    e4, o4 = run_spec(setup, prompts, 8, 4)
    assert o4 == o0
    assert e0.internal_errors == 0 and e4.internal_errors == 0
    assert e4.spec_draft_tokens > 0


def test_spec_greedy_parity_preempt_resume(setup):
    """Page pressure forces preemption mid-run: folded prompts resume
    and the speculating engine still matches speculation-off exactly."""
    e0, o0 = run_spec(setup, REPETITIVE, 24, 0, num_pages=10, max_batch=3)
    e4, o4 = run_spec(setup, REPETITIVE, 24, 4, num_pages=10, max_batch=3)
    assert o4 == o0
    assert e0.internal_errors == 0 and e4.internal_errors == 0
    # pressure actually materialized — in BOTH arms
    assert e0.sched.preemptions > 0 and e4.sched.preemptions > 0


def test_spec_stochastic_sampling_completes(setup):
    """Rejection sampling path (temperature > 0): distributions aren't
    asserted here (that's the verifier's rejection-sampling algebra),
    but the lifecycle must hold: full-length outputs, clean counters,
    sanitizers green."""
    eng = make_engine(setup)
    for i, p in enumerate(REPETITIVE):
        eng.submit(p, SamplingParams(max_new_tokens=12, temperature=0.8,
                                     top_k=8, speculation=3),
                   request_id=i)
    done = eng.run(max_steps=500)
    assert eng.internal_errors == 0
    assert len(done) == len(REPETITIVE)
    assert all(len(r.generated) == 12 for r in done)
    assert eng.spec_draft_tokens == (eng.spec_accepted_tokens
                                     + eng.spec_rollback_tokens)


def test_spec_emits_tokens_in_order(setup):
    """A multi-token commit must stream as consecutive single-token
    events — num_generated advancing by exactly one per event."""
    evs = []
    eng = make_engine(setup)
    eng.submit(REPETITIVE[0], SamplingParams(max_new_tokens=16,
                                             temperature=0.0,
                                             speculation=4),
               on_event=evs.append)
    eng.run(max_steps=200)
    nums = [e.num_generated for e in evs if e.token is not None]
    assert nums == list(range(1, len(nums) + 1))
    assert len(nums) == 16


# ------------------------------------------------------- validation + budget


def test_speculation_param_validation():
    with pytest.raises(ValueError, match="speculation"):
        SamplingParams(speculation=-1)


def test_submit_rejects_oversized_speculation(setup):
    eng = make_engine(setup, prefill_chunk_tokens=4)
    with pytest.raises(ValueError, match="speculation"):
        eng.submit([1, 2, 3], SamplingParams(speculation=4))


def test_single_token_request_noops_speculation(setup):
    """max_new_tokens=1 + speculation: a draft would be guaranteed
    rollback, so the engine silently skips drafting and counts it."""
    eng, out = run_spec(setup, [REPETITIVE[0]], 1, 4)
    assert len(out[0]) == 1
    assert eng.spec_draft_tokens == 0
    assert eng.spec_noop_count >= 1


def test_drafts_debit_prefill_budget(setup):
    """With a prompt mid-prefill, drafted tokens shrink the prefill
    chunk: total packed tokens per forward stay bounded by the step
    budget (prefill_chunk_tokens)."""
    cfg = setup[0]
    budget = 24
    eng = make_engine(setup, prefill_chunk_tokens=budget)
    seen = []
    orig = eng._forward_step

    def spy(plan, decode):
        seen.append(sum(t for _, _, t in plan)
                    + sum(1 + len(d) for _, d in decode))
        return orig(plan, decode)

    eng._forward_step = spy
    eng.submit(REPETITIVE[0], SamplingParams(max_new_tokens=16,
                                             temperature=0.0,
                                             speculation=8), request_id=0)
    eng.step()          # prefill the repetitive prompt
    long_prompt = np.random.default_rng(0).integers(
        1, cfg.vocab_size, 60).tolist()
    eng.submit(long_prompt, SamplingParams(max_new_tokens=2,
                                           temperature=0.0), request_id=1)
    eng.run(max_steps=200)
    assert eng.spec_draft_tokens > 0
    assert max(seen) <= budget
    assert eng.internal_errors == 0


# --------------------------------------------------------------- fault points


def test_draft_fault_degrades_to_plain_decode(setup):
    """A raising draft source is never fatal: the row decodes one token
    as if speculation were off, the error is counted, output unchanged."""
    _, baseline = run_spec(setup, REPETITIVE, 12, 0)
    fi = FaultInjector([Fault("draft", nth=1, action="raise"),
                        Fault("draft", nth=3, action="empty")])
    eng, out = run_spec(setup, REPETITIVE, 12, 4, faults=fi)
    assert out == baseline
    assert eng.draft_errors == 1            # raise counted, empty not
    assert eng.internal_errors == 0
    assert {p for p, _, _ in fi.fired} == {"draft"}


def test_broken_draft_source_counted_not_fatal(setup):
    class Exploding(DraftSource):
        def draft(self, prompt, generated, k):
            raise RuntimeError("boom")

    cfg, qc, qparams = setup
    eng = Engine(cfg, qparams, qc,
                 EngineConfig(max_batch=6, num_pages=128, page_size=8,
                              max_pages_per_seq=32,
                              prefill_chunk_tokens=24, kv_range=4.0,
                              unified_step=True, sanitize=True),
                 draft_source=Exploding())
    eng.submit(REPETITIVE[0], SamplingParams(max_new_tokens=8,
                                             temperature=0.0,
                                             speculation=4))
    done = eng.run(max_steps=200)
    assert len(done) == 1 and len(done[0].generated) == 8
    assert eng.draft_errors > 0 and eng.internal_errors == 0
    assert eng.spec_draft_tokens == 0


def test_out_of_vocab_draft_rejected(setup):
    class Liar(DraftSource):
        def draft(self, prompt, generated, k):
            return [10 ** 9] * k

    cfg, qc, qparams = setup
    eng = Engine(cfg, qparams, qc,
                 EngineConfig(max_batch=6, num_pages=128, page_size=8,
                              max_pages_per_seq=32,
                              prefill_chunk_tokens=24, kv_range=4.0,
                              unified_step=True, sanitize=True),
                 draft_source=Liar())
    eng.submit(REPETITIVE[0], SamplingParams(max_new_tokens=8,
                                             temperature=0.0,
                                             speculation=4))
    done = eng.run(max_steps=200)
    assert len(done) == 1 and len(done[0].generated) == 8
    assert eng.draft_errors > 0 and eng.spec_draft_tokens == 0


def test_verify_fault_quarantines_one_request(setup):
    """An injected verify failure fails exactly the speculating request
    — drafted KV retracted with its pages — while the rest drain."""
    fi = FaultInjector([Fault("verify", nth=1, action="raise")])
    eng, out = run_spec(setup, REPETITIVE, 12, 4, faults=fi)
    failed = [r for r in eng.sched.finished
              if r.state == RequestState.FAILED]
    assert len(failed) == 1
    assert "verify" in failed[0].stop_reason
    finished = [r for r in eng.sched.finished
                if r.state == RequestState.FINISHED]
    assert len(finished) == len(REPETITIVE) - 1
    assert all(len(r.generated) == 12 for r in finished)
    assert eng.internal_errors == 0
    assert eng.cache.pages_free == 128      # quarantine freed to baseline


@pytest.mark.parametrize("seed", range(12))
def test_chaos_with_spec_points(setup, seed):
    """Seeded chaos over the engine AND speculative fault points
    ('draft'/'verify' riding with alloc_page/forward/sample/append_kv/
    emit_event), speculation armed on every request: step() never
    raises, pages return to baseline, the event contract holds."""
    cfg = setup[0]
    fi = FaultInjector.random_schedule(
        seed, points=ENGINE_FAULT_POINTS + SPEC_FAULT_POINTS)
    eng = make_engine(setup, faults=fi, num_pages=64)
    rng = np.random.default_rng(seed)
    prompts = [REPETITIVE[seed % len(REPETITIVE)],
               rng.integers(1, cfg.vocab_size, 12).tolist(),
               REPETITIVE[(seed + 1) % len(REPETITIVE)]]
    sink = []
    for i, p in enumerate(prompts):
        eng.submit(p, SamplingParams(max_new_tokens=int(rng.integers(3, 9)),
                                     temperature=0.7 if i == 1 else 0.0,
                                     top_k=8, speculation=3),
                   on_event=sink.append if i == 0 else None)
    eng.run(max_steps=400)
    assert not eng.sched.has_work
    assert eng.cache.pages_free == 64
    assert (eng.cache.ref == 0).all()
    assert eng.internal_errors == 0, eng.last_error
    for req in eng._by_id.values():
        assert req.state.terminal
        terminals = [e for e in req.events if e.finished]
        assert len(terminals) == 1 and req.events[-1].finished
    assert eng.spec_draft_tokens == (eng.spec_accepted_tokens
                                     + eng.spec_rollback_tokens)
