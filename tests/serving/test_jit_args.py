"""Name-derived jit argnums (rule R2's runtime helper).

The contract under test: the engine declares its static/donate intent as
parameter NAMES (``Engine._FWD_STATIC_ARGS``/``_FWD_DONATE_ARGS``) and
``argnums_of`` resolves them against the live signature — so reordering
or inserting a forward parameter re-derives the right indices, and
renaming a declared one fails loudly at construction instead of
silently staticizing/donating the wrong argument.
"""
import inspect

import pytest

from repro.serving.engine import Engine
from repro.serving.jit_args import argnums_of


def test_basic_resolution():
    def fwd(cmax, no_history, schedule, params, k_pool, v_pool):
        pass
    assert argnums_of(fwd, "cmax", "no_history", "schedule") == (0, 1, 2)
    assert argnums_of(fwd, "k_pool", "v_pool") == (4, 5)
    assert argnums_of(fwd) == ()


def test_reorder_and_insertion_track_the_signature():
    # the exact failure mode that motivated R2: a new parameter shifts
    # every literal index; names re-derive correctly
    def before(cmax, params, k_pool, v_pool):
        pass

    def after(cmax, new_schedule_arg, params, k_pool, v_pool):
        pass
    assert argnums_of(before, "k_pool", "v_pool") == (2, 3)
    assert argnums_of(after, "k_pool", "v_pool") == (3, 4)


def test_rename_fails_loudly():
    def renamed(cmax, nohist, schedule, params, k_pool, v_pool):
        pass
    with pytest.raises(ValueError, match="no_history"):
        argnums_of(renamed, *Engine._FWD_STATIC_ARGS)


def test_removed_parameter_fails_loudly():
    def fwd(cmax, schedule):
        pass
    with pytest.raises(ValueError, match=r"\['k_pool', 'v_pool'\]"):
        argnums_of(fwd, "k_pool", "v_pool")


def test_keyword_only_rejected():
    def fwd(a, b, *, donate_me):
        pass
    with pytest.raises(ValueError, match="keyword-only"):
        argnums_of(fwd, "donate_me")


def test_bound_method_excludes_self():
    class C:
        def fwd(self, cmax, k_pool):
            pass
    assert argnums_of(C().fwd, "cmax", "k_pool") == (0, 1)
    assert argnums_of(C.fwd, "cmax", "k_pool") == (1, 2)


def test_engine_declared_intent_matches_unified_forward():
    """Every declared static/donate name must exist in the real forward
    signature — this is the test that fails when someone renames a
    ``_unified_forward`` parameter without updating the intent lists."""
    sig = inspect.signature(Engine._unified_forward)
    for name in (*Engine._FWD_STATIC_ARGS, *Engine._FWD_DONATE_ARGS):
        assert name in sig.parameters, (
            f"Engine._unified_forward lost declared jit-intent "
            f"parameter {name!r}")
    # unbound function includes self at 0; the engine jits the BOUND
    # method, so construction-time indices are these minus one — pin
    # the historical layout (static 0,1,2 / donate 4,5) so an
    # accidental reorder of the static/donated args is reviewed, not
    # silent
    static = argnums_of(Engine._unified_forward, *Engine._FWD_STATIC_ARGS)
    donate = argnums_of(Engine._unified_forward, *Engine._FWD_DONATE_ARGS)
    assert static == (1, 2, 3)
    assert donate == (5, 6)
