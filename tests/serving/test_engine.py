"""End-to-end engine: generation, parity with LM.decode, crash-restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(int4_fraction=0.75, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    return cfg, qc, qparams


def test_engine_completes_requests(setup):
    cfg, qc, qparams = setup
    eng = Engine(cfg, qparams, qc,
                 EngineConfig(max_batch=4, num_pages=64, page_size=16))
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]
    for i, p in enumerate(prompts):
        eng.add_request(i, p, 5)
    done = eng.run()
    assert len(done) == 3
    for r in done:
        assert len(r.generated) == 5
        assert all(0 <= t < cfg.vocab_size for t in r.generated)


def test_engine_close_to_lm_decode(setup):
    """Greedy engine tokens vs LM.decode greedy. Activation quantization
    amplifies scan-vs-loop bf16 fusion differences across rounding
    boundaries, so parity is checked in W4A16+KV4 mode (weight-only acts)
    where only benign bf16 noise remains."""
    cfg, _, _ = setup
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    lmq = LM(cfg, quant=qc)
    prompt = [3, 1, 4, 1, 5]
    n = 6
    eng = Engine(cfg, qparams, qc,
                 EngineConfig(max_batch=2, num_pages=64, page_size=16))
    eng.add_request(0, prompt, n)
    done = eng.run()
    eng_toks = done[0].generated

    cache = lmq.init_cache(1, 64)
    lg, cache = jax.jit(lmq.prefill)(
        qparams, jnp.asarray(prompt, jnp.int32)[None], cache)
    lm_toks = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(n - 1):
        lg, cache = jax.jit(lmq.decode)(
            qparams, jnp.asarray([[lm_toks[-1]]], jnp.int32), cache)
        lm_toks.append(int(jnp.argmax(lg[0, -1])))
    agree = sum(a == b for a, b in zip(eng_toks, lm_toks)) / n
    assert agree >= 0.8, (eng_toks, lm_toks)


def test_engine_crash_restore_completes(setup):
    cfg, qc, qparams = setup
    ecfg = EngineConfig(max_batch=2, num_pages=32, page_size=16)
    eng = Engine(cfg, qparams, qc, ecfg)
    for i in range(3):
        eng.add_request(i, [1 + i, 2 + i], 4)
    eng.step()           # partial progress
    blob = eng.snapshot()
    del eng              # "crash"
    eng2 = Engine.restore(blob, cfg, qparams, qc, ecfg)
    done = eng2.run()
    assert sorted(r.request_id for r in done) == [0, 1, 2]
    for r in done:
        # pre-crash progress was folded into the prompt by snapshot();
        # total generated across incarnations must equal the request's 4
        pre_crash = len(r.prompt) - 2          # original prompts were len 2
        assert pre_crash + len(r.generated) == 4


def test_engine_snapshot_restore_mid_decode(setup):
    """Snapshot taken mid-decode (multi-page block tables live) restores
    to an engine that completes every request — block-table state is
    rebuilt through re-prefill, not resurrected. (Exact text equality is
    NOT asserted: re-prefill attends in fp while decode attends over the
    int4 pages, so greedy argmax may flip on near-ties.)"""
    cfg, qc, qparams = setup
    ecfg = EngineConfig(max_batch=3, num_pages=64, page_size=4)
    prompts = [[1, 2, 3, 4, 5], [6, 7], [8, 9, 10]]

    eng = Engine(cfg, qparams, qc, ecfg)
    for i, p in enumerate(prompts):
        eng.add_request(i, p, 7)
    for _ in range(4):       # prefill + several decode steps → pages span
        eng.step()           # multiple blocks per sequence
    slots = [r.seq_slot for r in eng.sched.running]
    assert slots, "expected in-flight sequences mid-decode"
    # live block-table state: every running seq owns ≥ 2 pages by now
    for s in slots:
        assert (eng.cache.block_table[s] >= 0).sum() >= 2
    pre = {r.request_id: len(r.generated) for r in eng.sched.running}
    blob = eng.snapshot()
    del eng                  # crash

    eng2 = Engine.restore(blob, cfg, qparams, qc, ecfg)
    # restored cache starts empty — pages come back through re-prefill
    assert eng2.cache.pages_free == ecfg.num_pages
    assert (eng2.cache.block_table == -1).all()
    done = eng2.run()
    assert sorted(r.request_id for r in done) == [0, 1, 2]
    for r in done:
        pre_crash = len(r.prompt) - len(prompts[r.request_id])
        assert pre_crash == pre.get(r.request_id, 0)
        assert pre_crash + len(r.generated) == 7
    # allocator invariants hold after the restored run drains
    assert eng2.cache.pages_free == ecfg.num_pages
    assert not eng2.cache.active


def test_engine_page_cap_terminates(setup):
    """Regression: a sequence that hits max_pages_per_seq can never
    extend (no amount of preemption frees ITS cap), which used to spin
    the headroom loop / engine forever. It must finish with
    stop_reason="length_cap" and whatever it generated so far."""
    cfg, qc, qparams = setup
    eng = Engine(cfg, qparams, qc,
                 EngineConfig(max_batch=2, num_pages=64, page_size=4,
                              max_pages_per_seq=2))      # cap = 8 tokens
    eng.add_request(0, [1, 2, 3, 4, 5], 10)
    done = eng.run(max_steps=50)
    assert len(done) == 1
    r = done[0]
    assert r.stop_reason == "length_cap"
    assert 0 < len(r.generated) < 10
    assert eng.steps < 50                    # terminated, not max_steps'd
    # pool fully reclaimed
    assert eng.cache.pages_free == 64 and not eng.cache.active


def test_engine_prompt_too_long_fails_fast(setup):
    """A prompt that can never fit the per-seq page budget fails at
    admission instead of livelocking admit/preempt cycles."""
    cfg, qc, qparams = setup
    eng = Engine(cfg, qparams, qc,
                 EngineConfig(max_batch=2, num_pages=64, page_size=4,
                              max_pages_per_seq=2))      # cap = 8 tokens
    eng.add_request(0, list(range(1, 21)), 4)            # 20-token prompt
    eng.add_request(1, [1, 2, 3], 4)                     # healthy request
    done = eng.run(max_steps=50)
    by_id = {r.request_id: r for r in done}
    assert by_id[0].stop_reason == "prompt_too_long"
    assert by_id[0].generated == []
    assert by_id[1].stop_reason is None
    assert len(by_id[1].generated) == 4


def test_engine_prompt_bigger_than_pool_fails_fast(setup):
    """Regression: a prompt within the per-seq cap but larger than the
    whole pool used to make chunked prefill stream to pool exhaustion,
    self-preempt, and restart from zero forever."""
    cfg, qc, qparams = setup
    eng = Engine(cfg, qparams, qc,
                 EngineConfig(max_batch=2, num_pages=8, page_size=8,
                              max_pages_per_seq=16,       # cap 128 > pool 64
                              prefill_chunk_tokens=16))
    eng.add_request(0, list(range(1, 101)), 4)            # 13 pages > pool
    eng.add_request(1, [1, 2, 3], 4)
    done = eng.run(max_steps=60)
    by_id = {r.request_id: r for r in done}
    assert by_id[0].stop_reason == "prompt_too_long"
    assert len(by_id[1].generated) == 4
    assert eng.steps < 60


def test_engine_pool_cap_preserves_output(setup):
    """Regression: a sequence that grows to fill the ENTIRE pool used to
    be preempted (folding its output into the prompt) and then rejected
    as prompt_too_long with empty output. It must instead finish
    length_cap, keeping everything it generated."""
    cfg, qc, qparams = setup
    eng = Engine(cfg, qparams, qc,
                 EngineConfig(max_batch=2, num_pages=4, page_size=4,
                              max_pages_per_seq=8))    # pool 16 < cap 32
    eng.add_request(0, [1, 2, 3], 100)
    done = eng.run(max_steps=60)
    assert len(done) == 1
    r = done[0]
    assert r.stop_reason == "length_cap"
    assert len(r.prompt) == 3                  # output never folded away
    # 3 + 13 written tokens fill the 16-token pool; the 14th generated
    # token was sampled by the last decode step and needs no page
    assert len(r.generated) == 14
    assert eng.sched.preemptions == 0


def test_engine_prompt_fills_pool_with_slack_is_served(setup):
    """Token-granular pool admission: a prompt whose last page has slack
    for its decode tokens is fully servable, not prompt_too_long."""
    cfg, qc, qparams = setup
    eng = Engine(cfg, qparams, qc,
                 EngineConfig(max_batch=2, num_pages=4, page_size=4,
                              max_pages_per_seq=8))
    eng.add_request(0, list(range(1, 15)), 2)  # 14 + 2 = 16 = exact pool
    done = eng.run(max_steps=30)
    assert done[0].stop_reason is None
    assert len(done[0].generated) == 2


def test_engine_preemption_under_pressure(setup):
    cfg, qc, qparams = setup
    # tiny pool forces preemption while decoding long generations
    eng = Engine(cfg, qparams, qc,
                 EngineConfig(max_batch=3, num_pages=6, page_size=4,
                              max_pages_per_seq=8))
    for i in range(3):
        eng.add_request(i, [1, 2, 3, 4, 5], 8)
    done = eng.run(max_steps=200)
    assert len(done) == 3
    for r in done:
        # preemption folds generated text into the prompt (original
        # prompts were 5 tokens): total output across incarnations == 8
        assert (len(r.prompt) - 5) + len(r.generated) == 8
        assert r.stop_reason is None
