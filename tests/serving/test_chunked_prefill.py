"""Chunked ragged prefill: parity with whole-prompt prefill, bounded fp
footprint, ragged batching across requests, mid-prefill preemption and
crash-restore.

Parity uses weight-only quantization (activation quant amplifies benign
bf16 fusion noise) and a calibrated ``kv_range`` so int4 KV history
error stays below greedy argmax margins — chunked and whole-prompt
prefill then produce token-identical greedy output.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.engine import Engine, EngineConfig

PROMPT_LENS = (40, 7, 23, 64)       # ragged, several spanning many chunks
MAX_NEW = 6


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
               for n in PROMPT_LENS]
    return cfg, qc, qparams, prompts


def make_engine(cfg, qc, qparams, mode, chunk, **kw):
    defaults = dict(max_batch=4, num_pages=96, page_size=8,
                    max_pages_per_seq=16, prefill_mode=mode,
                    prefill_chunk_tokens=chunk, kv_range=4.0)
    defaults.update(kw)
    return Engine(cfg, qparams, qc, EngineConfig(**defaults))


def run_engine(eng, prompts, max_new=MAX_NEW, max_steps=300):
    for i, p in enumerate(prompts):
        eng.add_request(i, p, max_new)
    done = eng.run(max_steps=max_steps)
    return {r.request_id: list(r.generated) for r in done}


@pytest.fixture(scope="module")
def whole_prompt_tokens(setup):
    cfg, qc, qparams, prompts = setup
    eng = make_engine(cfg, qc, qparams, "whole", 64)
    toks = run_engine(eng, prompts)
    assert eng.peak_prefill_fp_tokens == max(PROMPT_LENS)
    return toks


@pytest.mark.parametrize(
    "chunk",
    [16, 64, pytest.param(128, marks=pytest.mark.slow)])
def test_chunked_matches_whole_prompt_greedy(setup, whole_prompt_tokens,
                                             chunk):
    """Greedy token-identical across chunk sizes: below / equal / above
    the longest prompt (the last = single-chunk fp, exact by math;
    chunk=64 already hits the single-chunk boundary, so 128 is CI-slow)."""
    cfg, qc, qparams, prompts = setup
    eng = make_engine(cfg, qc, qparams, "chunked", chunk)
    toks = run_engine(eng, prompts)
    assert set(toks) == set(whole_prompt_tokens)
    for rid, expect in whole_prompt_tokens.items():
        assert toks[rid] == expect, (chunk, rid, toks[rid], expect)
    # fp prefill footprint is bounded by the chunk budget
    assert eng.peak_prefill_fp_tokens <= chunk


def test_prefill_memory_bounded_by_chunk(setup):
    """The engine never holds a whole prompt's fp KV: a 64-token prompt
    streams through 16-token forwards."""
    cfg, qc, qparams, prompts = setup
    eng = make_engine(cfg, qc, qparams, "chunked", 16)
    run_engine(eng, prompts)
    assert eng.peak_prefill_fp_tokens <= 16
    assert eng.steps > len(max(prompts, key=len)) // 16  # multi-step stream


def test_ragged_batch_prefills_in_one_step(setup):
    """Prompts from several admitted requests share ONE ragged forward:
    a single step prefills all of them and samples each first token."""
    cfg, qc, qparams, _ = setup
    eng = make_engine(cfg, qc, qparams, "chunked", 32)
    for i, n in enumerate((5, 9, 3)):
        eng.add_request(i, list(range(1, n + 1)), 4)
    eng.step()
    # one step prefilled every prompt and sampled each first token (the
    # same step then also ran one decode, so ≥ 1 token per request)
    assert all(r.prefilled and len(r.generated) >= 1
               for r in eng.sched.running)


def test_decode_interleaves_with_long_prefill(setup):
    """While a long prompt streams chunk-by-chunk, already-running
    requests keep decoding — the interference the chunking removes."""
    cfg, qc, qparams, _ = setup
    eng = make_engine(cfg, qc, qparams, "chunked", 8)
    eng.add_request(0, list(range(1, 9)), 12)       # short, decodes early
    eng.add_request(1, list(range(1, 49)), 4)       # long, 6 chunks
    eng.run(max_steps=300)
    assert eng.interleaved_steps >= 3


def test_mid_prefill_preemption_restarts_cleanly(setup):
    """Preempting a request mid-prefill resets prefill_pos, frees pages,
    and re-admission completes it with full output length."""
    cfg, qc, qparams, _ = setup
    eng = make_engine(cfg, qc, qparams, "chunked", 8)
    prompt = list(range(1, 33))
    eng.add_request(0, prompt, 4)
    eng.step()                                       # one 8-token chunk
    req = eng.sched.running[0]
    assert 0 < req.prefill_pos < len(prompt)
    victim = eng.sched.preempt_one(eng.cache)
    assert victim is req and victim.prefill_pos == 0
    assert victim.prompt == prompt                   # nothing generated yet
    done = eng.run(max_steps=200)
    assert len(done) == 1 and len(done[0].generated) == 4


def test_snapshot_restore_mid_prefill(setup):
    """Crash while prompts are mid-prefill: pending work survives, the
    restored engine re-prefills from scratch and completes everything."""
    cfg, qc, qparams, prompts = setup
    ecfg = EngineConfig(max_batch=4, num_pages=96, page_size=8,
                        max_pages_per_seq=16, prefill_mode="chunked",
                        prefill_chunk_tokens=8, kv_range=4.0)
    eng = Engine(cfg, qparams, qc, ecfg)
    for i, p in enumerate(prompts):
        eng.add_request(i, p, MAX_NEW)
    eng.step()                       # several requests now mid-prefill
    mid = [r for r in eng.sched.running if 0 < r.prefill_pos < len(r.prompt)]
    assert mid, "expected at least one mid-prefill request"
    blob = eng.snapshot()
    del eng                          # crash

    eng2 = Engine.restore(blob, cfg, qparams, qc, ecfg)
    assert eng2.cache.pages_free == ecfg.num_pages
    done = eng2.run(max_steps=400)
    assert sorted(r.request_id for r in done) == list(range(len(prompts)))
    for r in done:
        # no tokens were generated pre-crash, so prompts are untouched
        assert len(r.prompt) == PROMPT_LENS[r.request_id]
        assert len(r.generated) == MAX_NEW
