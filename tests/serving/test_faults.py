"""Chaos suite for the fault-tolerant serving core.

Seeded fault schedules (``FaultInjector.random_schedule``) drive the
engine through injected allocator exhaustion, forward failures, NaN
logits, sampler blow-ups, KV-append failures, and throwing callbacks,
and the suite pins the serving invariants that must hold under ALL of
them:

* ``step()`` never raises (and on the unified path every injected fault
  is absorbed by its dedicated guard — ``internal_errors`` stays 0);
* the paged pool returns to baseline after the workload drains
  (refcount-exact quarantine: ``pages_free == num_pages``, all
  refcounts 0);
* every request reaches exactly ONE terminal event, it is the LAST
  event, and no token event ever follows it;
* the token-event stream equals the request's lifetime emitted count.

Named schedules then exercise each fault point's specific isolation
contract (batch-granular vs row-granular quarantine, exhaustion as a
condition, callback detach), deadlines/TTFT run against an injectable
fake clock, and the bounded waiting queue's reject/shed paths are
driven end-to-end.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.api import RequestState, SamplingParams
from repro.serving.engine import Engine, EngineConfig
from repro.serving.faults import (FAULT_POINTS, Fault, FaultInjector,
                                  InjectedFault)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    return cfg, qc, qparams


def make_engine(setup, faults=None, clock=None, **kw):
    cfg, qc, qparams = setup
    # sanitize=True: every seeded fault schedule also runs the
    # step-boundary runtime sanitizers (serving/sanitize.py) — a chaos
    # case that corrupted refcounts or duplicated a terminal would now
    # raise SanitizerError out of step() instead of passing silently
    defaults = dict(max_batch=4, num_pages=64, page_size=8,
                    max_pages_per_seq=16, prefill_chunk_tokens=24,
                    kv_range=4.0, sanitize=True)
    defaults.update(kw)
    ekw = {}
    if faults is not None:
        ekw["faults"] = faults
    if clock is not None:
        ekw["clock"] = clock
    return Engine(cfg, qparams, qc, EngineConfig(**defaults), **ekw)


class FakeClock:
    """Injectable wall clock: deadline tests advance time explicitly.
    Starts at 1.0, not 0.0 — the engine uses ``first_token_at == 0.0``
    as its "no first token yet" sentinel (harmless under real
    ``time.time()``, which is never 0)."""

    def __init__(self, t: float = 1.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def assert_serving_invariants(eng, num_pages=64):
    """The invariants every chaos run must uphold, whatever was injected."""
    assert not eng.sched.has_work                    # workload drained
    assert eng.cache.pages_free == num_pages         # pool back to baseline
    assert (eng.cache.ref == 0).all()
    assert not eng.cache.active
    for req in eng._by_id.values():
        assert req.state.terminal, \
            f"request {req.request_id} ended non-terminal: {req.state}"
        terminals = [e for e in req.events if e.finished]
        assert len(terminals) == 1, \
            f"request {req.request_id}: {len(terminals)} terminal events"
        assert req.events[-1].finished, \
            f"request {req.request_id}: events after the terminal event"
        tokens = [e for e in req.events if e.token is not None]
        assert all(not e.finished for e in tokens)
        assert len(tokens) == req.emitted


# ------------------------------------------------------------- chaos sweep


@pytest.mark.parametrize("seed", range(20))
def test_chaos_seeded_schedules(setup, seed):
    """20 seeded random fault mixes: whatever fires, step() never
    raises, pages return to baseline, and the event contract holds."""
    cfg = setup[0]
    fi = FaultInjector.random_schedule(seed)
    eng = make_engine(setup, faults=fi)
    rng = np.random.default_rng(seed)
    sink = []
    for i in range(4):
        prompt = rng.integers(1, cfg.vocab_size,
                              int(rng.integers(5, 15))).tolist()
        eng.submit(prompt,
                   SamplingParams(
                       max_new_tokens=int(rng.integers(3, 7)),
                       temperature=0.7 if i == 1 else 0.0, top_k=8),
                   # a callback on one request arms the emit_event point
                   on_event=sink.append if i == 0 else None)
    eng.run(max_steps=300)      # step() raising would propagate here
    assert_serving_invariants(eng)
    # every injected fault has a dedicated guard on the unified path —
    # nothing should fall through to the last-resort backstop
    assert eng.internal_errors == 0, eng.last_error
    # the schedule is deterministic: what fired is replayable from the
    # seed, and anything that fired was absorbed (asserts above held)
    assert all(p in FAULT_POINTS for p, _, _ in fi.fired)


# ------------------------------------------------- per-point named schedules


def test_forward_raise_quarantines_batch(setup):
    """An exception inside the forward fails every request in THAT
    step's batch — refcount-exact page release, step() returns."""
    fi = FaultInjector([Fault("forward", step=2, action="raise")])
    eng = make_engine(setup, faults=fi)
    hs = [eng.submit([3 + i, 5, 7, 11, 13], SamplingParams(max_new_tokens=6))
          for i in range(3)]
    eng.run(max_steps=100)
    states = [eng.result(h).state for h in hs]
    assert all(s == RequestState.FAILED for s in states)
    assert all(eng.result(h).stop_reason.startswith("forward:")
               for h in hs)
    assert eng.failed_count == 3
    assert fi.fired == [("forward", "raise", 2)]
    assert_serving_invariants(eng)
    assert eng.internal_errors == 0


def test_forward_nan_isolates_single_row(setup):
    """NaN logits on one row quarantine exactly that request — the rest
    of the batch keeps decoding to a clean finish."""
    fi = FaultInjector([Fault("forward", step=3, action="nan", row=1)])
    eng = make_engine(setup, faults=fi)
    hs = [eng.submit([3 + i, 5, 7, 11, 13], SamplingParams(max_new_tokens=6))
          for i in range(3)]
    eng.run(max_steps=100)
    by_state: dict = {}
    for h in hs:
        by_state.setdefault(eng.result(h).state, []).append(eng.result(h))
    assert len(by_state[RequestState.FAILED]) == 1
    assert by_state[RequestState.FAILED][0].stop_reason == \
        "non_finite_logits"
    assert len(by_state[RequestState.FINISHED]) == 2
    for r in by_state[RequestState.FINISHED]:
        assert len(r.generated) == 6        # survivors run to completion
    assert eng.failed_count == 1
    assert_serving_invariants(eng)
    assert eng.internal_errors == 0


def test_sample_fault_fails_only_sampled_rows(setup):
    """A sampler exception fails exactly the rows being sampled; a
    request still mid-prefill at that step is untouched."""
    fi = FaultInjector([Fault("sample", nth=2)])
    eng = make_engine(setup, faults=fi)
    ha = eng.submit([2, 3, 5, 7, 11, 13], SamplingParams(max_new_tokens=6))
    # 60-token prompt: prefill spans 3+ chunks of <=24 tokens, so this
    # request is still mid-prefill — NOT in the sampled set — when the
    # 2nd sampler call blows up on ha's decode row
    hb = eng.submit(list(range(2, 62)), SamplingParams(max_new_tokens=4))
    eng.run(max_steps=100)
    assert eng.result(ha).state == RequestState.FAILED
    assert eng.result(ha).stop_reason.startswith("sample:")
    assert eng.result(hb).state == RequestState.FINISHED
    assert len(eng.result(hb).generated) == 4
    assert eng.failed_count == 1
    assert_serving_invariants(eng)
    assert eng.internal_errors == 0


def test_append_kv_fault_quarantines_batch(setup):
    """A KV write-destination failure aborts the step's forward before
    any pool write — the batch quarantines, pages to baseline."""
    fi = FaultInjector([Fault("append_kv", nth=3)])
    eng = make_engine(setup, faults=fi)
    hs = [eng.submit([3 + i, 5, 7, 11], SamplingParams(max_new_tokens=5))
          for i in range(2)]
    eng.run(max_steps=100)
    for h in hs:
        assert eng.result(h).state == RequestState.FAILED
        assert "append_kv" in eng.result(h).stop_reason
    assert fi.fired[0][0] == "append_kv"
    assert_serving_invariants(eng)
    assert eng.internal_errors == 0


def test_alloc_exhaust_degrades_without_corruption(setup):
    """Allocator exhaustion is a CONDITION, not an exception: the first
    page acquisition coming up dry just defers admission one step — the
    request still finishes cleanly, nothing fails."""
    fi = FaultInjector([Fault("alloc_page", nth=1)])
    eng = make_engine(setup, faults=fi)
    h = eng.submit([2, 3, 5, 7], SamplingParams(max_new_tokens=4))
    eng.run(max_steps=100)
    assert fi.fired[0][0] == "alloc_page"
    assert eng.result(h).state == RequestState.FINISHED
    assert len(eng.result(h).generated) == 4
    assert eng.failed_count == 0
    assert_serving_invariants(eng)
    assert eng.internal_errors == 0


def test_emit_event_fault_detaches_callback(setup):
    """A throwing on_event callback is detached and counted — the
    request itself survives to a clean finish with its event log
    intact; only the push deliveries after the throw are lost."""
    fi = FaultInjector([Fault("emit_event", nth=2)])
    eng = make_engine(setup, faults=fi)
    received = []
    h = eng.submit([2, 3, 5, 7], SamplingParams(max_new_tokens=4),
                   on_event=received.append)
    eng.run(max_steps=100)
    req = eng.result(h)
    assert req.state == RequestState.FINISHED
    assert len(req.generated) == 4
    assert eng.callback_errors == 1
    assert req.on_event is None                 # detached, not retried
    assert len(received) == 1                   # only the pre-fault delivery
    assert len([e for e in req.events if e.token is not None]) == 4
    assert_serving_invariants(eng)


# --------------------------------------------------------- deadlines / TTFT


def test_deadline_expires_mid_decode_with_partial_output(setup):
    """A running request past deadline_ms lands in TIMED_OUT at the next
    step boundary — partial output retained, pages freed exactly;
    deadline-free requests are untouched."""
    clock = FakeClock()
    eng = make_engine(setup, clock=clock)
    ha = eng.submit([2, 3, 5, 7], SamplingParams(max_new_tokens=10,
                                                 deadline_ms=50.0))
    hb = eng.submit([11, 13, 17], SamplingParams(max_new_tokens=4))
    for _ in range(3):
        eng.step()          # both decode a few tokens at t=0
    got = len(eng.result(ha).generated)
    assert got >= 1
    clock.t = 1.051         # 51ms > the 50ms deadline
    eng.run(max_steps=100)
    ra = eng.result(ha)
    assert ra.state == RequestState.TIMED_OUT
    assert ra.stop_reason == "deadline"
    assert len(ra.generated) == got             # partial output retained
    assert eng.result(hb).state == RequestState.FINISHED
    assert eng.timeout_count == 1
    assert_serving_invariants(eng)


def test_ttft_budget_expires_before_first_token_only(setup):
    """ttft_ms guards the FIRST token: a tokenless request past it times
    out with "ttft_budget"; one that already produced a token is immune
    to the TTFT budget (only deadline_ms can expire it)."""
    clock = FakeClock()
    eng = make_engine(setup, clock=clock)
    hb = eng.submit([11, 13, 17], SamplingParams(max_new_tokens=4,
                                                 ttft_ms=50.0))
    for _ in range(2):
        eng.step()          # hb gets its first token at t=1.0
    assert len(eng.result(hb).generated) >= 1
    ha = eng.submit([2, 3, 5, 7], SamplingParams(max_new_tokens=4,
                                                 ttft_ms=50.0))
    clock.t = 1.051         # past ha's TTFT budget before it ever steps
    eng.run(max_steps=100)
    assert eng.result(ha).state == RequestState.TIMED_OUT
    assert eng.result(ha).stop_reason == "ttft_budget"
    assert eng.result(ha).generated == []
    assert eng.result(hb).state == RequestState.FINISHED   # immune: has TTFT
    assert_serving_invariants(eng)


def test_dead_on_arrival_never_acquires_pages(setup):
    """Expiry runs BEFORE admission: a request already past its deadline
    when the step starts is torn down without ever touching the pool."""
    clock = FakeClock()
    eng = make_engine(setup, clock=clock)
    h = eng.submit([2, 3, 5, 7], SamplingParams(max_new_tokens=4,
                                                deadline_ms=1.0))
    clock.t = 2.0           # 1000ms >> the 1ms deadline: dead on arrival
    eng.step()
    assert eng.result(h).state == RequestState.TIMED_OUT
    assert eng.cache.pages_free == 64      # never held a page
    assert eng.steps == 1
    assert_serving_invariants(eng)


# ------------------------------------------------- backpressure: reject/shed


def test_submit_rejects_when_waiting_queue_full(setup):
    """Bounded waiting queue: a submit against a full queue comes back
    already terminal — FAILED("queue_full"), no pages or slots held."""
    eng = make_engine(setup, max_waiting=1)
    h0 = eng.submit([2, 3, 5], SamplingParams(max_new_tokens=3))
    h1 = eng.submit([7, 11, 13], SamplingParams(max_new_tokens=3))
    r1 = eng.result(h1)
    assert r1.state == RequestState.FAILED       # rejected at submit
    assert r1.stop_reason == "queue_full"
    assert r1.events[-1].finished                # terminal event emitted
    assert eng.rejected_count == 1
    eng.run(max_steps=100)
    assert eng.result(h0).state == RequestState.FINISHED
    assert_serving_invariants(eng)


def test_preemption_sheds_victim_when_queue_full(setup):
    """Under pool pressure with the waiting queue full, the preemption
    victim is SHED (FAILED "shed") instead of re-queued — overload
    becomes an explicit, counted outcome, and the survivors finish."""
    # 4-page pool, 2 running seqs: both outgrow their two pages at the
    # 16-token boundary, the extend fails, and the youngest is preempted
    # — with the queue held full by a third request, the victim is shed.
    # Submits interleave with steps: against a max_waiting=1 queue, two
    # back-to-back submits before any admission would just reject the
    # second at the door
    eng = make_engine(setup, max_batch=2, num_pages=4, page_size=8,
                      max_pages_per_seq=4, max_waiting=1)
    rng = np.random.default_rng(11)
    ha = eng.submit(rng.integers(1, 100, 8).tolist(),
                    SamplingParams(max_new_tokens=12))
    eng.step()                                   # admit ha
    hb = eng.submit(rng.integers(1, 100, 8).tolist(),
                    SamplingParams(max_new_tokens=12))
    eng.step()                                   # admit hb
    hc = eng.submit(rng.integers(1, 100, 8).tolist(),
                    SamplingParams(max_new_tokens=2))
    assert eng.sched.waiting_full                # hc holds the only slot
    eng.run(max_steps=300)
    assert eng.shed_count >= 1
    shed = [r for r in (eng.result(h) for h in (ha, hb, hc))
            if r.stop_reason == "shed"]
    assert shed and all(r.state == RequestState.FAILED for r in shed)
    survivors = [r for r in (eng.result(h) for h in (ha, hb, hc))
                 if r.stop_reason != "shed"]
    assert all(r.state == RequestState.FINISHED for r in survivors)
    assert_serving_invariants(eng, num_pages=4)


# ------------------------------------------------------ schedule validation


def test_fault_validation():
    with pytest.raises(ValueError, match="unknown fault point"):
        Fault("warp_core", nth=1)
    with pytest.raises(ValueError, match="exactly one trigger"):
        Fault("forward")                       # neither nth nor step
    with pytest.raises(ValueError, match="exactly one trigger"):
        Fault("forward", nth=1, step=2)        # both
    with pytest.raises(ValueError, match="not valid for point"):
        Fault("alloc_page", nth=1, action="raise")
    with pytest.raises(ValueError, match="not valid for point"):
        Fault("sample", nth=1, action="nan")
    # defaults: action falls back to the point's canonical failure mode
    assert Fault("alloc_page", nth=1).action == "exhaust"
    assert Fault("forward", step=1).action == "raise"


def test_from_spec_parses_cli_grammar():
    fi = FaultInjector.from_spec(
        "forward:step=3,action=nan,row=2; alloc_page:nth=20; sample:nth=2")
    assert [f.point for f in fi.faults] == ["forward", "alloc_page",
                                            "sample"]
    f0 = fi.faults[0]
    assert (f0.step, f0.action, f0.row) == (3, "nan", 2)
    assert fi.faults[1].nth == 20 and fi.faults[1].action == "exhaust"
    with pytest.raises(ValueError, match="unknown fault key"):
        FaultInjector.from_spec("forward:when=3")
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultInjector.from_spec("bogus:nth=1")


def test_random_schedule_is_deterministic():
    a = FaultInjector.random_schedule(42)
    b = FaultInjector.random_schedule(42)
    assert a.describe() == b.describe()
    assert a.describe() != FaultInjector.random_schedule(43).describe()


def test_injector_fires_each_fault_once():
    fi = FaultInjector([Fault("sample", nth=2),
                        Fault("sample", nth=3)])
    assert fi.check("sample") is None          # hit 1
    assert fi.check("sample").nth == 2         # hit 2 fires
    assert fi.check("sample").nth == 3         # hit 3 fires the other
    assert fi.check("sample") is None          # both spent
    assert fi.hits["sample"] == 4
    assert [f for f in fi.pending] == []
    assert [p for p, _, _ in fi.fired] == ["sample", "sample"]


def test_step_triggered_fault_fires_on_step():
    fi = FaultInjector([Fault("forward", step=3)])
    fi.begin_step(2)
    assert fi.check("forward") is None
    fi.begin_step(3)
    f = fi.check("forward")
    assert f is not None and isinstance(InjectedFault("x"), RuntimeError)
    fi.begin_step(3)
    assert fi.check("forward") is None         # fire-once
