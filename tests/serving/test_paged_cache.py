"""Paged KV4 pool: write/append/gather roundtrips vs direct quant, plus
allocator invariants for the O(1) page-count bookkeeping and chunked
page acquisition (grow_to)."""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.serving.kv_cache import PagedKV4Cache, PagedKV4Config


def table_counts(cache):
    return (cache.block_table >= 0).sum(axis=1).astype(np.int32)


def test_page_count_tracks_block_table():
    """page_count (the O(1) replacement for the extend_seq row scan)
    stays equal to the block-table row population through allocate /
    extend / grow_to / free."""
    cfg = get_smoke_config("llama3_8b")
    cache = PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=16, page_size=4, max_seqs=4,
                            max_pages_per_seq=8), 1)
    assert cache.allocate_seq(0, 10)            # 3 pages
    assert cache.allocate_seq(1, 1)             # 1 page
    np.testing.assert_array_equal(cache.page_count, table_counts(cache))
    cache.seq_len[0] = 10
    for _ in range(3):                          # 11th token → 3 pages still
        assert cache.extend_seq(0)
        cache.seq_len[0] += 1
    np.testing.assert_array_equal(cache.page_count, table_counts(cache))
    assert cache.grow_to(1, 14) == 16           # 4 pages (page-granular)
    np.testing.assert_array_equal(cache.page_count, table_counts(cache))
    cache.free_seq(0)
    cache.free_seq(1)
    np.testing.assert_array_equal(cache.page_count, np.zeros(4, np.int32))
    assert cache.pages_free == 16


def test_grow_to_partial_and_capped():
    """grow_to grabs what the pool has (partial capacity is usable for a
    smaller chunk) and never exceeds max_pages_per_seq."""
    cfg = get_smoke_config("llama3_8b")
    cache = PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=4, page_size=8, max_seqs=4,
                            max_pages_per_seq=3), 1)
    assert cache.allocate_seq(0, 8)             # 1 page
    assert cache.allocate_seq(1, 16)            # 2 pages → 1 page left
    assert cache.grow_to(0, 24) == 16           # wanted 3, pool had 1 more
    cache.free_seq(1)
    assert cache.grow_to(0, 24) == 24           # now fully backed
    assert cache.grow_to(0, 100) == 24          # capped at 3 pages
    assert cache.at_capacity(0) is False        # seq_len still short
    cache.seq_len[0] = 24
    assert cache.at_capacity(0) is True


def test_allocate_rejects_over_cap():
    cfg = get_smoke_config("llama3_8b")
    cache = PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=16, page_size=4, max_seqs=4,
                            max_pages_per_seq=2), 1)
    assert not cache.allocate_seq(0, 9)         # 3 pages > cap 2
    assert cache.pages_free == 16 and 0 not in cache.active
    assert cache.allocate_seq(0, 8)
    assert cache.max_tokens_per_seq == 8


def test_write_gather_roundtrip(rng):
    cfg = get_smoke_config("llama3_8b")
    cache = PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=8, page_size=4, max_seqs=4,
                            max_pages_per_seq=8), 2)
    t = 10
    k = jnp.asarray(rng.normal(size=(1, t, cfg.num_kv_heads, cfg.head_dim)),
                    jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, t, cfg.num_kv_heads, cfg.head_dim)),
                    jnp.float32)
    assert cache.allocate_seq(0, t)
    cache.write_prompt(0, 0, k, v)
    cache.write_prompt(1, 0, k * 0.5, v * 0.5)
    kp, vp, lens = cache.gather_kv(0, [0], t)
    assert int(lens[0]) == t
    kp_direct, vp_direct = cache.quantize_kv(k, v)
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(kp_direct))
    np.testing.assert_array_equal(np.asarray(vp), np.asarray(vp_direct))
    # append one token
    assert cache.extend_seq(0)
    k1 = jnp.asarray(rng.normal(size=(1, 1, cfg.num_kv_heads, cfg.head_dim)),
                     jnp.float32)
    cache.append_token(0, 0, k1, k1, pos=t)
    cache.advance([0])
    kp2, _, lens2 = cache.gather_kv(0, [0], t + 1)
    assert int(lens2[0]) == t + 1
    k1p, _ = cache.quantize_kv(k1, k1)
    np.testing.assert_array_equal(np.asarray(kp2[0, :, t]),
                                  np.asarray(k1p[0, :, 0]))
    # earlier tokens untouched
    np.testing.assert_array_equal(np.asarray(kp2[0, :, :t]),
                                  np.asarray(kp_direct[0]))
