"""Paged KV4 pool: write/append/gather roundtrips vs direct quant, plus
allocator invariants for the O(1) page-count bookkeeping, chunked page
acquisition (grow_to), and the refcounted prefix cache (publish/match/
adopt, reclaimable-LRU eviction)."""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.serving.kv_cache import PagedKV4Cache, PagedKV4Config


def table_counts(cache):
    return (cache.block_table >= 0).sum(axis=1).astype(np.int32)


def test_page_count_tracks_block_table():
    """page_count (the O(1) replacement for the extend_seq row scan)
    stays equal to the block-table row population through allocate /
    extend / grow_to / free."""
    cfg = get_smoke_config("llama3_8b")
    cache = PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=16, page_size=4, max_seqs=4,
                            max_pages_per_seq=8), 1)
    assert cache.allocate_seq(0, 10)            # 3 pages
    assert cache.allocate_seq(1, 1)             # 1 page
    np.testing.assert_array_equal(cache.page_count, table_counts(cache))
    cache.seq_len[0] = 10
    for _ in range(3):                          # 11th token → 3 pages still
        assert cache.extend_seq(0)
        cache.seq_len[0] += 1
    np.testing.assert_array_equal(cache.page_count, table_counts(cache))
    assert cache.grow_to(1, 14) == 16           # 4 pages (page-granular)
    np.testing.assert_array_equal(cache.page_count, table_counts(cache))
    cache.free_seq(0)
    cache.free_seq(1)
    np.testing.assert_array_equal(cache.page_count, np.zeros(4, np.int32))
    assert cache.pages_free == 16


def test_grow_to_partial_and_capped():
    """grow_to grabs what the pool has (partial capacity is usable for a
    smaller chunk) and never exceeds max_pages_per_seq."""
    cfg = get_smoke_config("llama3_8b")
    cache = PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=4, page_size=8, max_seqs=4,
                            max_pages_per_seq=3), 1)
    assert cache.allocate_seq(0, 8)             # 1 page
    assert cache.allocate_seq(1, 16)            # 2 pages → 1 page left
    assert cache.grow_to(0, 24) == 16           # wanted 3, pool had 1 more
    cache.free_seq(1)
    assert cache.grow_to(0, 24) == 24           # now fully backed
    assert cache.grow_to(0, 100) == 24          # capped at 3 pages
    assert cache.at_capacity(0) is False        # seq_len still short
    cache.seq_len[0] = 24
    assert cache.at_capacity(0) is True


def test_allocate_rejects_over_cap():
    cfg = get_smoke_config("llama3_8b")
    cache = PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=16, page_size=4, max_seqs=4,
                            max_pages_per_seq=2), 1)
    assert not cache.allocate_seq(0, 9)         # 3 pages > cap 2
    assert cache.pages_free == 16 and 0 not in cache.active
    assert cache.allocate_seq(0, 8)
    assert cache.max_tokens_per_seq == 8


def test_write_gather_roundtrip(rng):
    cfg = get_smoke_config("llama3_8b")
    cache = PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=8, page_size=4, max_seqs=4,
                            max_pages_per_seq=8), 2)
    t = 10
    k = jnp.asarray(rng.normal(size=(1, t, cfg.num_kv_heads, cfg.head_dim)),
                    jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, t, cfg.num_kv_heads, cfg.head_dim)),
                    jnp.float32)
    assert cache.allocate_seq(0, t)
    cache.write_prompt(0, 0, k, v)
    cache.write_prompt(1, 0, k * 0.5, v * 0.5)
    kp, vp, lens = cache.gather_kv(0, [0], t)
    assert int(lens[0]) == t
    kp_direct, vp_direct = cache.quantize_kv(k, v)
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(kp_direct))
    np.testing.assert_array_equal(np.asarray(vp), np.asarray(vp_direct))
    # append one token
    assert cache.extend_seq(0)
    k1 = jnp.asarray(rng.normal(size=(1, 1, cfg.num_kv_heads, cfg.head_dim)),
                     jnp.float32)
    cache.append_token(0, 0, k1, k1, pos=t)
    cache.advance([0])
    kp2, _, lens2 = cache.gather_kv(0, [0], t + 1)
    assert int(lens2[0]) == t + 1
    k1p, _ = cache.quantize_kv(k1, k1)
    np.testing.assert_array_equal(np.asarray(kp2[0, :, t]),
                                  np.asarray(k1p[0, :, 0]))
    # earlier tokens untouched
    np.testing.assert_array_equal(np.asarray(kp2[0, :, :t]),
                                  np.asarray(kp_direct[0]))


# --------------------------------------------- refcounted prefix cache


def make_prefix_cache(num_pages=8, page_size=4, max_seqs=4):
    cfg = get_smoke_config("llama3_8b")
    return PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=num_pages, page_size=page_size,
                            max_seqs=max_seqs, max_pages_per_seq=8), 1)


def test_publish_match_adopt_share_and_reclaim():
    cache = make_prefix_cache()
    tokens = list(range(1, 13))                 # 12 tokens = 3 full pages
    assert cache.allocate_seq(0, 12)
    cache.seq_len[0] = 12
    cache.publish_prefix(0, tokens)
    # matching caps one token short of the prompt: a prompt equal to the
    # published tokens matches only 2 of its 3 pages
    assert cache.match_prefix(tokens)[1] == 8
    pages, matched = cache.match_prefix(tokens + [99, 100])
    assert matched == 12 and len(pages) == 3
    # adopt: second sequence shares all 3 pages, allocates 1 private
    free_before = cache.pages_free
    assert cache.allocate_seq(1, 14, prefix_pages=pages, prefix_tokens=12)
    assert cache.pages_free == free_before - 1  # only the suffix charged
    assert int(cache.seq_len[1]) == 12
    assert (cache.ref[np.asarray(pages)] == 2).all()
    np.testing.assert_array_equal(cache.block_table[1, :3],
                                  cache.block_table[0, :3])
    # shared pages survive the publisher's exit (ref 2 → 1)
    cache.free_seq(0)
    assert (cache.ref[np.asarray(pages)] == 1).all()
    assert cache.match_prefix(tokens + [99])[1] == 12
    # last owner leaves: published pages become reclaimable but stay
    # cached (counted free, still matchable) — private page truly freed
    cache.free_seq(1)
    assert cache.pages_free == 8
    assert (cache.ref == 0).all()
    assert cache.match_prefix(tokens + [99])[1] == 12
    # adopting a reclaimable page revives it off the LRU
    pages2, m2 = cache.match_prefix(tokens + [5])
    assert cache.allocate_seq(2, 13, prefix_pages=pages2, prefix_tokens=m2)
    assert (cache.ref[np.asarray(pages2)] == 1).all()
    cache.free_seq(2)


def test_eviction_takes_lru_reclaimable_pages_first():
    cache = make_prefix_cache(num_pages=3, page_size=4)
    prompt_a = [1, 2, 3, 4, 9]                  # one full publishable page
    prompt_b = [5, 6, 7, 8, 9]
    assert cache.allocate_seq(0, 5)
    cache.seq_len[0] = 5
    cache.publish_prefix(0, prompt_a)
    cache.free_seq(0)                           # page(a) → reclaimable
    assert cache.allocate_seq(1, 5)
    cache.seq_len[1] = 5
    cache.publish_prefix(1, prompt_b)
    cache.free_seq(1)                           # page(b) → reclaimable
    assert cache.pages_free == 3
    # demand 2 pages: 1 from the free list + evict the OLDEST
    # reclaimable page (a's) — b's stays cached
    assert cache.allocate_seq(2, 8)
    assert cache.match_prefix(prompt_a) == ([], 0)
    assert cache.match_prefix(prompt_b)[1] == 4
    # pool fully dry → allocation fails (this is where the scheduler's
    # preemption would fire, strictly after LRU eviction)
    assert not cache.allocate_seq(3, 8)


def test_allocate_rejects_when_prefix_pages_cannot_double_as_headroom():
    """A matched prefix sitting on the reclaimable LRU counts in
    pages_free, but adopting it consumes that slack — the acquisition
    check must not count those pages twice."""
    cache = make_prefix_cache(num_pages=2, page_size=4)
    tokens = list(range(1, 9))                  # 2 full pages
    assert cache.allocate_seq(0, 8)
    cache.seq_len[0] = 8
    cache.publish_prefix(0, tokens)
    cache.free_seq(0)
    assert cache.pages_free == 2                # both reclaimable
    pages, matched = cache.match_prefix(tokens + [7])
    assert matched == 8
    # needs 2 shared + 1 private = 3 pages; the pool only has 2
    assert not cache.allocate_seq(1, 9, prefix_pages=pages,
                                  prefix_tokens=matched)
    assert cache.pages_free == 2                # no partial adoption
    assert (cache.ref == 0).all()


def test_allocate_rolls_back_on_midloop_exhaustion():
    """``_acquire_page`` failing partway through the acquisition loop
    must roll back EVERYTHING the call took — adopted prefix refs and
    already-acquired pages — leaving the block-table row fully unmapped
    and the pool byte-exact. (Regression: the row assignment used to
    poison the int32 block table when the acquisition returned None,
    and the adopted refs leaked.)"""
    cache = make_prefix_cache(num_pages=8, page_size=4)
    tokens = list(range(1, 9))                  # 2 full publishable pages
    assert cache.allocate_seq(0, 8)
    cache.seq_len[0] = 8
    cache.publish_prefix(0, tokens)
    cache.free_seq(0)                           # both pages → reclaimable
    pages, matched = cache.match_prefix(tokens + [9])
    assert matched == 8
    free_before = cache.pages_free
    ref_before = cache.ref.copy()
    real = cache._acquire_page
    calls = {"n": 0}

    def flaky_acquire():                        # 2nd acquisition dies
        calls["n"] += 1
        return real() if calls["n"] == 1 else None

    cache._acquire_page = flaky_acquire
    try:
        # 2 adopted + 2 acquired needed; the estimate says both
        # acquisitions fit, but the second one comes back dry
        ok = cache.allocate_seq(1, 16, prefix_pages=pages, prefix_tokens=8)
    finally:
        cache._acquire_page = real
    assert ok is False and 1 not in cache.active
    # row fully unmapped: nothing for token_dests/build_work_queue to
    # trip over later
    assert (cache.block_table[1] == -1).all()
    np.testing.assert_array_equal(cache.ref, ref_before)
    assert cache.pages_free == free_before
    # the adopted prefix went back to the reclaimable LRU: still
    # matchable, and a retry with honest acquisitions succeeds
    pages2, m2 = cache.match_prefix(tokens + [9])
    assert m2 == 8
    assert cache.allocate_seq(2, 16, prefix_pages=pages2, prefix_tokens=8)
    assert (cache.block_table[2, :4] >= 0).all()


def test_first_publisher_wins_duplicate_prefix():
    """Two sequences prefill the same prompt concurrently: the second
    publish is a no-op and its pages stay private (freed on exit)."""
    cache = make_prefix_cache()
    tokens = [1, 2, 3, 4]
    assert cache.allocate_seq(0, 4)
    assert cache.allocate_seq(1, 4)
    cache.seq_len[0] = cache.seq_len[1] = 4
    cache.publish_prefix(0, tokens)
    cache.publish_prefix(1, tokens)
    p0, p1 = int(cache.block_table[0, 0]), int(cache.block_table[1, 0])
    assert cache.page_key.get(p0) is not None
    assert cache.page_key.get(p1) is None       # stayed private
    cache.free_seq(1)
    assert p1 in cache.free_pages               # truly freed
    cache.free_seq(0)
    assert cache.match_prefix(tokens + [9])[1] == 4


def make_capped_cache(max_pages_cached, num_pages=8, page_size=4):
    cfg = get_smoke_config("llama3_8b")
    pb = (2 * page_size * cfg.num_kv_heads * (cfg.head_dim // 2))
    return PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=num_pages, page_size=page_size,
                            max_seqs=4, max_pages_per_seq=8,
                            reclaimable_max_bytes=max_pages_cached * pb), 1)


def publish_and_free(cache, seq_id, tokens):
    assert cache.allocate_seq(seq_id, len(tokens))
    cache.seq_len[seq_id] = len(tokens)
    cache.publish_prefix(seq_id, tokens)
    cache.free_seq(seq_id)


def test_reclaimable_byte_cap_evicts_lru():
    """The LRU holds at most ``reclaimable_max_bytes``: publishing past
    the cap evicts oldest-first (their index entries go with them), the
    eviction counter ticks, and the newest prefixes stay matchable."""
    cache = make_capped_cache(max_pages_cached=2)
    prompts = [[i * 10 + j for j in range(5)] for i in range(3)]
    for i, p in enumerate(prompts[:2]):
        publish_and_free(cache, i, p)
    assert cache.prefix_reclaimable_bytes == 2 * cache.page_bytes
    assert cache.prefix_evicted_pages == 0
    publish_and_free(cache, 2, prompts[2])      # cap → evict prompt 0's page
    assert cache.prefix_reclaimable_bytes == 2 * cache.page_bytes
    assert cache.prefix_evicted_pages == 1
    assert cache.match_prefix(prompts[0]) == ([], 0)     # evicted
    assert cache.match_prefix(prompts[1])[1] == 4        # survivors
    assert cache.match_prefix(prompts[2])[1] == 4
    # evicted pages are genuinely free (on the free list, not the LRU)
    assert cache.pages_free == 8 and len(cache.free_pages) == 6


def test_zero_byte_cap_disables_caching_without_leaks():
    """Cap 0 → every published page is evicted the moment its refcount
    drops; the allocator stays exact (pages all return to the free
    list) and matching never hits."""
    cache = make_capped_cache(max_pages_cached=0)
    tokens = list(range(1, 10))
    publish_and_free(cache, 0, tokens)
    assert cache.prefix_reclaimable_bytes == 0
    assert cache.prefix_evicted_pages == 2      # both full pages dropped
    assert cache.match_prefix(tokens + [99]) == ([], 0)
    assert len(cache.free_pages) == 8


def test_acquire_pressure_eviction_counts():
    """Allocation-pressure evictions (the pre-preemption LRU pop) tick
    the same counter as cap evictions."""
    cache = make_prefix_cache(num_pages=2, page_size=4)
    publish_and_free(cache, 0, [1, 2, 3, 4, 9])
    assert cache.prefix_evicted_pages == 0
    assert cache.allocate_seq(1, 8)             # needs both pages → evict
    assert cache.prefix_evicted_pages == 1
    assert cache.prefix_reclaimable_bytes == 0


# ------------------------------------------ truncate_seq (spec rollback)


def test_truncate_releases_tail_pages_and_sets_len():
    """The basic rollback move: a verify chunk grew the sequence past
    its committed length; truncate drops the tail pages, updates the
    O(1) page_count, and lands seq_len — all consistent with the
    block-table row."""
    cache = make_prefix_cache(num_pages=8, page_size=4)
    assert cache.allocate_seq(0, 16)            # 4 pages
    cache.seq_len[0] = 16
    assert cache.truncate_seq(0, 6) == 2        # 4 pages → 2
    assert int(cache.seq_len[0]) == 6
    assert int(cache.page_count[0]) == 2
    np.testing.assert_array_equal(cache.page_count, table_counts(cache))
    assert cache.pages_free == 6
    # idempotent at a page boundary: nothing more to drop
    assert cache.truncate_seq(0, 5) == 0
    assert int(cache.page_count[0]) == 2


def test_truncate_may_raise_seq_len_within_backing():
    """new_len past seq_len is legal up to the page-backed capacity:
    the spec path scatters KV beyond seq_len during verification and
    lands the accepted length in one truncate call."""
    cache = make_prefix_cache(num_pages=8, page_size=4)
    assert cache.allocate_seq(0, 10)            # 3 pages = 12 tokens backed
    cache.seq_len[0] = 7
    assert cache.truncate_seq(0, 11) == 0       # advance, no release
    assert int(cache.seq_len[0]) == 11
    assert int(cache.page_count[0]) == 3


def test_truncate_then_regrow_reuses_freed_pages():
    """Released tail pages go back to the pool and grow_to can take
    them again — the draft/verify/rollback cycle doesn't leak."""
    cache = make_prefix_cache(num_pages=4, page_size=4)
    assert cache.allocate_seq(0, 16)            # whole pool
    cache.seq_len[0] = 16
    cache.truncate_seq(0, 4)                    # 3 pages released
    assert cache.pages_free == 3
    assert cache.grow_to(0, 16) == 16           # regrown from the pool
    np.testing.assert_array_equal(cache.page_count, table_counts(cache))
    cache.free_seq(0)
    assert cache.pages_free == 4 and len(cache.free_pages) == 4


def test_truncate_errors_inactive_and_out_of_range():
    cache = make_prefix_cache()
    try:
        cache.truncate_seq(0, 0)
        assert False, "inactive seq must be rejected"
    except ValueError as e:
        assert "not active" in str(e)
    assert cache.allocate_seq(0, 8)             # 2 pages = 8 tokens backed
    for bad in (-1, 9):
        try:
            cache.truncate_seq(0, bad)
            assert False, f"new_len={bad} outside page backing must raise"
        except ValueError as e:
            assert "page-backed range" in str(e)
    # state untouched by the rejected calls
    assert int(cache.page_count[0]) == 2 and int(cache.seq_len[0]) == 0


def test_truncate_shared_prefix_pages_survive_for_owner():
    """Rollback on an adopting sequence drops only ITS references:
    shared prefix pages keep serving the publisher (ref 2 → 1) and stay
    matchable; only the adopter's private tail page is truly freed."""
    cache = make_prefix_cache(num_pages=8, page_size=4)
    tokens = list(range(1, 9))                  # 2 full pages
    assert cache.allocate_seq(0, 8)
    cache.seq_len[0] = 8
    cache.publish_prefix(0, tokens)
    pages, matched = cache.match_prefix(tokens + [99])
    assert matched == 8
    assert cache.allocate_seq(1, 12, prefix_pages=pages, prefix_tokens=8)
    cache.seq_len[1] = 12
    assert (cache.ref[np.asarray(pages)] == 2).all()
    # roll the adopter all the way back into the shared prefix
    assert cache.truncate_seq(1, 5) == 1        # private page dropped
    assert (cache.ref[np.asarray(pages)] == 2).all()  # still co-owned
    assert cache.truncate_seq(1, 2) == 1        # drops one SHARED page
    assert cache.ref[pages[0]] == 2 and cache.ref[pages[1]] == 1
    # the publisher's view is untouched
    assert int(cache.seq_len[0]) == 8
    assert cache.match_prefix(tokens + [99])[1] == 8
    cache.free_seq(1)
    cache.free_seq(0)
    assert cache.pages_free == 8


def test_truncate_published_page_parks_on_reclaimable_lru():
    """A published page whose last reference is dropped BY TRUNCATE
    parks on the reclaimable LRU exactly like free_seq: counted free,
    still matchable, revivable by a later adopter."""
    cache = make_prefix_cache(num_pages=8, page_size=4)
    tokens = list(range(1, 9))
    assert cache.allocate_seq(0, 8)
    cache.seq_len[0] = 8
    cache.publish_prefix(0, tokens)
    assert cache.truncate_seq(0, 4) == 1        # published page, ref 1 → 0
    assert cache.pages_free == 7                # counted free...
    assert len(cache.free_pages) == 6           # ...but parked, not freed
    # the parked page stays MATCHABLE: its KV is intact until evicted
    assert cache.match_prefix(tokens + [99])[1] == 8
    # a new adopter revives the parked page off the LRU (ref 0 → 1);
    # the page still co-owned by seq 0 just gains a reference
    pages, m = cache.match_prefix(tokens + [77])
    assert m == 8
    assert cache.allocate_seq(1, 9, prefix_pages=pages, prefix_tokens=8)
    assert cache.ref[pages[0]] == 2 and cache.ref[pages[1]] == 1
    cache.free_seq(0)
    cache.free_seq(1)
    assert cache.pages_free == 8
