"""Paged KV4 pool: write_prompt/append/gather roundtrip vs direct quant."""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.serving.kv_cache import PagedKV4Cache, PagedKV4Config


def test_write_gather_roundtrip(rng):
    cfg = get_smoke_config("llama3_8b")
    cache = PagedKV4Cache(
        cfg, PagedKV4Config(num_pages=8, page_size=4, max_seqs=4,
                            max_pages_per_seq=8), 2)
    t = 10
    k = jnp.asarray(rng.normal(size=(1, t, cfg.num_kv_heads, cfg.head_dim)),
                    jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, t, cfg.num_kv_heads, cfg.head_dim)),
                    jnp.float32)
    assert cache.allocate_seq(0, t)
    cache.write_prompt(0, 0, k, v)
    cache.write_prompt(1, 0, k * 0.5, v * 0.5)
    kp, vp, lens = cache.gather_kv(0, [0], t)
    assert int(lens[0]) == t
    kp_direct, vp_direct = cache.quantize_kv(k, v)
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(kp_direct))
    np.testing.assert_array_equal(np.asarray(vp), np.asarray(vp_direct))
    # append one token
    assert cache.extend_seq(0)
    k1 = jnp.asarray(rng.normal(size=(1, 1, cfg.num_kv_heads, cfg.head_dim)),
                     jnp.float32)
    cache.append_token(0, 0, k1, k1, pos=t)
    cache.advance([0])
    kp2, _, lens2 = cache.gather_kv(0, [0], t + 1)
    assert int(lens2[0]) == t + 1
    k1p, _ = cache.quantize_kv(k1, k1)
    np.testing.assert_array_equal(np.asarray(kp2[0, :, t]),
                                  np.asarray(k1p[0, :, 0]))
    # earlier tokens untouched
    np.testing.assert_array_equal(np.asarray(kp2[0, :, :t]),
                                  np.asarray(kp_direct[0]))
