"""Request-lifecycle API: streaming events, abort at every lifecycle
state, per-request sampling params, and refcounted prefix-cache reuse
(shared-prefix parity, preempt→resume under a warm cache).

Parity scenarios use the weight-only + calibrated ``kv_range`` regime
of the chunked/unified parity suites: int4 KV error stays below greedy
argmax margins, so prefix-cache on/off is token-identical.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.lm import LM, QuantConfig
from repro.serving.api import RequestState, SamplingParams
from repro.serving.engine import Engine, EngineConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_8b")
    qc = QuantConfig(weight_only=True, kv4=True, impl="ref")
    lm = LM(cfg)
    params, axes = lm.init(jax.random.PRNGKey(0))
    qparams, _ = LM(cfg, quant=qc).quantize(params, axes)
    return cfg, qc, qparams


def make_engine(setup, **kw):
    cfg, qc, qparams = setup
    defaults = dict(max_batch=4, num_pages=64, page_size=8,
                    max_pages_per_seq=16, prefill_chunk_tokens=24,
                    kv_range=4.0)
    defaults.update(kw)
    return Engine(cfg, qparams, qc, EngineConfig(**defaults))


def prompts_with_shared_prefix(cfg, n=3, prefix_len=32, suffix_len=5):
    rng = np.random.default_rng(7)
    prefix = rng.integers(1, cfg.vocab_size, prefix_len).tolist()
    return [prefix + rng.integers(1, cfg.vocab_size, suffix_len).tolist()
            for _ in range(n)]


def run_staggered(eng, prompts, max_new):
    """Serve the first prompt to completion, then the rest — later
    arrivals see whatever the first published into the prefix cache."""
    eng.add_request(0, prompts[0], max_new)
    eng.run()
    for i, p in enumerate(prompts[1:], start=1):
        eng.add_request(i, p, max_new)
    done = eng.run()
    return {r.request_id: list(r.generated) for r in done}


# ------------------------------------------------------------ prefix cache


def test_shared_prefix_parity_and_fewer_prefill_tokens(setup):
    """N requests sharing a system prompt: cache-on is greedy-token-
    identical to cache-off while forwarding strictly fewer prompt
    tokens (the shared prefix is served from published pages)."""
    cfg = setup[0]
    prompts = prompts_with_shared_prefix(cfg)
    off = make_engine(setup, prefix_cache=False)
    toks_off = run_staggered(off, prompts, max_new=6)
    on = make_engine(setup, prefix_cache=True)
    toks_on = run_staggered(on, prompts, max_new=6)

    assert toks_on == toks_off
    assert off.prefix_hit_tokens == 0
    # each later request hits the 32-token (4-page) published prefix
    assert on.prefix_hit_tokens == 2 * 32
    assert on.prefill_tokens < off.prefill_tokens
    assert on.prefill_tokens + on.prefix_hit_tokens == off.prefill_tokens
    # lifecycle bookkeeping: everything finished cleanly
    for r in on.sched.finished:
        assert r.state == RequestState.FINISHED
        assert r.stop_reason is None


def test_prefix_cache_refcounts_are_exact(setup):
    """After the workload drains, every page is reclaimable: refcounts
    all zero, pages_free back to the full pool (published pages survive
    on the reclaimable LRU and still count as free)."""
    cfg = setup[0]
    eng = make_engine(setup, prefix_cache=True)
    run_staggered(eng, prompts_with_shared_prefix(cfg), max_new=4)
    assert not eng.cache.active
    assert (eng.cache.ref == 0).all()
    assert eng.cache.pages_free == eng.ecfg.num_pages
    # the published prefix is still cached — a new identical prompt hits
    pages, matched = eng.cache.match_prefix(
        prompts_with_shared_prefix(cfg)[0])
    assert matched >= 32 and len(pages) >= 4


def test_preempt_resume_warm_prefix_is_a_hit(setup):
    """Satellite regression: a preempted request drops only its private
    pages; re-admission goes through match_prefix, so its own
    already-published prompt pages are a warm hit and only the tail
    re-forwards."""
    cfg = setup[0]
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab_size, 18).tolist()   # 2 full pages
    eng = make_engine(setup, prefix_cache=True)
    h = eng.submit(prompt, SamplingParams(max_new_tokens=8))
    while not eng._resolve(h).prefilled:
        eng.step()
    req = eng._resolve(h)
    assert req.state == RequestState.DECODING
    assert eng.cache.match_prefix(prompt)[1] == 16   # prefix published
    victim = eng.sched.preempt_one(eng.cache)
    assert victim is req and req.state == RequestState.QUEUED
    # its published pages survived the preemption, ref==0 (reclaimable)
    assert eng.cache.match_prefix(prompt)[1] == 16
    eng.run()
    assert req.state == RequestState.FINISHED
    assert req.cached_tokens == 16                   # warm re-admission
    assert eng.prefix_hit_tokens == 16
    # stream log == final output even across the preemption fold
    streamed = [e.token for e in req.events if e.token is not None]
    assert streamed == req.prompt[len(prompt):] + req.generated
    assert eng.cache.pages_free == eng.ecfg.num_pages


def test_prefix_cache_off_for_whole_prompt_baseline(setup):
    eng = make_engine(setup, prefill_mode="whole", prefix_cache=True)
    assert not eng.ecfg.prefix_caching
    prompts = prompts_with_shared_prefix(setup[0])
    run_staggered(eng, prompts, max_new=2)
    assert eng.prefix_hit_tokens == 0


# ------------------------------------------------------------------- abort


def test_abort_while_queued(setup):
    eng = make_engine(setup)
    base = eng.cache.pages_free
    h = eng.submit([1, 2, 3, 4], SamplingParams(max_new_tokens=4))
    assert eng.abort(h)
    req = eng.result(h)
    assert req.state == RequestState.ABORTED
    assert req.stop_reason == "aborted" and req.generated == []
    assert eng.cache.pages_free == base
    assert not eng.sched.waiting and not eng.sched.running
    assert not eng.abort(h)              # already terminal → no-op
    ev = eng.events()
    assert len(ev) == 1 and ev[0].finished
    assert ev[0].state == RequestState.ABORTED


def test_abort_mid_prefill_restores_pages(setup):
    cfg = setup[0]
    rng = np.random.default_rng(5)
    eng = make_engine(setup, prefill_chunk_tokens=8)
    base = eng.cache.pages_free
    h = eng.submit(rng.integers(1, cfg.vocab_size, 40).tolist(),
                   SamplingParams(max_new_tokens=4))
    eng.step()
    req = eng.result(h)
    assert req.state == RequestState.PREFILLING
    assert 0 < req.prefill_pos < len(req.prompt)
    assert eng.cache.pages_free < base   # pages held mid-prefill
    assert eng.abort(h)
    assert eng.cache.pages_free == base  # nothing published mid-prefill
    assert (eng.cache.ref == 0).all()
    assert req.state == RequestState.ABORTED
    assert not eng.sched.has_work


def test_abort_mid_decode_restores_pages_and_serves_others(setup):
    cfg = setup[0]
    rng = np.random.default_rng(6)
    eng = make_engine(setup)
    base = eng.cache.pages_free
    ha = eng.submit(rng.integers(1, cfg.vocab_size, 12).tolist(),
                    SamplingParams(max_new_tokens=50))
    hb = eng.submit(rng.integers(1, cfg.vocab_size, 9).tolist(),
                    SamplingParams(max_new_tokens=5))
    while len(eng.result(ha).generated) < 3:
        eng.step()
    assert eng.result(ha).state == RequestState.DECODING
    assert eng.abort(ha)
    done = eng.run()
    assert eng.result(hb).state == RequestState.FINISHED
    assert len(eng.result(hb).generated) == 5
    assert eng.result(ha) in done
    assert len(eng.result(ha).generated) == 3    # kept what it had
    # refcount-exact: all pages back (published prompt pages reclaimable)
    assert eng.cache.pages_free == base
    assert (eng.cache.ref == 0).all()
    assert eng.aborted_count == 1


# -------------------------------------------------------- streaming/events


def test_stream_yields_tokens_in_final_order(setup):
    cfg = setup[0]
    rng = np.random.default_rng(8)
    eng = make_engine(setup)
    handles = [eng.submit(rng.integers(1, cfg.vocab_size, n).tolist(),
                          SamplingParams(max_new_tokens=6))
               for n in (11, 5, 17)]
    events = list(eng.stream(handles[1]))
    toks = [e.token for e in events if e.token is not None]
    req = eng.result(handles[1])
    assert toks == req.generated and len(toks) == 6
    assert events[-1].finished and events[-1].state == RequestState.FINISHED
    # the other requests rode along in the same steps and also finish
    eng.run()
    for h in handles:
        r = eng.result(h)
        assert [e.token for e in r.events if e.token is not None] \
            == r.generated


def test_events_and_callback_cover_every_token(setup):
    cfg = setup[0]
    rng = np.random.default_rng(9)
    eng = make_engine(setup)
    pushed = []
    h = eng.submit(rng.integers(1, cfg.vocab_size, 7).tolist(),
                   SamplingParams(max_new_tokens=4),
                   on_event=pushed.append)
    eng.run()
    drained = eng.events()
    req = eng.result(h)
    assert [e.token for e in pushed if e.token is not None] == req.generated
    assert pushed == drained            # same objects, same order
    assert pushed[-1].finished and pushed[-1].stop_reason is None
    assert eng.events() == []           # drained exactly once


# ------------------------------------------------- per-request sampling


def test_per_request_sampling_params(setup):
    """One batch mixing a greedy and a stochastic request: the greedy
    request's text matches a solo greedy run, and the stochastic one is
    reproducible (keyed by request_id/position) and actually varied."""
    cfg = setup[0]
    rng = np.random.default_rng(11)
    prompt = rng.integers(1, cfg.vocab_size, 10).tolist()

    # reference batch has the same shape (two rows) so the jitted
    # forward traces identically — only the second row's sampler differs
    ref = make_engine(setup)
    hs = ref.submit(prompt, SamplingParams(max_new_tokens=8))
    ref.submit(prompt, SamplingParams(max_new_tokens=8))
    ref.run()
    greedy_ref = list(ref.result(hs).generated)

    outs = []
    for _ in range(2):
        eng = make_engine(setup)
        hg = eng.submit(prompt, SamplingParams(max_new_tokens=8))
        ht = eng.submit(prompt, SamplingParams(
            max_new_tokens=8, temperature=0.9, top_k=8))
        eng.run()
        assert list(eng.result(hg).generated) == greedy_ref
        outs.append(list(eng.result(ht).generated))
    assert outs[0] == outs[1]           # reproducible stochastic text
    assert len(set(outs[0])) > 1        # and actually sampled


def test_submit_auto_ids_coexist_with_add_request(setup):
    eng = make_engine(setup)
    eng.add_request(0, [1, 2, 3], 2)
    h = eng.submit([4, 5, 6], SamplingParams(max_new_tokens=2))
    assert h.request_id != 0 and h.prompt_len == 3
    with pytest.raises(ValueError):
        eng.submit([7], request_id=0)
    done = eng.run()
    assert sorted(r.request_id for r in done) == sorted([0, h.request_id])


def test_pool_donation_gated_off_on_cpu(setup):
    """Buffer donation for the KV pools is only enabled on backends
    that honor it; the CPU test backend must not donate (XLA would warn
    and copy anyway)."""
    eng = make_engine(setup)
    assert eng.donate_pools == (jax.default_backend() in ("tpu", "gpu"))
    assert jax.default_backend() == "cpu" and not eng.donate_pools


def test_prompt_too_long_emits_terminal_event(setup):
    """Admission-time rejections never pass through the normal complete
    path but still owe their terminal event (event-driven consumers
    would otherwise wait forever)."""
    seen = []
    eng = make_engine(setup, max_pages_per_seq=2)    # cap = 16 tokens
    h = eng.submit(list(range(1, 40)), SamplingParams(max_new_tokens=2),
                   on_event=seen.append)
    eng.submit([1, 2, 3], SamplingParams(max_new_tokens=2))
    eng.run()
    req = eng.result(h)
    assert req.stop_reason == "prompt_too_long"
    assert req.state == RequestState.FINISHED
    assert len(seen) == 1 and seen[0].finished
    assert seen[0].stop_reason == "prompt_too_long"
    replay = list(eng.stream(h))                     # replays the log
    assert len(replay) == 1 and replay[0].finished
    assert any(e.request_id == h.request_id and e.finished
               for e in eng.events())


def test_request_id_reusable_after_terminal(setup):
    """Terminal ids can be recycled (the pre-lifecycle API allowed it);
    only genuinely in-flight ids are rejected."""
    eng = make_engine(setup)
    eng.add_request(0, [1, 2, 3], 2)
    eng.run()
    eng.add_request(0, [4, 5, 6], 3)                 # reuse after finish
    done = eng.run()
    assert len(eng.result(0).generated) == 3
    assert sum(1 for r in done if r.request_id == 0) == 2


def test_reentrant_abort_from_callback_keeps_terminal_event_last(setup):
    """An on_event callback that aborts ANOTHER request mid-step must
    not cause a token event after that request's terminal event."""
    eng = make_engine(setup)
    hb = eng.submit([9, 8, 7, 6], SamplingParams(max_new_tokens=6))

    def killer(ev):
        if ev.token is not None:
            eng.abort(hb)

    eng.submit([1, 2, 3], SamplingParams(max_new_tokens=6),
               on_event=killer)
    eng.run()
    b = eng.result(hb)
    assert b.state == RequestState.ABORTED
    terminal_at = [i for i, e in enumerate(b.events) if e.finished]
    assert terminal_at == [len(b.events) - 1]        # terminal is LAST
    assert [e.token for e in b.events if e.token is not None] \
        == b.generated


def test_reentrant_abort_during_length_cap_reservation(setup):
    """A length_cap completion fires its terminal event INSIDE the
    decode-reservation loop; if its callback aborts a request still on
    the pending/ready lists, that request's freed slot (-1) must never
    reach extend_seq or the forward (numpy would wrap the index and
    corrupt another sequence's pages)."""
    eng = make_engine(setup, page_size=4, max_pages_per_seq=2,
                      num_pages=16)                  # cap = 8 tokens/seq
    hb = eng.submit([9, 8, 7, 6], SamplingParams(max_new_tokens=20))

    def killer(ev):
        if ev.finished and ev.stop_reason == "length_cap":
            eng.abort(hb)

    ha = eng.submit([1, 2, 3, 4, 5], SamplingParams(max_new_tokens=20),
                    on_event=killer)
    eng.run(max_steps=60)
    a, b = eng.result(ha), eng.result(hb)
    assert a.state == RequestState.FINISHED
    assert a.stop_reason == "length_cap"
    assert b.state == RequestState.ABORTED
    # B's event log stays well-formed: terminal last, tokens == output
    assert [e.finished for e in b.events].index(True) == len(b.events) - 1
    assert [e.token for e in b.events if e.token is not None] == b.generated
    # no leaked or corrupted pages
    assert eng.cache.pages_free == 16 and (eng.cache.ref == 0).all()


# ------------------------------------------------------- bounded retention


def test_release_bounds_terminal_retention(setup):
    """Regression (ROADMAP bounded-retention item): terminal request
    state used to live for the engine's lifetime — ``release(handle)``
    must return ``sched.finished``, the id map, and the event logs to
    their pre-submit baseline so memory scales with in-flight work."""
    eng = make_engine(setup)
    handles = [eng.submit([1 + i, 2, 3, 4 + i], SamplingParams(
        max_new_tokens=4)) for i in range(4)]
    eng.run()
    eng.events()                         # consume the engine-wide queue
    results = {h.request_id: list(eng.result(h).generated)
               for h in handles}
    assert all(len(t) == 4 for t in results.values())
    assert len(eng.sched.finished) == 4
    assert all(eng.result(h).events for h in handles)

    for h in handles:
        assert eng.release(h)
    assert len(eng.sched.finished) == 0          # scheduler forgot them
    assert all(eng.result(h) is None for h in handles)   # id map too
    # idempotent / unknown-safe
    assert not eng.release(handles[0])
    assert not eng.release(12345)


def test_release_refuses_in_flight(setup):
    """Only terminal requests release — in-flight state must go through
    abort() (refcount-exact) first."""
    eng = make_engine(setup)
    h = eng.submit([5, 6, 7], SamplingParams(max_new_tokens=8))
    assert not eng.release(h)            # QUEUED
    eng.step()
    assert not eng.release(h)            # PREFILLING/DECODING
    assert eng.abort(h)
    assert eng.release(h)
    assert eng.result(h) is None
    assert eng.cache.pages_free == eng.ecfg.num_pages


def test_release_makes_request_id_reusable(setup):
    """A released id can be resubmitted immediately (the batch API's
    fixed-id pattern keeps working under bounded retention)."""
    eng = make_engine(setup)
    eng.add_request(0, [1, 2, 3], 3)
    eng.run()
    first = list(eng.result(0).generated)
    assert eng.release(0)
    eng.add_request(0, [1, 2, 3], 3)
    eng.run()
    assert list(eng.result(0).generated) == first
